"""Cell assembly: (arch, shape, mesh) -> lowered-compilable step.

``build_cell`` returns everything the dry-run, the launcher, and the
roofline harness need: the step function, abstract (ShapeDtypeStruct)
inputs — zero device allocation — matching in/out shardings, donation
indices, and analytic MODEL_FLOPS for the roofline's useful-compute ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs
from repro.core import ec_sghmc, sghmc
from repro.distributed import sharding as shd
from repro.models import abstract_params, active_params, get_model, param_axes
from repro.serve.loop import make_decode_step, make_prefill_step
from repro.train.step import make_train_step

# archs whose dims divide the model axis poorly — run them data-parallel
PURE_DP = frozenset({"whisper-base", "xlstm-350m"})
# archs needing FSDP at serve time (params too big for TP-only)
SERVE_FSDP = frozenset({"grok-1-314b", "gemma3-27b", "gemma2-27b", "qwen2-vl-7b"})
N_DATA = 1_000_000_000  # representative corpus size for the N/|B| NLL scale
VLM_PATCHES = 64


def vlm_patches(seq_len: int) -> int:
    """Patch-prefix length; bounded so tiny smoke shapes keep text tokens."""
    return min(VLM_PATCHES, seq_len // 2)


class Cell(NamedTuple):
    arch: str
    shape: str
    kind: str  # train | prefill | decode
    fn: Callable
    args: tuple  # abstract args
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    static_argnums: tuple
    model_flops: float  # analytic useful FLOPs per step (6ND / 2ND)
    num_chains: int
    meta: dict


def _stack(tree, k: int):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct((k,) + s.shape, s.dtype), tree)


def _stack_axes(tree):
    is_ax = lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)
    return jax.tree.map(lambda ax: ("chain",) + ax, tree, is_leaf=is_ax)


def _shardings(axes_tree, shapes_tree, rules, mesh):
    return shd.tree_shardings(axes_tree, shapes_tree, rules, mesh)


def _key_abstract():
    return jax.eval_shape(lambda: jax.random.key(0))


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, PartitionSpec()), tree)


def _train_batch(cfg, k: int, per_chain_batch: int, seq: int):
    """(abstract batch, axes tree) with leading chain axis."""
    i32 = jnp.int32
    B, S = per_chain_batch, seq
    sds = jax.ShapeDtypeStruct
    if cfg.family == "vlm":
        n_patch = vlm_patches(S)
        n_text = S - n_patch
        batch = {
            "tokens": sds((k, B, n_text), i32),
            "labels": sds((k, B, n_text), i32),
            "patch_embeds": sds((k, B, n_patch, cfg.d_model), cfg.compute_dtype),
            "positions": sds((k, 3, B, S), i32),
        }
        axes = {
            "tokens": ("chain", "batch", "seq"),
            "labels": ("chain", "batch", "seq"),
            "patch_embeds": ("chain", "batch", "seq", None),
            "positions": ("chain", None, "batch", "seq"),
        }
    elif cfg.family == "audio":
        batch = {
            "tokens": sds((k, B, S), i32),
            "labels": sds((k, B, S), i32),
            "frame_embeds": sds((k, B, cfg.enc_seq, cfg.d_model), cfg.compute_dtype),
        }
        axes = {
            "tokens": ("chain", "batch", "seq"),
            "labels": ("chain", "batch", "seq"),
            "frame_embeds": ("chain", "batch", "seq", None),
        }
    else:
        batch = {"tokens": sds((k, B, S), i32), "labels": sds((k, B, S), i32)}
        axes = {"tokens": ("chain", "batch", "seq"), "labels": ("chain", "batch", "seq")}
    return batch, axes


def _serve_batch(cfg, batch_size: int, seq: int, prefill: bool):
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    B, S = batch_size, seq
    if prefill:
        if cfg.family == "vlm":
            n_patch = vlm_patches(S)
            n_text = S - n_patch
            return (
                {
                    "tokens": sds((B, n_text), i32),
                    "labels": sds((B, n_text), i32),
                    "patch_embeds": sds((B, n_patch, cfg.d_model), cfg.compute_dtype),
                    "positions": sds((3, B, S), i32),
                },
                {
                    "tokens": ("batch", "seq"),
                    "labels": ("batch", "seq"),
                    "patch_embeds": ("batch", "seq", None),
                    "positions": (None, "batch", "seq"),
                },
            )
        if cfg.family == "audio":
            return (
                {
                    "tokens": sds((B, S), i32),
                    "frame_embeds": sds((B, cfg.enc_seq, cfg.d_model), cfg.compute_dtype),
                },
                {"tokens": ("batch", "seq"), "frame_embeds": ("batch", "seq", None)},
            )
        return (
            {"tokens": sds((B, S), i32)},
            {"tokens": ("batch", "seq")},
        )
    return {"tokens": sds((B, 1), i32)}, {"tokens": ("batch", None)}


def default_sampler(
    cfg, arch: str, num_chains: int, sync_every: int = 4, fused: bool = False,
    compress_sync: bool = False,
):
    """The paper's sampler wired for this arch (state dtype tracks params)."""
    state_dtype = cfg.param_dtype
    if num_chains > 1:
        compression = None
        if compress_sync:
            from repro.distributed.compression import int8_codec

            compression = int8_codec()
        return ec_sghmc(
            step_size=1e-5,
            alpha=1.0,
            friction=1.0,
            center_friction=1.0,
            sync_every=sync_every,
            state_dtype=state_dtype,
            fused=fused,
            compression=compression,
        )
    return sghmc(step_size=1e-5, friction=1.0, state_dtype=state_dtype)


def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    smoke: bool = False,
    num_chains: int | None = None,
    sync_every: int = 4,
    overrides: dict | None = None,
    fsdp: bool = True,
    serve_fsdp: bool | None = None,
    compress_sync: bool = False,
    shard_style: str = "tp_fsdp",
) -> Cell:
    cfg = configs.get_config(arch, smoke=smoke)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = configs.SHAPES[shape_name]
    model = get_model(cfg)
    pure_dp = arch in PURE_DP
    specs = model.param_specs(cfg)
    p_abs = abstract_params(specs)
    p_axes = param_axes(specs)
    n_active = active_params(cfg)

    pods = mesh.shape.get("pod", 1)
    if cell.kind == "train":
        k = num_chains if num_chains is not None else configs.EC_CHAINS[arch] * pods
        k = max(k, 1)
        sampler = default_sampler(cfg, arch, k, sync_every, compress_sync=compress_sync)
        step = make_train_step(cfg, model, sampler, n_data=N_DATA)
        params_abs = _stack(p_abs, k)
        params_axes = _stack_axes(p_axes)
        state_abs = jax.eval_shape(sampler.init, params_abs)
        per_chain_b = max(cell.global_batch // k, 1)
        batch_abs, batch_axes = _train_batch(cfg, k, per_chain_b, cell.seq_len)

        prm_rules = shd.train_param_rules(mesh, pure_dp, fsdp=fsdp, style=shard_style)
        ctr_rules = shd.center_rules(mesh, pure_dp)
        bat_rules = shd.batch_rules(mesh, pure_dp, style=shard_style)
        params_shard = _shardings(params_axes, params_abs, prm_rules, mesh)
        if hasattr(state_abs, "center"):  # ECSGHMCState
            state_shard = type(state_abs)(
                momentum=_shardings(params_axes, state_abs.momentum, prm_rules, mesh),
                center=_shardings(p_axes, state_abs.center, ctr_rules, mesh),
                center_momentum=_shardings(p_axes, state_abs.center_momentum, ctr_rules, mesh),
                center_stale=_shardings(p_axes, state_abs.center_stale, ctr_rules, mesh),
                mean_theta_stale=_shardings(p_axes, state_abs.mean_theta_stale, ctr_rules, mesh),
                step=NamedSharding(mesh, PartitionSpec()),
            )
        else:  # SGHMCState
            state_shard = type(state_abs)(
                momentum=_shardings(params_axes, state_abs.momentum, prm_rules, mesh),
                step=NamedSharding(mesh, PartitionSpec()),
            )
        batch_shard = _shardings(batch_axes, batch_abs, bat_rules, mesh)
        key_abs = _key_abstract()
        tokens = cell.global_batch * cell.seq_len
        return Cell(
            arch,
            shape_name,
            "train",
            step,
            (params_abs, state_abs, batch_abs, key_abs),
            (params_shard, state_shard, batch_shard, NamedSharding(mesh, PartitionSpec())),
            (params_shard, state_shard, _replicated(mesh, {"potential": 0, "nll_per_token": 0})),
            (0, 1),
            (),
            6.0 * n_active * tokens,
            k,
            {"tokens_per_step": tokens, "n_active": n_active},
        )

    # ---- serving cells ----------------------------------------------------
    use_serve_fsdp = (arch in SERVE_FSDP) if serve_fsdp is None else serve_fsdp
    srv_rules = shd.serve_param_rules(mesh, fsdp=use_serve_fsdp, pure_dp=pure_dp, style=shard_style)
    bat_rules = shd.serve_batch_rules(mesh)
    params_shard = _shardings(p_axes, p_abs, srv_rules, mesh)

    if cell.kind == "prefill":
        step = make_prefill_step(cfg, model, max_seq=cell.seq_len, cache_dtype=cfg.compute_dtype)
        batch_abs, batch_axes = _serve_batch(cfg, cell.global_batch, cell.seq_len, True)
        batch_shard = _shardings(batch_axes, batch_abs, bat_rules, mesh)
        tokens = cell.global_batch * cell.seq_len
        return Cell(
            arch,
            shape_name,
            "prefill",
            step,
            (p_abs, batch_abs),
            (params_shard, batch_shard),
            None,
            (),
            (),
            2.0 * n_active * tokens,
            1,
            {"tokens_per_step": tokens, "n_active": n_active},
        )

    # decode (decode_32k / long_500k): one new token against a seq_len cache
    step = make_decode_step(cfg, model)
    cache_abs = model.make_cache(cfg, cell.global_batch, cell.seq_len, cfg.compute_dtype, abstract=True)
    cache_ax = model.cache_axes(cfg)
    cache_shard = _shardings(cache_ax, cache_abs, bat_rules, mesh)
    tok_abs, tok_axes = _serve_batch(cfg, cell.global_batch, cell.seq_len, False)
    tok_shard = _shardings(tok_axes, tok_abs, bat_rules, mesh)
    return Cell(
        arch,
        shape_name,
        "decode",
        step,
        (p_abs, cache_abs, tok_abs["tokens"]),
        (params_shard, cache_shard, tok_shard["tokens"]),
        (tok_shard["tokens"], cache_shard),
        (1,),
        (),
        2.0 * n_active * cell.global_batch,
        1,
        {"tokens_per_step": cell.global_batch, "n_active": n_active},
    )
