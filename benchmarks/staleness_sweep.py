"""Paper §2 analysis: staleness ladder s ∈ {1, 2, 4, 8, 16} for naive Async
SGHMC vs EC-SGHMC on the MLP posterior.

Claim reproduced: small s (1 < s < 4) is unproblematic even for the naive
scheme; growing s hurts Async SGHMC much more than EC-SGHMC.

Mixing diagnostics (probe ESS, split-R̂, cross-chain spread) come from the
shared ``repro.diagnostics`` subsystem via the posterior driver — staleness
should depress the naive scheme's ESS before it shows in final NLL.

Execution: every (scheme, s) cell runs DEVICE-RESIDENT through the
posterior driver's ``ChainExecutor`` (whole eval intervals as one scan
program, moments/ESS in the carry).  The ladder itself stays a Python loop
by necessity, not laziness: ``sync_every`` and the async worker phases are
STRUCTURAL hyperparameters — they change the compiled program (DESIGN.md
§3) — so the s-axis cannot ride the executor's vmapped sweep axis the way
the (alpha, step_size) grids in ``sampler_overhead`` do."""
from __future__ import annotations

import time

import numpy as np

from repro import core
from repro.data import synthetic_mnist
from repro.models import mlp, init_params

from common import QUICK, emit, record
from posterior_driver import run_sampling, sgd_map

K = 6
EPS, FRIC = sgd_map(lr=3e-7, beta=0.9)


def run():
    hidden = 128 if QUICK else 800
    n_train = 8000 if QUICK else 60_000
    steps = 200 if QUICK else 1500
    svals = (1, 2, 4, 8) if QUICK else (1, 2, 4, 8, 16)
    x, y = synthetic_mnist(n_train + 2000)
    train, test = (x[:n_train], y[:n_train]), (x[n_train:], y[n_train:])
    specs = mlp.param_specs(hidden=hidden)
    init_fn = lambda rng: init_params(specs, rng)

    out = {}
    perf = {}
    for s in svals:
        for name, (sampler, chains) in {
            f"async_s{s}": (
                core.async_sghmc(step_size=EPS, friction=FRIC, num_workers=K, sync_every=s), 1),
            f"ec_s{s}": (
                core.ec_sghmc(step_size=EPS, friction=FRIC, center_friction=FRIC, alpha=1.0,
                              sync_every=s, noise_convention="eq4", center_noise_in_p=False), K),
        }.items():
            # dt includes diagnostics collection (2 small jitted dispatches
            # per post-burn-in step, <1% of these multi-ms model steps) —
            # the cost column is a sweep-internal comparator, not a roofline
            t0 = time.time()
            _, curve, info = run_sampling(
                mlp.apply, mlp.nll_fn, init_fn, sampler, chains, train, test,
                n_data=n_train, steps=steps, eval_every=steps,
                collect_diagnostics=True,
            )
            dt = time.time() - t0
            out[name] = curve[-1]["nll"]
            perf[name] = {
                "steps_per_s": info["steps_per_s"],
                "final_nll": curve[-1]["nll"],
                "probe_ess_chain_mean": info["probe_ess_chain_mean"],
            }
            emit(f"staleness/{name}_steps_per_s", 1e6 / max(info["steps_per_s"], 1e-9),
                 f"{info['steps_per_s']:.1f}")
            emit(f"staleness/{name}_final_nll", 1e6 * dt / steps, f"{curve[-1]['nll']:.4f}")
            emit(f"staleness/{name}_probe_ess_chain_mean", 1e6 * dt / steps,
                 f"{info['probe_ess_chain_mean']:.0f}")
            emit(f"staleness/{name}_split_rhat", 1e6 * dt / steps,
                 f"{info['probe_split_rhat']:.3f}")
            if chains > 1:
                emit(f"staleness/{name}_chain_spread", 1e6 * dt / steps,
                     f"{info['chain_spread']:.5f}")
    # degradation from s=1 to s_max per scheme
    smax = svals[-1]
    d_async = out[f"async_s{smax}"] - out["async_s1"]
    d_ec = out[f"ec_s{smax}"] - out["ec_s1"]
    emit("staleness/async_degradation", 0, f"{d_async:.4f}")
    emit("staleness/ec_degradation", 0, f"{d_ec:.4f}")
    emit("staleness/claim_ec_buffers_staleness", 0, "CONFIRMED" if d_ec <= d_async + 1e-4 else "REFUTED")
    record("perf", {"cells": perf,
                    "config": {"steps": steps, "chains": K, "svals": list(svals),
                               "hidden": hidden, "quick": QUICK}})
    return out


if __name__ == "__main__":
    run()
