"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local+global alternating, logit softcaps. [arXiv:2408.00118]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    vocab_size=256000,
    d_model=4608,
    num_layers=46,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    pattern=(LayerKind("attn", window=4096), LayerKind("attn")),  # alternating
    norm_scale_offset=1.0,
    sandwich_norm=True,
    act="gelu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=144.0**-0.5,  # query_pre_attn_scalar = d_model / num_heads
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale="sqrt_d",
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=4,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    pattern=(LayerKind("attn", window=8), LayerKind("attn")),
    query_scale=16.0**-0.5,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
