"""Device-resident run executor (scan-fused sampling drivers)."""
from .executor import ChainExecutor, ChunkSnapshot, RunResult, rollout

__all__ = ["ChainExecutor", "ChunkSnapshot", "RunResult", "rollout"]
