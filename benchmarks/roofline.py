"""Roofline analysis (deliverable g): the three bound-terms per
(arch x shape x mesh) cell.

    compute    = FLOPs_per_device / peak_FLOP/s              [s]
    memory     = HBM_bytes_per_device / HBM_bw               [s]
    collective = collective_bytes_per_device / ICI_bw        [s]

TERMS COME FROM THE ANALYTIC MODEL (src/repro/roofline/analytic.py), with
the compiled dry-run artifacts as schedule evidence + cross-checks.  Reason
(verified empirically, see EXPERIMENTS.md §Roofline): XLA cost_analysis()
counts a scanned loop body ONCE, so its totals are structurally wrong for
any scanned-layers program; its bytes-accessed assumes zero fusion.
"""
from __future__ import annotations

import json
from pathlib import Path

HW = {"peak_flops_bf16": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}
ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def rows_analytic():
    import sys

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    from repro import configs
    from repro.roofline import analyze_cell

    rows = []
    for arch, cell in configs.all_cells():
        for mp in (False, True):
            r = analyze_cell(arch, cell.name, multi_pod=mp)
            rows.append(r)
    return rows


def hlo_evidence(arch, shape, multi_pod, tag=""):
    mesh_tag = "pod2" if multi_pod else "pod1"
    suffix = f"__{tag}" if tag else ""
    p = ART / f"{arch}__{shape}__{mesh_tag}{suffix}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return {
        "compiled": True,
        "colls": {k: v["count"] for k, v in rec.get("collectives", {}).items()},
        "args_gb": rec.get("memory_analysis", {}).get("argument_size_in_bytes", 0) / 1e9,
    }


def markdown_table(rows) -> str:
    hdr = (
        "| arch | shape | mesh | K | compute (s) | memory (s) | collective (s) "
        "| dominant | roofline frac | useful ratio | compiled | args/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        ev = hlo_evidence(r["arch"], r["shape"], r["mesh"] == "2x16x16")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chains']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} | {r['collective_s']:.4g} "
            f"| **{r['dominant']}** | {r['roofline_frac']:.2f} | {r['useful_ratio']:.2f} "
            f"| {'yes' if ev else 'NO'} | {ev['args_gb']:.2f}GB |"
            if ev
            else f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chains']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} | {r['collective_s']:.4g} "
            f"| **{r['dominant']}** | {r['roofline_frac']:.2f} | {r['useful_ratio']:.2f} | NO | - |"
        )
    return hdr + "\n".join(lines) + "\n"


def run():
    from common import emit

    rows = rows_analytic()
    for r in rows:
        emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            1e6 * max(r["compute_s"], r["memory_s"], r["collective_s"]),
            f"dom={r['dominant']};frac={r['roofline_frac']:.2f};useful={r['useful_ratio']:.2f}",
        )
    out = Path(__file__).resolve().parent / "artifacts" / "roofline.md"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(markdown_table(rows))
    emit("roofline/table_written", 0, str(out))
    return rows


if __name__ == "__main__":
    print(markdown_table(rows_analytic()))
