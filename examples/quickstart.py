"""Quickstart: elastically-coupled SG-MCMC on a 2-D Gaussian (paper Fig. 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core

MU = jnp.array([2.0, -1.0])
K, STEPS = 4, 800


def grad_U(theta):  # U = ||theta - mu||^2 / 2  =>  posterior N(mu, I)
    return theta - MU


def main():
    # K chains, coupled through a center variable, syncing every 4 steps
    sampler = core.ec_sghmc(step_size=5e-2, alpha=1.0, sync_every=4,
                            noise_convention="eq4", center_noise_in_p=False)
    params = jnp.zeros((K, 2))
    state = sampler.init(params)

    def body(carry, key):
        p, st = carry
        updates, st = sampler.update(grad_U(p), st, params=p, rng=key)
        p = core.apply_updates(p, updates)
        return (p, st), p

    keys = jax.random.split(jax.random.PRNGKey(0), STEPS)
    (_, state), traj = jax.lax.scan(body, (params, state), keys)
    samples = np.asarray(traj[STEPS // 4 :]).reshape(-1, 2)

    print(f"target  mean: {np.asarray(MU)}          target  var: [1. 1.]")
    print(f"sampled mean: {samples.mean(0).round(3)}   sampled var: {samples.var(0).round(3)}")
    print(f"center ended at: {np.asarray(state.center).round(3)}")

    # ASCII density plot
    H, xe, ye = np.histogram2d(samples[:, 0], samples[:, 1], bins=(24, 12),
                               range=[[-1, 5], [-4, 2]])
    shades = " .:-=+*#%@"
    print("\nsample density (x: theta_0, y: theta_1):")
    for row in (H / max(H.max(), 1) * (len(shades) - 1)).astype(int).T[::-1]:
        print("  " + "".join(shades[v] for v in row))


if __name__ == "__main__":
    main()
