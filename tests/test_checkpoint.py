"""Fault-tolerance tests: atomic checkpointing, corrupted-checkpoint
fallback, auto-resume, simulated preemption, elastic chain rescaling."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.train import checkpoint as ck
from repro.train.loop import LoopConfig, Preempted, run


def _tiny_setup(num_chains=2):
    params = jax.random.normal(jax.random.PRNGKey(0), (num_chains, 8))
    sampler = core.ec_sghmc(step_size=1e-2, alpha=1.0, sync_every=2)
    state = sampler.init(params)
    return params, sampler, state


class TestCheckpointRoundtrip:
    def test_save_restore_exact(self, tmp_path):
        params, sampler, state = _tiny_setup()
        ck.save(tmp_path, 7, params, state)
        got = ck.restore(tmp_path, params, state)
        assert got is not None
        step, p2, s2, _ = got
        assert step == 7
        np.testing.assert_array_equal(np.asarray(p2), np.asarray(params))
        np.testing.assert_array_equal(np.asarray(s2.center), np.asarray(state.center))

    def test_atomic_no_tmp_left(self, tmp_path):
        params, sampler, state = _tiny_setup()
        ck.save(tmp_path, 1, params, state)
        assert not any(p.name.startswith("tmp.") for p in tmp_path.iterdir())

    def test_corrupted_falls_back(self, tmp_path):
        params, sampler, state = _tiny_setup()
        ck.save(tmp_path, 1, params, state)
        ck.save(tmp_path, 2, params, state)
        # corrupt the newest checkpoint
        newest = sorted(tmp_path.glob("step_*"))[-1]
        (newest / "arrays.npz").write_bytes(b"garbage")
        got = ck.restore(tmp_path, params, state)
        assert got is not None and got[0] == 1

    def test_manifest_shape_mismatch_detected(self, tmp_path):
        params, sampler, state = _tiny_setup()
        path = ck.save(tmp_path, 3, params, state)
        m = json.loads((path / "manifest.json").read_text())
        k = next(iter(m["shapes"]))
        m["shapes"][k] = [999]
        (path / "manifest.json").write_text(json.dumps(m))
        assert ck.restore(tmp_path, params, state) is None

    def test_prune_keeps_latest(self, tmp_path):
        params, sampler, state = _tiny_setup()
        for s in range(1, 6):
            ck.save(tmp_path, s, params, state)
        ck.prune(tmp_path, keep=2)
        names = sorted(p.name for p in tmp_path.glob("step_*"))
        assert names == ["step_00000004", "step_00000005"]


class TestElasticRescale:
    def test_restore_with_different_chain_count(self, tmp_path):
        params, sampler, state = _tiny_setup(num_chains=2)
        ck.save(tmp_path, 5, params, state)
        # new job wants K=4: exact restore impossible -> resample from center
        p4 = jnp.zeros((4, 8))
        s4 = core.ec_sghmc(step_size=1e-2, alpha=1.0).init(p4)
        got = ck.restore_elastic(tmp_path, p4, s4, num_chains=4, alpha=1.0)
        assert got is not None
        step, new_p, new_s, extra = got
        assert step == 5 and new_p.shape == (4, 8)
        assert extra.get("elastic_resample")
        # chains scatter around the restored center
        np.testing.assert_allclose(
            np.asarray(new_s.center), np.asarray(state.center), atol=1e-6
        )

    def test_dead_chain_recovery_math(self):
        """resample_chain_from_center gives the stationary conditional."""
        params, sampler, state = _tiny_setup(num_chains=2)
        new_p, new_s = core.resample_chain_from_center(
            state, alpha=2.0, rng=jax.random.PRNGKey(1), num_chains=8
        )
        assert new_p.shape == (8, 8)
        assert np.all(np.isfinite(np.asarray(new_p)))


class TestLoopResume:
    def _run(self, tmp_path, steps, preempt_at=None):
        params, sampler, state = _tiny_setup()
        grad = lambda t: t - 1.0  # U = ||theta - 1||^2/2

        def train_step(params, state, batch, rng):
            g = grad(params)
            upd, state = sampler.update(g, state, params, rng)
            return core.apply_updates(params, upd), state, {"nll_per_token": jnp.mean(g**2)}

        cfg = LoopConfig(num_steps=steps, ckpt_dir=str(tmp_path), ckpt_every=5,
                         log_every=100, preempt_at=preempt_at)
        return run(train_step, params, state, lambda t: None, cfg, num_chains=2)

    def test_preempt_then_resume(self, tmp_path):
        with pytest.raises(Preempted):
            self._run(tmp_path, steps=20, preempt_at=10)
        # checkpoints exist up to step 10
        assert (tmp_path / "step_00000010").exists()
        # resume completes the run and picks up from step 10
        params, state, _ = self._run(tmp_path, steps=20)
        assert int(state.step) == 20

    def test_resume_is_noop_when_done(self, tmp_path):
        self._run(tmp_path, steps=10)
        params, state, _ = self._run(tmp_path, steps=10)
        assert int(state.step) == 10
