"""Schema validation for exported ``trace.json`` and run manifests.

Used by tests and by ``scripts/ci.sh`` (``python -m repro.obs.validate
trace.json --require serve``) to assert a traced run actually produced a
loadable Perfetto timeline with the span set the acceptance criteria
name.  Hand-rolled checks, not jsonschema — no new deps.
"""
from __future__ import annotations

import json

from repro.obs.sinks import MANIFEST_KEYS

# required event-name sets per profile; "a|b" means any-of
REQUIRED = {
    "serve": (
        "serve.decode_tick",
        "serve.admit",
        "refresh.micro_chunk",
        "refresh.flip|refresh.flip_deferred",
    ),
    "serve_ec": (
        "serve.decode_tick",
        "refresh.micro_chunk",
        "refresh.flip|refresh.flip_deferred",
        "sampler.sync_collective",
    ),
    "executor": ("executor.chunk",),
}

_PHASES = {"X", "i", "M"}


def validate_manifest(manifest) -> list:
    errs = []
    if not isinstance(manifest, dict):
        return [f"manifest is {type(manifest).__name__}, not dict"]
    for key in MANIFEST_KEYS:
        if key not in manifest:
            errs.append(f"manifest missing key {key!r}")
    if not isinstance(manifest.get("device_count", 0), int):
        errs.append("manifest device_count not int")
    return errs


def validate_trace(obj, required: tuple = ()) -> list:
    """Return a list of schema violations (empty list == valid).

    ``obj`` is a parsed trace dict or a path to one.  ``required`` names
    must each appear among event names; a name containing ``|`` is
    satisfied by any alternative.
    """
    if isinstance(obj, (str, bytes)) or hasattr(obj, "read_text"):
        with open(obj) as f:
            obj = json.load(f)
    errs = []
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    names = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event {i} not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errs.append(f"event {i} ({ev.get('name')!r}): bad ph {ph!r}")
        if not isinstance(ev.get("name"), str):
            errs.append(f"event {i}: missing name")
        if "pid" not in ev or "tid" not in ev:
            errs.append(f"event {i} ({ev.get('name')!r}): missing pid/tid")
        if ph == "X":
            if not isinstance(ev.get("ts"), (int, float)):
                errs.append(f"event {i} ({ev.get('name')!r}): X without numeric ts")
            if not isinstance(ev.get("dur"), (int, float)) or ev.get("dur", -1) < 0:
                errs.append(f"event {i} ({ev.get('name')!r}): X without non-negative dur")
        if ph == "i" and not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"event {i} ({ev.get('name')!r}): instant without numeric ts")
        if ph != "M":
            names.add(ev.get("name"))
    for req in required:
        if not any(alt in names for alt in req.split("|")):
            errs.append(f"required event {req!r} absent (have {sorted(n for n in names if n)})")
    other = obj.get("otherData", {})
    if "manifest" in other:
        errs.extend(validate_manifest(other["manifest"]))
    else:
        errs.append("otherData.manifest missing")
    return errs


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description="validate a repro trace.json")
    ap.add_argument("path")
    ap.add_argument("--require", default=None,
                    help="profile name (%s) or comma-list of event names"
                    % "/".join(sorted(REQUIRED)))
    ns = ap.parse_args(argv)
    required: tuple = ()
    if ns.require:
        required = REQUIRED.get(ns.require) or tuple(ns.require.split(","))
    errs = validate_trace(ns.path, required=required)
    if errs:
        for e in errs:
            print(f"INVALID: {e}")
        return 1
    print(f"OK: {ns.path} valid" + (f" (profile {ns.require})" if ns.require else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
