"""The training loop: sampler-driven posterior sampling with fault
tolerance (atomic checkpoints, auto-resume, simulated preemption) and
elastic chain scaling.

The step loop itself is DEVICE-RESIDENT: ``repro.run.ChainExecutor``
compiles chunks of steps as one donated ``lax.scan`` program, and the host
only regains control at chunk boundaries.  The chunk length is the GCD of
every host-event cadence (checkpoint, logging, simulated preemption), so
every event the per-step loop used to honor still lands exactly on a
boundary — auto-resume semantics are unchanged while per-step dispatch
overhead is gone (DESIGN.md §3).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax

from repro.obs import get_logger
from repro.run import ChainExecutor
from . import checkpoint as ckpt_lib

log = get_logger("loop")


@dataclass
class LoopConfig:
    num_steps: int = 200
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    preempt_at: Optional[int] = None  # simulate a kill after this step
    seed: int = 0
    max_chunk: int = 1024  # upper bound on steps per device visit


class Preempted(RuntimeError):
    pass


def _chunk_steps(cfg: LoopConfig) -> int:
    """Largest chunk whose boundaries hit every host-event step exactly."""
    g = 0
    if cfg.ckpt_dir:
        g = math.gcd(g, cfg.ckpt_every)
    if cfg.log_every:
        g = math.gcd(g, cfg.log_every)
    if cfg.preempt_at is not None:
        g = math.gcd(g, cfg.preempt_at)
    if g == 0:
        # no host events at all: chunking is a pure perf knob (the executor
        # handles a partial final chunk), so just cap it
        return max(min(cfg.num_steps or cfg.max_chunk, cfg.max_chunk), 1)
    if g <= cfg.max_chunk:
        return g
    # the bound must not break divisibility (a capped non-divisor would
    # skip events entirely): largest divisor of g within the bound
    return max(d for d in range(1, cfg.max_chunk + 1) if g % d == 0)


def run(
    train_step: Callable,  # (params, state, batch, rng) -> (params, state, metrics)
    init_params,
    init_state,
    batch_fn: Callable,  # (step) -> batch
    cfg: LoopConfig,
    num_chains: int = 1,
    alpha: float = 1.0,
    sampler=None,  # optional: its jit-safe stats hook is logged at boundaries
):
    """Returns (params, state, history).  Auto-resumes from cfg.ckpt_dir."""
    params, state = init_params, init_state
    start = 0
    if cfg.ckpt_dir:
        got = ckpt_lib.restore_elastic(
            cfg.ckpt_dir, params, state, num_chains=num_chains, alpha=alpha, seed=cfg.seed
        )
        if got is not None:
            start, params, state, extra = got
            log.info(f"resumed from step {start}" + (" (elastic)" if extra.get("elastic_resample") else ""))

    executor = ChainExecutor(
        step_fn=train_step,
        batch_fn=batch_fn,
        key_mode="fold",
        chunk_steps=_chunk_steps(cfg),
        donate=True,
    )
    stats_fn = jax.jit(sampler.stats) if sampler is not None and sampler.stats else None

    key = jax.random.key(cfg.seed)
    history = []
    t0 = time.time()

    def on_chunk(step_end, params, state, outs):
        metrics = jax.tree.map(lambda a: a[-1], outs["metrics"])
        if cfg.ckpt_dir and step_end % cfg.ckpt_every == 0:
            ckpt_lib.save(cfg.ckpt_dir, step_end, params, state)
            ckpt_lib.prune(cfg.ckpt_dir, cfg.keep_ckpts)
        if cfg.log_every and step_end % cfg.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            if stats_fn is not None:
                m.update({k: float(v) for k, v in stats_fn(state, params).items() if k != "step"})
            m["step"] = step_end
            m["wall_s"] = round(time.time() - t0, 2)
            history.append(m)
            log.info(f"step {step_end}: " + " ".join(f"{k}={v:.5g}" for k, v in m.items() if k != "step"))
        if cfg.preempt_at is not None and step_end == cfg.preempt_at:
            raise Preempted(f"simulated preemption at step {step_end}")

    if start < cfg.num_steps:
        result = executor.run(
            params, state, num_steps=cfg.num_steps - start, key=key,
            start_step=start, on_chunk=on_chunk,
        )
        params, state = result.params, result.state
    return params, state, history
