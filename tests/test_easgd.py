"""Paper §5: the EASGD connection.

The headline check: the paper's Eq. (9) optimizer (ec_msgd) is the exact
deterministic limit of EC-SGHMC under the variable substitution
v = eps*p, h = eps*r, xi = eps*V = eps*C (M = I).  Equivalently:
ec_msgd(step=eps^2_6, xi=eps_6*V) ≡ ec_sghmc(eps_6, V, C=V, temp=0, s=1).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from util import gaussian_grad, run_sampler


class TestEq9Equivalence:
    def test_ec_msgd_is_deterministic_limit_of_ec_sghmc(self):
        eps, V, alpha, K = 0.05, 0.8, 1.3, 4
        p0 = jax.random.normal(jax.random.PRNGKey(0), (K, 3))
        grad = gaussian_grad(jnp.array([1.0, -2.0, 0.5]))

        ec = core.ec_sghmc(
            step_size=eps, alpha=alpha, friction=V, center_friction=V,
            mass=1.0, sync_every=1, temperature=0.0,
        )
        # Eq. 9 with eps_9 = eps^2 (gradient term), alpha_9 scaled so that
        # eps_9*alpha_9 = eps^2*alpha, and xi = eps*V:
        msgd = core.ec_msgd(step_size=eps**2, alpha=alpha, xi=eps * V)

        t_ec = run_sampler(ec, p0, grad, 150)
        t_m = run_sampler(msgd, p0, grad, 150)
        np.testing.assert_allclose(t_ec, t_m, rtol=1e-5, atol=1e-6)

    def test_eq9_vs_eq10_both_converge(self):
        """Paper: 'an initial test suggests the former perform at least as
        good as EAMSGD' — both must drive U to ~0 on a quadratic."""
        grad = gaussian_grad(jnp.zeros(4))
        p0 = jax.random.normal(jax.random.PRNGKey(1), (4, 4)) * 3
        final = {}
        for name, opt in [
            ("eq9", core.ec_msgd(step_size=1e-3, alpha=1.0, xi=0.05)),
            ("eq10", core.eamsgd(step_size=1e-3 / 0.05, alpha=1e-3, xi=0.05)),
        ]:
            traj = run_sampler(opt, p0, grad, 4000)
            final[name] = float(np.abs(traj[-1]).mean())
        assert final["eq9"] < 0.15
        assert final["eq10"] < 0.35

    def test_easgd_center_tracks_chains(self):
        opt = core.easgd(step_size=5e-2, alpha=0.5)
        p0 = jax.random.normal(jax.random.PRNGKey(2), (3, 2)) + 4.0
        grad = gaussian_grad(jnp.zeros(2))
        params, st = p0, opt.init(p0)
        for i in range(800):
            upd, st = opt.update(grad(params), st, params=params)
            params = core.apply_updates(params, upd)
        assert float(jnp.abs(params).max()) < 0.3
        assert float(jnp.abs(st.center).max()) < 0.3

    def test_eamsgd_sync_period_drops_coupling(self):
        """Zhang et al.: coupling terms only apply every s steps."""
        opt = core.eamsgd(step_size=1e-2, alpha=1.0, xi=0.0, sync_every=3)
        p0 = jnp.ones((2, 2))
        st = opt.init(p0)
        # zero grads: with xi=0 the only force is the coupling
        zeros = jnp.zeros_like(p0)
        params = p0
        moved = []
        for t in range(6):
            upd, st = opt.update(zeros, st, params=params)
            moved.append(float(jnp.abs(upd).max()) > 0)
            params = core.apply_updates(params, upd)
        # center == chain mean here, so coupling force is chain-dependent;
        # all chains equal -> no force ever. Use asymmetric start instead.
        p0 = jnp.array([[1.0, 1.0], [3.0, 3.0]])
        st = opt.init(p0)
        params = p0
        moved = []
        for t in range(6):
            upd, st = opt.update(jnp.zeros_like(p0), st, params=params)
            moved.append(float(jnp.abs(upd).max()) > 1e-12)
            params = core.apply_updates(params, upd)
        assert moved == [(t % 3 == 0) for t in range(6)]
