"""Host-side event tracer: a fixed-capacity ring buffer of spans/instants
with Chrome/Perfetto ``trace.json`` export.

The paper's claim is a wall-clock one, and the interesting failure modes
live in *timing* — when a decode tick stalled, when a refresh micro-chunk
ran, when a snapshot flip deferred.  This tracer makes that timeline
visible without ever being allowed to change it:

* **Zero-cost when off.**  The module-level tracer defaults to a disabled
  singleton.  ``span()`` on a disabled tracer returns a shared no-op
  context manager WITHOUT reading the clock, and ``instant()`` returns
  after one attribute check — no clock reads, no allocation beyond the
  argument dict, no device interaction ever (``tests/test_obs.py`` pins
  zero ``_now()`` calls across a full engine run with tracing off, plus
  bit-identical tokens and the decode compile-count pin).
* **Host-only recording.**  Nothing here may be called from inside a
  traced/jitted function, and nothing here fetches a device value: span
  timestamps are ``time.perf_counter_ns`` around host-side *dispatch*, so
  an async-dispatched chunk's span measures enqueue, not device compute.
  Events that happen inside compiled programs (the s-periodic sync
  collective) are host-RECONSTRUCTED at chunk boundaries from static
  cadence metadata (DESIGN.md §11).
* **Ring buffer, not a log.**  Events land in a preallocated list at a
  monotonically increasing cursor (mod capacity); old events are
  overwritten, never reallocated, and ``dropped`` counts the overwrites.
  Single write per event under the GIL — no locks, safe for the
  cooperative single-host-thread design (the engine, refresher and
  executor all run on the caller's thread).

Export is the Chrome trace-event JSON flavour Perfetto loads directly:
complete events (``ph: "X"``) for spans, thread-scoped instants
(``ph: "i"``), one synthetic tid per category, and the run manifest in
``otherData``.
"""
from __future__ import annotations

import json
import time
from typing import Any

# module-level clock indirection: tests monkeypatch this to prove the
# disabled tracer never reads the clock
_now = time.perf_counter_ns

# stable synthetic thread ids per category — one Perfetto track each
_TIDS = {
    "serve": 0,
    "refresh": 1,
    "executor": 2,
    "alloc": 3,
    "pool": 4,
    "sampler": 5,
}


class _NoopSpan:
    """Shared do-nothing context manager handed out by a disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("_tr", "name", "cat", "args", "_t0")

    def __init__(self, tr: "Tracer", name: str, cat: str, args: dict):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0

    def __enter__(self):
        self._t0 = _now()
        return self

    def __exit__(self, *exc):
        self._tr._record(("X", self.name, self.cat, self._t0, _now() - self._t0, self.args))
        return False


class Tracer:
    """Fixed-capacity span/instant recorder.  ``enabled`` is checked first
    on every public call; a disabled tracer does no work."""

    __slots__ = ("enabled", "capacity", "_buf", "_written", "_t0")

    def __init__(self, capacity: int = 1 << 16, enabled: bool = True):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._buf: list = [None] * self.capacity
        self._written = 0
        self._t0 = _now() if enabled else 0

    # -- recording ----------------------------------------------------------

    def _record(self, ev: tuple) -> None:
        # single GIL-atomic list-index write at the monotone cursor; the
        # ring wraps by overwriting, never by reallocating
        self._buf[self._written % self.capacity] = ev
        self._written += 1

    def span(self, name: str, cat: str = "repro", **args):
        """Context manager recording one complete ('X') event on exit.
        On a disabled tracer this returns a shared no-op without touching
        the clock."""
        if not self.enabled:
            return _NOOP
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        """Record a zero-duration ('i') event."""
        if not self.enabled:
            return
        self._record(("i", name, cat, _now(), 0, args))

    # -- introspection ------------------------------------------------------

    @property
    def dropped(self) -> int:
        """Events overwritten by ring wraparound."""
        return max(0, self._written - self.capacity)

    def __len__(self) -> int:
        return min(self._written, self.capacity)

    def events(self) -> list:
        """Recorded events, oldest first (post-wraparound order is the
        cursor-rotated ring)."""
        n = self._written
        if n <= self.capacity:
            return [e for e in self._buf[:n]]
        cur = n % self.capacity
        return self._buf[cur:] + self._buf[:cur]

    def names(self) -> set:
        return {e[1] for e in self.events()}

    # -- export -------------------------------------------------------------

    def to_chrome(self, manifest: dict | None = None) -> dict:
        """Chrome trace-event JSON object (the format Perfetto loads).
        Timestamps are microseconds relative to tracer construction."""
        events: list[dict] = [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": "repro"}},
        ]
        used = sorted({e[2] for e in self.events()}, key=lambda c: _TIDS.get(c, 99))
        for cat in used:
            events.append({
                "ph": "M", "name": "thread_name", "pid": 0,
                "tid": _TIDS.get(cat, 99), "args": {"name": cat},
            })
        for ph, name, cat, ts, dur, args in self.events():
            ev: dict[str, Any] = {
                "name": name,
                "cat": cat,
                "ph": ph,
                "ts": (ts - self._t0) / 1e3,
                "pid": 0,
                "tid": _TIDS.get(cat, 99),
            }
            if ph == "X":
                ev["dur"] = dur / 1e3
            else:
                ev["s"] = "t"
            if args:
                ev["args"] = args
            events.append(ev)
        if manifest is None:
            from repro.obs.sinks import run_manifest

            manifest = run_manifest()
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"manifest": manifest, "dropped_events": self.dropped},
        }

    def export(self, path, manifest: dict | None = None) -> dict:
        """Write ``trace.json`` to ``path``; returns the exported object."""
        obj = self.to_chrome(manifest)
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj


# the module-level tracer every instrumentation site reads through get():
# disabled by default, so an un-configured run pays one attribute check
# per potential event and nothing else
NULL = Tracer(capacity=1, enabled=False)
_TRACER: Tracer = NULL


def get() -> Tracer:
    """The active tracer (the disabled NULL singleton unless enabled)."""
    return _TRACER


def enable(capacity: int = 1 << 16) -> Tracer:
    """Install and return a fresh enabled tracer."""
    global _TRACER
    _TRACER = Tracer(capacity=capacity, enabled=True)
    return _TRACER


def disable() -> None:
    """Restore the disabled NULL tracer."""
    global _TRACER
    _TRACER = NULL


def install(tracer: Tracer) -> Tracer:
    """Install a specific tracer object — for save/restore around scoped
    measurements that toggle tracing themselves (e.g. the obs-overhead
    bench must hand back whatever tracer ``--trace`` installed)."""
    global _TRACER
    _TRACER = tracer
    return tracer
