"""Shared driver: posterior sampling over classification models (the
paper's Fig. 2 experiment machinery).

Metric identical to the paper: negative log likelihood of the *posterior
predictive* on held-out data, over sampling steps.  For parallel samplers
the predictive averages over all K chains (Bayesian model averaging) —
that, not single-chain quality, is what a sampler earns its keep for.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import math

from repro import core
from repro import diagnostics as diag
from repro.data.pipeline import ShardedLoader


def sgd_map(lr: float, beta: float = 0.9):
    """Map SGD-with-momentum (lr, beta) to SGHMC (step_size, friction):
    eps = sqrt(lr (1-beta)), V = (1-beta)/eps.  Equilibrium step eps/V = lr
    and momentum decay per step = eps*V = 1-beta — the scale-adapted SGHMC
    parameterization that makes burn-in practical."""
    eps = math.sqrt(lr * (1.0 - beta))
    return eps, (1.0 - beta) / eps


def run_sampling(
    apply_fn,  # (params, x) -> logits
    nll_fn,  # (params, batch) -> (sum_nll, count)
    init_params_fn,  # (rng) -> params (single chain)
    sampler,
    num_chains: int,
    train,  # (x, y)
    test,  # (x, y)
    *,
    n_data: int,
    steps: int,
    batch_size: int = 100,
    eval_every: int = 20,
    weight_decay: float = 1e-5,
    burnin_frac: float = 0.25,
    seed: int = 0,
    collect_diagnostics: bool = False,
):
    """When ``collect_diagnostics`` is set, additionally returns a dict of
    shared convergence diagnostics (repro.diagnostics): post-burn-in probe
    ESS / split-R̂, streaming parameter moments, cross-chain spread, and the
    sampler's own stats hook — the machinery benchmarks previously
    hand-rolled per script."""
    prior = core.gaussian_prior(weight_decay)
    pot = core.make_potential(nll_fn, n_data=n_data, prior=prior)
    params1 = init_params_fn(jax.random.PRNGKey(seed))
    stacked = num_chains > 1 or sampler.grad_targets is not None
    if num_chains > 1:
        params = core.tree_broadcast_axis0(params1, num_chains)
    else:
        params = params1
    state = sampler.init(params)
    loader = ShardedLoader(train[0], train[1], batch_size, num_chains, seed)
    xt, yt = jnp.asarray(test[0]), jnp.asarray(test[1])

    grad_pot = jax.vmap(pot.grad) if num_chains > 1 else pot.grad

    @jax.jit
    def step_fn(params, state, batch, key):
        targets = sampler.grad_targets(state, params) if sampler.grad_targets else params
        if sampler.grad_targets is not None and num_chains == 1:
            # async sampler: targets carry a worker axis; batch needs one too
            g = jax.vmap(pot.grad)(targets, batch)
        else:
            g = grad_pot(targets, batch)
        upd, state = sampler.update(g, state, params=params, rng=key)
        return core.apply_updates(params, upd), state

    @jax.jit
    def predictive_nll(prob_sum, n_models):
        probs = prob_sum / n_models
        logp = jnp.log(jnp.maximum(probs, 1e-12))
        gold = jnp.take_along_axis(logp, yt[:, None], axis=-1)[:, 0]
        return -jnp.mean(gold)

    @jax.jit
    def chain_probs(params):
        f = lambda p: jax.nn.softmax(apply_fn(p, xt).astype(jnp.float32), -1)
        if num_chains > 1:
            return jnp.sum(jax.vmap(f)(params), axis=0)
        return f(params)

    @jax.jit
    def probe_fn(params):
        """First few coordinates of the first leaf, per chain — the scalar
        series the ESS / R̂ estimators run on."""
        leaf = jax.tree.leaves(params)[0].astype(jnp.float32)
        k = leaf.shape[0] if num_chains > 1 else 1
        return leaf.reshape(k, -1)[:, :4]

    wf_add = jax.jit(diag.welford_add)

    key = jax.random.PRNGKey(seed + 1)
    curve = []
    probes = []
    wf = None
    prob_sum = jnp.zeros((xt.shape[0], 10), jnp.float32)
    n_acc = 0
    burnin = int(steps * burnin_frac)
    for t in range(steps):
        batch = loader.batch(t)
        if sampler.grad_targets is not None and num_chains == 1:
            # async needs K worker batches
            k_workers = jax.tree.leaves(state.snapshots)[0].shape[0]
            wl = ShardedLoader(train[0], train[1], batch_size, k_workers, seed)
            batch = wl.batch(t)
        key, sub = jax.random.split(key)
        params, state = step_fn(params, state, batch, sub)
        if collect_diagnostics and t >= burnin:
            probes.append(probe_fn(params))
            wf = wf_add(wf, params) if wf is not None else wf_add(diag.welford_init(params), params)
        if (t + 1) % eval_every == 0:
            if t >= burnin:  # accumulate posterior-predictive after burn-in
                prob_sum = prob_sum + chain_probs(params)
                n_acc += num_chains
            cur = chain_probs(params)
            nll_now = float(predictive_nll(cur, num_chains))
            nll_avg = float(predictive_nll(prob_sum, max(n_acc, 1))) if n_acc else nll_now
            curve.append({"step": t + 1, "nll": nll_now, "nll_bma": nll_avg})
    if not collect_diagnostics:
        return params, curve

    chains = np.moveaxis(np.asarray(jnp.stack(probes)), 1, 0)  # (K, T', 4)
    # element-weighted mean variance (same convention as cross_chain_spread)
    var_leaves = jax.tree.leaves(diag.welford_var(wf))
    param_var = float(
        sum(float(jnp.sum(v)) for v in var_leaves)
        / max(sum(int(v.size) for v in var_leaves), 1)
    )
    info = {
        # pooled assumes independent chains (upper bound under coupling);
        # chain_mean is the conservative coupled-chain estimate
        "probe_ess": float(np.sum(diag.effective_sample_size_nd(chains))),
        "probe_ess_chain_mean": float(np.sum(diag.coupled_ess_nd(chains))),
        "probe_split_rhat": float(np.max(diag.split_rhat_nd(chains))),
        "param_var": param_var,
        "chain_spread": float(diag.cross_chain_spread(params)) if num_chains > 1 else 0.0,
    }
    if sampler.stats is not None:
        info["sampler_stats"] = {
            k: float(v) for k, v in sampler.stats(state, params).items()
        }
    return params, curve, info
