"""Fused EC-SGHMC update kernel (the paper-technique hot spot).

One VMEM pass per parameter block computes Eq. 6's chain update:

    theta' = theta + eps*M^-1*p                       (old momentum)
    p'     = (1 - eps*V*M^-1)*p - eps*g
             - eps*alpha*(theta - c_tilde) + sigma_p * N(0,1)

HBM traffic: 4 reads (theta, p, g, c̃) + 2 writes (theta', p') + noise bits.
XLA's unfused form re-reads theta for the coupling term, materializes the
Gaussian noise tensor in HBM, and round-trips p twice — ~9 tensor streams
vs. our 6.5 (the roofline win for the memory-bound sampler sweep).

Gaussian noise is derived in-register from uint32 bits via Box-Muller.
On real TPU the bits come from pltpu.prng_random_bits (no HBM traffic at
all); the CPU-interpret validation path takes bits as an input so the
pure-jnp oracle sees identical randomness.  bf16 parameter stores use
STOCHASTIC ROUNDING (bits reused) — plain round-to-nearest bf16 MCMC biases
the stationary distribution at 1e-5-scale step sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 1024  # 8 sublanes x 128 lanes
BLOCK_ROWS = 8  # rows of LANES per grid step


def _bits_to_unit(bits):
    """uint32 -> uniform (0, 1) f32 using the top 24 bits."""
    return (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24)) + (0.5 / (1 << 24))


def _box_muller(bits1, bits2):
    u1 = _bits_to_unit(bits1)
    u2 = _bits_to_unit(bits2)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos((2.0 * jnp.pi) * u2)


def _stochastic_round_bf16(x_f32, bits):
    """f32 -> bf16 with probability proportional to proximity."""
    xi = jax.lax.bitcast_convert_type(x_f32, jnp.uint32)
    xi = xi + (bits & jnp.uint32(0xFFFF))  # add uniform in [0, 2^16)
    xi = xi & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(xi, jnp.float32).astype(jnp.bfloat16)


def _kernel(
    scal_ref,  # SMEM (5,): eps_minv, decay, eps, coupling, sigma_p
    theta_ref,
    p_ref,
    g_ref,
    c_ref,
    bits1_ref,
    bits2_ref,
    theta_out_ref,
    p_out_ref,
    *,
    stochastic_round: bool,
    onchip_prng: bool,
):
    eps_minv = scal_ref[0]
    decay = scal_ref[1]
    eps = scal_ref[2]
    coupling = scal_ref[3]
    sigma_p = scal_ref[4]

    theta = theta_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    if onchip_prng:  # TPU target: zero-HBM-traffic noise
        pltpu.prng_seed(pl.program_id(0))
        bits1 = pltpu.prng_random_bits(theta.shape).astype(jnp.uint32)
        bits2 = pltpu.prng_random_bits(theta.shape).astype(jnp.uint32)
    else:
        bits1 = bits1_ref[...]
        bits2 = bits2_ref[...]

    noise = _box_muller(bits1, bits2)
    theta_new = theta + eps_minv * p
    p_new = decay * p - eps * g - coupling * (theta - c) + sigma_p * noise

    if stochastic_round and theta_out_ref.dtype == jnp.bfloat16:
        sr_bits = bits1 ^ bits2
        theta_out_ref[...] = _stochastic_round_bf16(theta_new, sr_bits)
        p_out_ref[...] = _stochastic_round_bf16(p_new, jnp.uint32(0x9E3779B9) ^ sr_bits)
    else:
        theta_out_ref[...] = theta_new.astype(theta_out_ref.dtype)
        p_out_ref[...] = p_new.astype(p_out_ref.dtype)


def fused_ec_update_flat(
    theta,
    p,
    g,
    c_tilde,
    bits1,
    bits2,
    *,
    eps: float,
    friction: float,
    mass: float,
    alpha: float,
    sigma_p: float,
    stochastic_round: bool = True,
    onchip_prng: bool = False,
    interpret: bool = True,
):
    """Core entry: all operands (R, LANES)-shaped, R % BLOCK_ROWS == 0.
    Hyperparameters may be traced (they travel via SMEM)."""
    R, L = theta.shape
    assert L == LANES and R % BLOCK_ROWS == 0, (theta.shape,)
    minv = 1.0 / mass
    scalars = jnp.stack(
        [
            jnp.asarray(eps * minv, jnp.float32),
            jnp.asarray(1.0 - eps * friction * minv, jnp.float32),
            jnp.asarray(eps, jnp.float32),
            jnp.asarray(eps * alpha, jnp.float32),
            jnp.asarray(sigma_p, jnp.float32),
        ]
    )
    grid = (R // BLOCK_ROWS,)
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    kernel = functools.partial(
        _kernel, stochastic_round=stochastic_round, onchip_prng=onchip_prng
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            blk(),
            blk(),
            blk(),
            blk(),
            blk(),
            blk(),
        ],
        out_specs=(blk(), blk()),
        out_shape=(
            jax.ShapeDtypeStruct(theta.shape, theta.dtype),
            jax.ShapeDtypeStruct(p.shape, p.dtype),
        ),
        interpret=interpret,
    )(scalars, theta, p, g, c_tilde, bits1, bits2)


def _precond_kernel(
    scal_ref,  # SMEM (4,): eps, ef (= eps*V), coupling (= eps*alpha), sigma_p
    theta_ref,
    p_ref,
    g_ref,
    c_ref,
    minv_ref,  # per-element M^-1 block (frozen diagonal preconditioner)
    bits1_ref,
    bits2_ref,
    theta_out_ref,
    p_out_ref,
    *,
    stochastic_round: bool,
    onchip_prng: bool,
):
    """Preconditioned Eq. 6 chain update — ``_kernel`` with the scalar
    eps*M^-1 / decay pair replaced by a streamed diagonal M^-1:

        theta' = theta + (eps*M^-1) * p
        p'     = (1 - ef*M^-1)*p - eps*g - coupling*(theta - c̃) + sigma_p*n

    Term grouping mirrors ``core.ec_sghmc.p_step`` with an ARRAY ``minv``
    (ef*minv, then 1 - ·), so fused and unfused agree bit-for-bit in f32 —
    pinned by tests/test_fused_equivalence.py.  One extra HBM read stream
    (M^-1) vs. the plain kernel; still beats XLA's ~10 streams."""
    eps = scal_ref[0]
    ef = scal_ref[1]
    coupling = scal_ref[2]
    sigma_p = scal_ref[3]

    theta = theta_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    minv = minv_ref[...].astype(jnp.float32)
    if onchip_prng:  # TPU target: zero-HBM-traffic noise
        pltpu.prng_seed(pl.program_id(0))
        bits1 = pltpu.prng_random_bits(theta.shape).astype(jnp.uint32)
        bits2 = pltpu.prng_random_bits(theta.shape).astype(jnp.uint32)
    else:
        bits1 = bits1_ref[...]
        bits2 = bits2_ref[...]

    noise = _box_muller(bits1, bits2)
    theta_new = theta + eps * minv * p
    p_new = (1.0 - ef * minv) * p - eps * g - coupling * (theta - c) + sigma_p * noise

    if stochastic_round and theta_out_ref.dtype == jnp.bfloat16:
        sr_bits = bits1 ^ bits2
        theta_out_ref[...] = _stochastic_round_bf16(theta_new, sr_bits)
        p_out_ref[...] = _stochastic_round_bf16(p_new, jnp.uint32(0x9E3779B9) ^ sr_bits)
    else:
        theta_out_ref[...] = theta_new.astype(theta_out_ref.dtype)
        p_out_ref[...] = p_new.astype(p_out_ref.dtype)


def fused_precond_ec_update_flat(
    theta,
    p,
    g,
    c_tilde,
    minv,
    bits1,
    bits2,
    *,
    eps: float,
    friction: float,
    alpha: float,
    sigma_p: float,
    stochastic_round: bool = True,
    onchip_prng: bool = False,
    interpret: bool = True,
):
    """Preconditioned entry: operands (R, LANES)-shaped, R % BLOCK_ROWS == 0,
    ``minv`` elementwise (the frozen diagonal M^-1).  Hyperparameters may be
    traced (SMEM); the diagonal streams as a tensor block."""
    R, L = theta.shape
    assert L == LANES and R % BLOCK_ROWS == 0, (theta.shape,)
    assert minv.shape == theta.shape, (minv.shape, theta.shape)
    scalars = jnp.stack(
        [
            jnp.asarray(eps, jnp.float32),
            jnp.asarray(eps * friction, jnp.float32),
            jnp.asarray(eps * alpha, jnp.float32),
            jnp.asarray(sigma_p, jnp.float32),
        ]
    )
    grid = (R // BLOCK_ROWS,)
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    kernel = functools.partial(
        _precond_kernel, stochastic_round=stochastic_round, onchip_prng=onchip_prng
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            blk(),
            blk(),
            blk(),
            blk(),
            blk(),
            blk(),
            blk(),
        ],
        out_specs=(blk(), blk()),
        out_shape=(
            jax.ShapeDtypeStruct(theta.shape, theta.dtype),
            jax.ShapeDtypeStruct(p.shape, p.dtype),
        ),
        interpret=interpret,
    )(scalars, theta, p, g, c_tilde, minv, bits1, bits2)
