"""Tests for the beyond-paper extensions: scale-adapted SGHMC and the
flash-kernel dispatch flag in the model layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, core
from repro.models import get_model, init_params
from util import gaussian_grad, run_sampler


class TestScaleAdaptedSGHMC:
    def test_stationary_on_anisotropic_gaussian(self):
        """Stability + mixing on a badly-scaled target: curvatures
        (100, 0.25). The preconditioner must keep the stiff direction stable
        at a step size that still mixes the soft one."""
        prec = jnp.array([100.0, 0.25])
        grad = lambda th: prec * th

        s = core.scale_adapted_sghmc(step_size=1e-2, burnin=2000)
        traj = run_sampler(s, jnp.array([0.3, 5.0]), grad, 12000, collect_from=6000)
        assert np.all(np.isfinite(traj))
        assert abs(traj[:, 1].mean()) < 1.0  # soft direction mixes to 0
        assert abs(traj[:, 0].mean()) < 0.2  # stiff direction stable at 0
        assert traj[:, 0].var() < 1.0  # no stiff-direction blow-up

    def test_preconditioner_freezes_after_burnin(self):
        s = core.scale_adapted_sghmc(step_size=1e-3, burnin=5)
        params = jnp.ones(4)
        st = s.init(params)
        for t in range(10):
            g = jax.random.normal(jax.random.PRNGKey(t), (4,)) * (t + 1)
            _, st = s.update(g, st, params=params, rng=jax.random.PRNGKey(100 + t))
            if t == 6:
                frozen = np.asarray(st.precond.v)
        np.testing.assert_array_equal(np.asarray(st.precond.v), frozen)


class TestFlashKernelFlag:
    def test_model_forward_matches_chunked_path(self):
        """use_flash_kernel=True must reproduce the XLA-path NLL."""
        cfg = configs.get_config("h2o-danube-1.8b", smoke=True)
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        B, S = 2, 32
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size),
        }
        nll_ref, _ = model.train_nll(cfg, params, batch)
        cfg_flash = cfg.replace(use_flash_kernel=True)
        nll_flash, _ = model.train_nll(cfg_flash, params, batch)
        np.testing.assert_allclose(float(nll_flash), float(nll_ref), rtol=5e-4)

    def test_flash_flag_with_softcap_arch(self):
        cfg = configs.get_config("gemma2-27b", smoke=True)
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        B, S = 1, 32
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size),
        }
        nll_ref, _ = model.train_nll(cfg, params, batch)
        nll_flash, _ = model.train_nll(cfg.replace(use_flash_kernel=True), params, batch)
        np.testing.assert_allclose(float(nll_flash), float(nll_ref), rtol=5e-4)
