"""Mixture-of-Experts layer (grok-1: 8e top-2, olmoe: 64e top-8).

TPU-native dense-dispatch formulation (einsum + capacity, MaxText-style):
tokens are grouped (group size g) so the dispatch einsums stay a small
fraction of expert-FFN FLOPs; experts shard over the `expert` logical axis
(EP).  Capacity overflow drops tokens (residual passes through), standard
for TPU MoE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec
from .layers import _ACTS

GROUP = 512  # tokens per dispatch group


def moe_specs(cfg: ModelConfig) -> dict:
    D, F, E, pd = cfg.d_model, cfg.moe_d_ff, cfg.moe_num_experts, cfg.param_dtype
    return {
        "router": ParamSpec((D, E), ("embed", None), dtype=pd),
        "w_gate": ParamSpec((E, D, F), ("expert", "embed", "mlp"), dtype=pd),
        "w_up": ParamSpec((E, D, F), ("expert", "embed", "mlp"), dtype=pd),
        "w_down": ParamSpec((E, F, D), ("expert", "mlp", "embed"), dtype=pd),
    }


def _capacity(cfg: ModelConfig, g: int) -> int:
    cap = int(g * cfg.moe_top_k * cfg.capacity_factor / cfg.moe_num_experts)
    return max(cap, cfg.moe_top_k)


def moe_ffn(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> (B, S, D)."""
    cd = cfg.compute_dtype
    B, S, D = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    g = min(GROUP, S)
    n_groups = (B * S) // g
    xg = x.reshape(n_groups, g, D)
    C = _capacity(cfg, g)

    logits = jnp.einsum("ngd,de->nge", xg.astype(cd), p["router"].astype(cd))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (n,g,E)
    gate_vals, idx = jax.lax.top_k(probs, K)  # (n,g,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (n,g,K,E)
    flat = onehot.reshape(n_groups, g * K, E)  # token-major priority
    pos = jnp.cumsum(flat, axis=1) - flat  # (n,g*K,E): slot index per entry
    pos = pos.reshape(n_groups, g, K, E)
    keep = (pos < C).astype(jnp.float32) * onehot
    slot_oh = jax.nn.one_hot(
        jnp.sum(pos * onehot, axis=-1).astype(jnp.int32), C, dtype=jnp.float32
    )  # (n,g,K,C)
    # dispatch: (n, g, E, C); combine adds the gate weights
    dispatch = jnp.einsum("ngke,ngkc->ngec", keep, slot_oh)
    combine = jnp.einsum("ngke,ngkc,ngk->ngec", keep, slot_oh, gate_vals)

    xe = jnp.einsum("ngec,ngd->necd", dispatch.astype(cd), xg.astype(cd))  # (n,E,C,D)
    act = _ACTS[cfg.act]
    h = act(jnp.einsum("necd,edf->necf", xe, p["w_gate"].astype(cd)))
    h = h * jnp.einsum("necd,edf->necf", xe, p["w_up"].astype(cd))
    ye = jnp.einsum("necf,efd->necd", h, p["w_down"].astype(cd))  # (n,E,C,D)
    y = jnp.einsum("ngec,necd->ngd", combine.astype(cd), ye)
    return y.reshape(B, S, D)
