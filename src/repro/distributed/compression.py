"""Gradient/center-exchange compression for the EC sync collective.

int8 with per-block scales (block = trailing 256 elements).  Soundness
argument specific to this paper: the quantization error of the exchanged
center/mean-theta is mathematically absorbed into the center-noise
covariance C of Eq. 6 — EC-SGHMC is *designed* to tolerate a noisy center,
so compressing its one collective is free robustness the naive approach
does not enjoy (Async-SGHMC's stale gradients enter the dynamics directly).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Int8Codec(NamedTuple):
    encode: callable
    decode: callable
    ratio: float  # wire-bytes ratio vs f32


def int8_codec() -> Int8Codec:
    def encode(x):
        shape = x.shape
        flat = x.astype(jnp.float32).reshape(-1)
        pad = (-flat.size) % BLOCK
        flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
        scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
        q = jnp.round(flat / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
        return {"q": q, "scale": scale, "shape": shape, "n": x.size}

    def decode(enc):
        flat = enc["q"].astype(jnp.float32) * enc["scale"]
        return flat.reshape(-1)[: enc["n"]].reshape(enc["shape"])

    return Int8Codec(encode, decode, ratio=(1 + 4 / BLOCK) / 4)
