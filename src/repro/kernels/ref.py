"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# --- fused EC-SGHMC update -------------------------------------------------


def _bits_to_unit(bits):
    return (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24)) + (0.5 / (1 << 24))


def box_muller(bits1, bits2):
    u1 = _bits_to_unit(bits1)
    u2 = _bits_to_unit(bits2)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos((2.0 * jnp.pi) * u2)


def fused_ec_update(
    theta, p, g, c_tilde, bits1, bits2, *, eps, friction, mass, alpha, sigma_p
):
    """Reference Eq. 6 chain update with Box-Muller noise from given bits.
    Returns (theta_new_f32, p_new_f32) — round-to-nearest casting is applied
    by callers; stochastic rounding is validated distributionally."""
    minv = 1.0 / mass
    t32, p32 = theta.astype(jnp.float32), p.astype(jnp.float32)
    noise = box_muller(bits1, bits2)
    theta_new = t32 + eps * minv * p32
    p_new = (
        (1.0 - eps * friction * minv) * p32
        - eps * g.astype(jnp.float32)
        - eps * alpha * (t32 - c_tilde.astype(jnp.float32))
        + sigma_p * noise
    )
    return theta_new, p_new


# --- flash attention ---------------------------------------------------------


def attention(q, k, v, *, causal=True, window=None, softcap=None, scale=None):
    """q: (B, Hq, S, d); k/v: (B, Hkv, S, d); GQA by head broadcast.
    Full-materialization reference."""
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qr = q.reshape(B, Hkv, G, S, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qr * scale, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, S, d)


# --- RG-LRU scan -------------------------------------------------------------


def rglru_scan(a, x, h0=None):
    """h_t = a_t * h_{t-1} + x_t over axis 1.  a, x: (B, S, R) f32."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a = a.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if h0 is not None:
        x = x.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h
