"""Stochastic Gradient Langevin Dynamics (Welling & Teh, 2011).

    theta_{t+1} = theta_t - eps * grad Ũ(theta_t) + N(0, 2 eps)

First-order baseline; also the deterministic-limit bridge to EASGD without
momentum noted in the paper's §5.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .schedules import as_schedule
from .tree_util import tree_random_normal
from .types import Sampler


class SGLDState(NamedTuple):
    step: jnp.ndarray


def sgld(step_size, temperature: float = 1.0) -> Sampler:
    """Diagonal preconditioning lives in ``preconditioned_sgld`` — this is
    the bare Welling–Teh update."""
    schedule = as_schedule(step_size)

    def init(params):
        del params
        return SGLDState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None, rng=None):
        del params
        eps = schedule(state.step)
        sigma = jnp.sqrt(2.0 * eps * temperature)
        noise = tree_random_normal(rng, grads, jnp.float32)
        updates = jax.tree.map(
            lambda g, n: -eps * g.astype(jnp.float32) + sigma * n, grads, noise
        )
        return updates, SGLDState(step=state.step + 1)

    return Sampler(init, update)
