"""Sharded data pipeline.

Feeds (K chains x per-chain batch) batches to the train step, placing each
shard on its mesh position (chain axis = which chain consumes it; per the
paper, every worker samples its OWN minibatches).  Stateless indexing: batch
t is a
pure function of (seed, t), so restart/resume needs only the step counter —
no iterator state in checkpoints.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ShardedLoader:
    """Classification datasets (paper experiments): (x, y) arrays ->
    per-chain minibatches by stateless permutation."""

    def __init__(self, x, y, batch_size: int, num_chains: int = 1, seed: int = 0):
        self.x, self.y = np.asarray(x), np.asarray(y)
        self.n = self.x.shape[0]
        self.bs = batch_size
        self.k = num_chains
        self.seed = seed

    def batch(self, step: int):
        """Returns {"x": (K, B, ...), "y": (K, B)} for chain-stacked steps,
        or unstacked when num_chains == 1."""
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, self.n, size=(self.k, self.bs))
        bx, by = self.x[idx], self.y[idx]
        if self.k == 1:
            bx, by = bx[0], by[0]
        return {"x": jnp.asarray(bx), "y": jnp.asarray(by)}


def chain_batches(sampler: Callable, step: int, num_chains: int, per_chain: int, seq_len: int):
    """LM batches with a leading chain axis, from a synthetic token sampler."""
    toks = sampler(step, (num_chains, per_chain, seq_len + 1))
    return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


def place(batch, shardings):
    """Device_put a host batch against NamedShardings (double-buffer point)."""
    return jax.tree.map(jax.device_put, batch, shardings)
