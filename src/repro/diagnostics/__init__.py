"""Convergence diagnostics for the coupled SG-MCMC samplers.

Three layers, used together by the stationary test battery and the
benchmarks:

- ``moments``  — streaming Welford accumulators over pytrees (jit/scan
  compatible; chain-axis aware pooling).
- ``ess``      — FFT-autocorrelation effective sample size and split-R̂
  (host-side numpy, post-hoc).
- ``oracle``   — exact stationary moments of the discrete-time sampler
  recursions on a Gaussian target (the ground truth empirical moments are
  gated against; no small-ε approximation).
- ``spread``   — cross-chain / ensemble dispersion scalars.
- ``streaming`` — in-carry batch-means ESS (device-resident runs where the
  FFT estimators' full-trajectory requirement is unaffordable).
"""
from .ess import (
    autocorrelation,
    coupled_ess,
    coupled_ess_nd,
    effective_sample_size,
    effective_sample_size_nd,
    split_rhat,
    split_rhat_nd,
)
from .moments import (
    ChainSummary,
    MomentState,
    chain_summary,
    welford_add,
    welford_init,
    welford_mean,
    welford_merge,
    welford_std,
    welford_var,
)
from .oracle import (
    DiagGaussianOracle,
    GaussianOracle,
    async_sghmc_stationary,
    ec_sghmc_stationary,
    lyapunov_stationary,
    monte_carlo_tolerance,
    noise_sigmas,
    preconditioned_ec_sghmc_stationary,
    preconditioned_sghmc_stationary,
    preconditioned_sgld_stationary,
    sghmc_stationary,
    sgld_stationary,
)
from .spread import (
    chain_center_rms,
    cross_chain_spread,
    ensemble_spread,
    ensemble_spread_device,
    pooled_moments,
)
from .streaming import (
    BatchMeansState,
    batch_ess_add,
    batch_ess_estimate,
    batch_ess_init,
)

__all__ = [
    "autocorrelation",
    "coupled_ess",
    "coupled_ess_nd",
    "effective_sample_size",
    "effective_sample_size_nd",
    "split_rhat",
    "split_rhat_nd",
    "ChainSummary",
    "MomentState",
    "chain_summary",
    "welford_add",
    "welford_init",
    "welford_mean",
    "welford_merge",
    "welford_std",
    "welford_var",
    "DiagGaussianOracle",
    "GaussianOracle",
    "async_sghmc_stationary",
    "ec_sghmc_stationary",
    "lyapunov_stationary",
    "monte_carlo_tolerance",
    "noise_sigmas",
    "preconditioned_ec_sghmc_stationary",
    "preconditioned_sghmc_stationary",
    "preconditioned_sgld_stationary",
    "sghmc_stationary",
    "sgld_stationary",
    "chain_center_rms",
    "cross_chain_spread",
    "ensemble_spread",
    "ensemble_spread_device",
    "pooled_moments",
    "BatchMeansState",
    "batch_ess_add",
    "batch_ess_estimate",
    "batch_ess_init",
]
