"""Cross-chain / ensemble dispersion summaries.

Everything here reduces a chain-stacked pytree (leading axis K on every
leaf) to a handful of scalars — the numbers the serving loop, fig1, and
the staleness sweep previously each hand-rolled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cross_chain_spread(tree) -> jnp.ndarray:
    """Element-weighted mean over all parameters of the per-element
    variance across the leading chain axis.  0 ⇔ all chains identical."""
    num, den = jnp.float32(0.0), 0
    for leaf in jax.tree.leaves(tree):
        v = jnp.var(leaf.astype(jnp.float32), axis=0)
        num = num + jnp.sum(v)
        den += int(v.size)
    return num / max(den, 1)


def chain_center_rms(tree, center) -> jnp.ndarray:
    """RMS distance of chains from a center tree (leaves without the chain
    axis): sqrt(mean_i,elem (θⁱ - c)²) — the elastic-coupling energy scale."""
    num, den = jnp.float32(0.0), 0
    for leaf, c in zip(jax.tree.leaves(tree), jax.tree.leaves(center)):
        d = leaf.astype(jnp.float32) - c.astype(jnp.float32)[None]
        num = num + jnp.sum(d * d)
        den += int(d.size)
    return jnp.sqrt(num / max(den, 1))


def ensemble_spread_device(params_stack) -> dict:
    """Device-side half of :func:`ensemble_spread`: the pure-jnp reduction
    of a (K, ...)-stacked ensemble to scalar DEVICE arrays — jit-safe, no
    host syncs.  The serving registry's lazy promotion gate dispatches this
    alongside the decode stream and fetches the verdict only at flip time
    (DESIGN.md §9)."""
    leaves = jax.tree.leaves(params_stack)
    k = int(leaves[0].shape[0])
    n_per_chain = max(sum(int(l.size) for l in leaves) // max(k, 1), 1)
    spread = cross_chain_spread(params_stack)
    norms = jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2, axis=tuple(range(1, l.ndim))) for l in leaves)
    )  # (K,)
    rms_param = jnp.mean(norms) / jnp.sqrt(jnp.float32(n_per_chain))
    return {
        "chain_spread": spread,
        "mean_param_norm": jnp.mean(norms),
        "rel_spread": jnp.sqrt(spread) / jnp.maximum(rms_param, 1e-12),
    }


def ensemble_spread(params_stack) -> dict:
    """Serving-side ensemble health: how dispersed the K posterior samples
    actually are (a collapsed ensemble is a silent BMA no-op).

    ``rel_spread`` is scale-free: per-element cross-chain std over the RMS
    parameter magnitude, so the same physical dispersion reports the same
    number regardless of model size.  Host-syncing wrapper around
    :func:`ensemble_spread_device`."""
    leaves = jax.tree.leaves(params_stack)
    out = {k: float(v) for k, v in ensemble_spread_device(params_stack).items()}
    out["num_chains"] = int(leaves[0].shape[0])
    return out


def pooled_moments(trajectory) -> tuple[np.ndarray, np.ndarray]:
    """(mean, var) per trailing dimension of a (chains, samples, *dims)
    trajectory, pooled over chains and samples — the estimate the
    stationary battery compares against the oracle."""
    x = np.asarray(trajectory, np.float64)
    flat = x.reshape(-1, *x.shape[2:])
    return flat.mean(axis=0), flat.var(axis=0)
