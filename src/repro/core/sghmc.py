"""Stochastic Gradient Hamiltonian Monte Carlo — paper Eq. (4).

    theta_{t+1} = theta_t + eps * M^{-1} p_t
    p_{t+1}     = p_t - eps * grad Ũ(theta_t) - eps * V M^{-1} p_t
                      + N(0, 2 eps V)            [noise_convention="eq4"]

V plays the double role of friction and injected-noise scale (the paper
follows Ma et al.'s complete-recipe form where D = diag([0, V])).  ``mass``
is the diagonal of M (scalar or pytree).  ``temperature`` scales the noise
covariance (1.0 = faithful sampler, 0.0 = deterministic momentum dynamics —
useful for tests and cold-posterior ablations).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .schedules import as_schedule
from .tree_util import global_norm, tree_random_normal
from .types import Sampler


class SGHMCState(NamedTuple):
    momentum: any
    step: jnp.ndarray


def _noise_scale(eps, friction, extra, convention: str):
    """Std-dev of injected noise. eq4: N(0, 2 eps V); eq6: N(0, 2 eps^2 (V+C))."""
    v = friction + extra
    if convention == "eq4":
        return jnp.sqrt(2.0 * eps * v)
    elif convention == "eq6":
        return eps * jnp.sqrt(2.0 * v)
    raise ValueError(f"unknown noise convention {convention!r}")


def sghmc(
    step_size,
    friction: float = 1.0,
    mass: float = 1.0,
    temperature: float = 1.0,
    noise_convention: str = "eq4",
    grad_noise_estimate: float = 0.0,
    state_dtype=jnp.float32,
) -> Sampler:
    """Plain SGHMC (single chain, or K independent chains if params carry a
    leading chain axis — there is no cross-leaf or cross-chain interaction).

    ``grad_noise_estimate`` is the B̂ term of Chen et al. (2014): injected
    noise becomes 2 eps (V - B̂) while friction stays V.
    ``state_dtype``: momentum storage dtype (bf16 at 100B+ scale; arithmetic
    is always f32 with cast-on-store).
    """
    schedule = as_schedule(step_size)
    minv = 1.0 / mass

    def init(params):
        return SGHMCState(
            momentum=jax.tree.map(lambda p: jnp.zeros_like(p, state_dtype), params),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params=None, rng=None):
        del params
        eps = schedule(state.step)
        # position update uses the *current* momentum (Eq. 4 line 1)
        updates = jax.tree.map(lambda p: eps * minv * p.astype(jnp.float32), state.momentum)
        sigma = temperature**0.5 * _noise_scale(
            eps, friction - grad_noise_estimate, 0.0, noise_convention
        )
        noise = tree_random_normal(rng, state.momentum, jnp.float32)

        def mom_step(p, g, n):
            # decay form (1 - eps V M^-1) p: the association the fused
            # Pallas kernel uses, so the coupled sampler's unfused path
            # stays bit-identical at alpha=0
            p32 = p.astype(jnp.float32)
            out = (1.0 - eps * friction * minv) * p32 - eps * g.astype(jnp.float32) + sigma * n
            return out.astype(state_dtype)

        new_mom = jax.tree.map(mom_step, state.momentum, grads, noise)
        return updates, SGHMCState(momentum=new_mom, step=state.step + 1)

    def stats(state, params):
        del params
        return {"step": state.step, "momentum_norm": global_norm(state.momentum)}

    return Sampler(init, update, stats=stats)
