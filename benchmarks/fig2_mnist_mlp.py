"""Paper Fig. 2 (left): posterior sampling of a 2x800 ReLU MLP on (synthetic)
MNIST — SGHMC vs. naive Async SGHMC vs. EC-SGHMC, K=6 threads, batch 100,
Gaussian prior lambda=1e-5.

Claims reproduced:
  (1) both parallel samplers beat single-chain SGHMC at s=1;
  (2) at s=8 the stale-gradient Async SGHMC degrades; EC-SGHMC copes
      gracefully (the center buffers the staleness noise).
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro import core
from repro.data import synthetic_mnist
from repro.models import mlp, init_params

from common import QUICK, emit
from posterior_driver import run_sampling, sgd_map

K = 6
EPS, FRIC = sgd_map(lr=3e-7, beta=0.9)  # scale-adapted SGHMC hyperparams


def _setup():
    hidden = 256 if QUICK else 800
    n_train = 12_000 if QUICK else 60_000
    steps = 300 if QUICK else 2000
    x, y = synthetic_mnist(n_train + 2000)
    train = (x[:n_train], y[:n_train])
    test = (x[n_train:], y[n_train:])
    specs = mlp.param_specs(hidden=hidden)
    return train, test, specs, n_train, steps


def run():
    train, test, specs, n_data, steps = _setup()
    init_fn = lambda rng: init_params(specs, rng)
    apply_fn = mlp.apply
    results = {}

    ec = lambda s: core.ec_sghmc(
        step_size=EPS, friction=FRIC, center_friction=FRIC, alpha=1.0,
        sync_every=s, noise_convention="eq4", center_noise_in_p=False,
    )
    jobs = {
        "sghmc": (core.sghmc(step_size=EPS, friction=FRIC), 1),
        "ec_s1": (ec(1), K),
        "ec_s8": (ec(8), K),
        "async_s1": (core.async_sghmc(step_size=EPS, friction=FRIC, num_workers=K, sync_every=1), 1),
        "async_s8": (core.async_sghmc(step_size=EPS, friction=FRIC, num_workers=K, sync_every=8), 1),
    }
    import time

    for name, (sampler, chains) in jobs.items():
        t0 = time.time()
        _, curve = run_sampling(
            apply_fn, mlp.nll_fn, init_fn, sampler, chains, train, test,
            n_data=n_data, steps=steps, eval_every=max(steps // 10, 10),
        )
        dt = time.time() - t0
        final = curve[-1]["nll_bma"]
        results[name] = final
        emit(f"fig2_mlp/{name}_final_nll", 1e6 * dt / steps, f"{final:.4f}")
        for pt in curve:
            emit(f"fig2_mlp/{name}_curve@{pt['step']}", 1e6 * dt / steps, f"{pt['nll']:.4f}")

    c1 = results["ec_s1"] <= results["sghmc"] * 1.05
    c2 = results["async_s8"] >= results["async_s1"] - 1e-4
    c3 = (results["ec_s8"] - results["ec_s1"]) <= (results["async_s8"] - results["async_s1"]) + 1e-4
    emit("fig2_mlp/claim_parallel_beats_serial", 0, "CONFIRMED" if c1 else "REFUTED")
    emit("fig2_mlp/claim_async_degrades_with_s", 0, "CONFIRMED" if c2 else "REFUTED")
    emit("fig2_mlp/claim_ec_more_robust_to_staleness", 0, "CONFIRMED" if c3 else "REFUTED")
    return results


if __name__ == "__main__":
    run()
