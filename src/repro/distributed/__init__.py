from . import compression, sharding
from .compression import (
    Int8Codec,
    compressed_tree_mean,
    decode_packed,
    encode_packed,
    int8_codec,
    packed_nbytes,
    sync_wire_bytes,
)
from .sharding import (
    build_spec,
    chain_specs,
    leading_axes_shardings,
    leading_axes_specs,
    tree_shardings,
    tree_specs,
)
