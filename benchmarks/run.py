"""Benchmark harness — one module per paper figure/table + system benches.
Prints ``name,us_per_call,derived`` CSV.  REPRO_BENCH_QUICK=0 for the full
paper-scale configurations (QUICK keeps the CPU-only run in minutes).

  PYTHONPATH=src python -m benchmarks.run [--bench fig1_toy ...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

BENCHES = {
    "fig1_toy": "fig1_toy_gaussian",  # paper Fig. 1
    "fig2_mlp": "fig2_mnist_mlp",  # paper Fig. 2 left
    "fig2_resnet": "fig2_cifar_resnet",  # paper Fig. 2 right
    "staleness": "staleness_sweep",  # paper §2 analysis
    "overhead": "sampler_overhead",  # sampler hot-loop + fused kernel
    "roofline": "roofline",  # deliverable (g), reads dry-run artifacts
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", nargs="*", default=list(BENCHES), choices=list(BENCHES))
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    failures = []
    for name in args.bench:
        mod_name = BENCHES[name]
        t0 = time.time()
        try:
            mod = __import__(mod_name)
            mod.run()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failures.append(name)
            print(f"# {name} FAILED: {e!r}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
