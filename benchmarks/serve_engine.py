"""Serving-engine latency/throughput bench (``BENCH_serve.json``).

Drives the continuous-batching posterior-predictive engine
(``repro.serve.engine``) with open-loop synthetic request traces on the
smoke-sized qwen3 config and records, per (slots, K, offered-load)
configuration: p50/p99 request latency, p50/p99 first-token latency, and
aggregate tokens/s — the serving tier's perf trajectory across PRs.  One
configuration additionally runs with live snapshot refresh enabled to price
the refresh cost in-band, and a dense-vs-paged sweep (with and without
prefix sharing, on a prompt-pool trace) records the DESIGN.md §8 memory
axes: KV bytes per request (high-water for paged, static footprint for
dense) and the prefix-cache hit rate.

The ``refresh_slo`` variant is the DESIGN.md §9 acceptance row: on a
compile-warmed engine pair it compares *continuous* overlapped background
refresh against the frozen-ensemble baseline and records the p99 ratio and
tokens/s under refresh (targets: p99 <= 1.2x frozen, tok/s >= 2x the old
synchronous-refresh row).  Both engines serve a tiny warm-up trace first so
the ratio prices refresh, not first-call compilation.  The pair runs in a
forced-2-host-device SUBPROCESS (``repro.launch.mesh.forced_device_env``,
the same fallback the shard sweep uses) so the scheduler has a spare device
to park the background sampler on — on the parent's already-locked
single-device backend the sampler would serialize with decode and the row
would measure queueing, not overlap.

CSV rows keep the historical ``name,us_per_call,derived`` shape:
us_per_call = mean decode-step wall time, derived = tokens/s.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np

from repro import configs
from repro.models import get_model, init_params
from repro.launch.mesh import forced_device_env
from repro.launch.serve import _live_refresher
from repro.serve.engine import Request, ServeEngine, SnapshotRegistry, synthetic_trace

from common import QUICK, emit, record

ARCH = "qwen3-0.6b"
# (slots, K, mean_interarrival decode-steps): two slot widths x two ensemble
# sizes, light and heavy offered load on the wider one
GRID_QUICK = [
    (2, 1, 2.0),
    (4, 2, 2.0),
    (4, 2, 0.5),
]
GRID_FULL = GRID_QUICK + [
    (8, 4, 2.0),
    (8, 4, 0.5),
]


def _members(cfg, model, k: int, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    return jax.vmap(lambda kk: init_params(model.param_specs(cfg), kk))(keys)


PROMPT_LENS = (8, 16)


def _one_config(cfg, model, slots, k, interarrival, *, num_requests, max_new,
                refresh=False, refresh_mode="sync", refresh_chunk=16,
                refresh_every=8, warm=False, prompt_pool=0, **engine_kw):
    registry = SnapshotRegistry(_members(cfg, model, k))
    refresher = None
    if refresh:
        refresher = _live_refresher(
            model.param_specs(cfg), jax.random.PRNGKey(7), registry,
            chunk_steps=refresh_chunk, mode=refresh_mode,
        )
    engine = ServeEngine(
        cfg, model, registry,
        num_slots=slots, max_seq=max(PROMPT_LENS) + max_new,
        refresher=refresher, refresh_every=refresh_every if refresh else 0,
        **engine_kw,
    )
    if warm:
        # compile admit (both prompt lengths) + decode off the clock, so the
        # timed report prices steady-state serving, not first-call tracing
        engine.run([
            Request(rid=9000 + i, prompt=np.arange(1, L + 1, dtype=np.int32), max_new=2)
            for i, L in enumerate(PROMPT_LENS)
        ])
    trace = synthetic_trace(
        num_requests,
        vocab_size=cfg.vocab_size,
        prompt_lens=PROMPT_LENS,
        max_new=max_new,
        mean_interarrival=interarrival,
        seed=1,
        prompt_pool=prompt_pool,
    )
    report = engine.run(trace)
    assert report.trace_counts.get("decode") == 1, report.trace_counts
    pct = report.latency_percentiles()
    return engine, report, pct


def slo_pair(num_requests, max_new, slots, k, inter, trials=5):
    """DESIGN.md §9 acceptance measurement: frozen-ensemble baseline vs
    continuous overlapped refresh, both compile-warmed, same trace.  Runs
    in the CURRENT process — ``run()`` calls it through a forced-2-device
    child so ``RefreshScheduler`` parks the sampler on the spare device.

    The ratio is the MEDIAN over ``trials`` back-to-back (frozen, refresh)
    paired runs of the same trace on the same warmed engines: a p99 over
    ~10^2 requests is a near-max order statistic, and on a shared CPU box
    the frozen baseline alone varies ~40% trial to trial — a single-shot
    ratio would measure scheduler jitter, not refresh cost.  Pairing the
    runs in time and taking the median prices the refresh overhead while
    staying honest: every trial serves with continuous background refresh
    enabled, nothing is cherry-picked."""
    cfg = configs.get_config(ARCH, smoke=True)
    model = get_model(cfg)
    eng_f, rep_frozen, pct_frozen = _one_config(
        cfg, model, slots, k, inter, num_requests=num_requests, max_new=max_new,
        warm=True,
    )
    # refresh_chunk=2: on this CPU-quick config a smoke-model SGLD step is
    # ~30x a warmed decode tick, so a 16-step chunk would not reach a
    # single promotion inside the trace — the short chunk keeps the row
    # exercising real promotions while backpressure protects decode.
    # refresh_every=48: the forced-2-device child still shares ONE core, so
    # sampler micro-chunks contend with decode for cycles rather than truly
    # overlapping; the cadence sets the refresh duty cycle so the row prices
    # the scheduler's overlap machinery, not raw single-core contention —
    # the trace still lands several promotions end to end.
    eng_r, rep_slo, pct_slo = _one_config(
        cfg, model, slots, k, inter, num_requests=num_requests, max_new=max_new,
        warm=True, refresh=True, refresh_mode="overlapped", refresh_chunk=2,
        refresh_every=48,
    )
    trace = synthetic_trace(
        num_requests, vocab_size=cfg.vocab_size, prompt_lens=PROMPT_LENS,
        max_new=max_new, mean_interarrival=inter, seed=1,
    )
    pairs = [(rep_frozen, pct_frozen, rep_slo, pct_slo)]
    for _ in range(trials - 1):
        rep_f = eng_f.run(trace)
        rep_r = eng_r.run(trace)
        pairs.append((rep_f, rep_f.latency_percentiles(),
                      rep_r, rep_r.latency_percentiles()))
    ratios = sorted(
        pr[3]["latency_p99_s"] / max(pr[1]["latency_p99_s"], 1e-12) for pr in pairs
    )
    p99_ratio = float(np.median(ratios))
    # report the run whose ratio IS the median, so the row's p99/latency
    # fields are a real measured trace, not a synthetic mix of trials
    rep_frozen, pct_frozen, rep_slo, pct_slo = min(
        pairs,
        key=lambda pr: abs(
            pr[3]["latency_p99_s"] / max(pr[1]["latency_p99_s"], 1e-12) - p99_ratio
        ),
    )
    rf = rep_slo.refresher
    assert rf["device"], "scheduler found no spare device — overlap not measured"
    return {
        "slots": slots,
        "ensemble": k,
        "mean_interarrival": inter,
        "variant": "refresh_slo",
        "refresh_every": 48,
        "sampler_chunk_steps": 2,
        "trials": trials,
        "requests": len(rep_slo.results),
        "step_us": round(1e6 * rep_slo.wall_s / max(rep_slo.decode_steps, 1), 1),
        "tokens_per_s": round(rep_slo.tokens_per_s, 2),
        "tokens_per_s_frozen": round(rep_frozen.tokens_per_s, 2),
        "p99_ratio": round(p99_ratio, 4),
        "p99_ratio_trials": [round(r, 4) for r in ratios],
        "latency_p99_frozen_s": round(pct_frozen["latency_p99_s"], 6),
        "snapshots_promoted": rep_slo.registry["promoted"],
        "snapshots_rejected": rep_slo.registry["rejected"],
        "sampler_device": rf["device"],
        "micro_chunks": rf["micro_chunks"],
        "micro_steps": rf["micro_steps"],
        "backpressure_ticks": rf["backpressure_ticks"],
        "flips_deferred": rf["flips_deferred"],
        "decode_steps_stalled": rf["decode_steps_stalled"],
        "per_refresh_wall_s": round(rf["per_refresh_wall_s"], 6),
        "pump_wall_s": round(rf["pump_wall_s"], 6),
        "wall_s": round(rep_slo.wall_s, 4),
        **{kk: round(v, 6) for kk, v in pct_slo.items()},
    }


def _kv_bytes(engine):
    """Dense: the static pool footprint (every slot pays max_seq up front).
    Paged: high-water page bytes actually touched over the run."""
    if engine.paged:
        return engine.pool.stats()["bytes_high_water"]
    return sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(engine.pool.caches)
    )


def run():
    cfg = configs.get_config(ARCH, smoke=True)
    model = get_model(cfg)
    grid = GRID_QUICK if QUICK else GRID_FULL
    num_requests = 8 if QUICK else 32
    max_new = 8 if QUICK else 24
    configs_out = []
    for slots, k, inter in grid:
        _, report, pct = _one_config(
            cfg, model, slots, k, inter, num_requests=num_requests, max_new=max_new
        )
        name = f"serve_s{slots}_k{k}_ia{inter:g}"
        step_us = 1e6 * report.wall_s / max(report.decode_steps, 1)
        emit(name, step_us, f"{report.tokens_per_s:.1f}tok/s")
        configs_out.append(
            {
                "slots": slots,
                "ensemble": k,
                "mean_interarrival": inter,
                "requests": len(report.results),
                "total_tokens": report.total_tokens,
                "decode_steps": report.decode_steps,
                "wall_s": round(report.wall_s, 4),
                "tokens_per_s": round(report.tokens_per_s, 2),
                "decode_traces": report.trace_counts.get("decode"),
                **{kk: round(v, 6) for kk, v in pct.items()},
            }
        )
    # dense vs paged (± prefix sharing) on the middle configuration, over a
    # prompt-pool trace so sharing has something to hit
    slots, k, inter = grid[1]
    pool_size = 3
    for variant, kw in (
        ("dense", {}),
        ("paged", {"paged": True, "block_size": 8}),
        ("paged_noshare", {"paged": True, "block_size": 8, "prefix_sharing": False}),
    ):
        engine, report, pct = _one_config(
            cfg, model, slots, k, inter, num_requests=num_requests,
            max_new=max_new, prompt_pool=pool_size, **kw,
        )
        kv = _kv_bytes(engine)
        per_req = kv / max(len(report.results), 1)
        st = engine.pool.stats()
        hit_rate = st.get("prefix_hit_rate", 0.0)
        emit(
            f"serve_s{slots}_k{k}_{variant}",
            1e6 * report.wall_s / max(report.decode_steps, 1),
            f"{per_req / 1024:.1f}KiB/req",
        )
        configs_out.append(
            {
                "slots": slots,
                "ensemble": k,
                "mean_interarrival": inter,
                "variant": variant,
                "prompt_pool": pool_size,
                "requests": len(report.results),
                "total_tokens": report.total_tokens,
                "tokens_per_s": round(report.tokens_per_s, 2),
                "wall_s": round(report.wall_s, 4),
                "kv_bytes": int(kv),
                "kv_bytes_per_request": round(per_req, 1),
                "prefix_hit_rate": round(float(hit_rate), 4),
                "prefix_hits": st.get("prefix_hits", 0),
                "blocks_high_water": st.get("blocks_high_water"),
                "decode_traces": report.trace_counts.get("decode"),
                **{kk: round(v, 6) for kk, v in pct.items()},
            }
        )
    # price the live-refresh path on the middle configuration
    _, report, pct = _one_config(
        cfg, model, slots, k, inter, num_requests=num_requests, max_new=max_new, refresh=True
    )
    emit(
        f"serve_s{slots}_k{k}_refresh",
        1e6 * report.wall_s / max(report.decode_steps, 1),
        f"{report.tokens_per_s:.1f}tok/s",
    )
    configs_out.append(
        {
            "slots": slots,
            "ensemble": k,
            "mean_interarrival": inter,
            "refresh_every": 8,
            "snapshots_promoted": report.registry["promoted"],
            "snapshots_rejected": report.registry["rejected"],
            "refresh_wall_s": report.refresher["refresh_wall_s"],
            "tokens_per_s": round(report.tokens_per_s, 2),
            "wall_s": round(report.wall_s, 4),
            **{kk: round(v, 6) for kk, v in pct.items()},
        }
    )
    # DESIGN.md §9 acceptance row: continuous *overlapped* refresh vs the
    # frozen baseline, in a forced-2-device child so the sampler has a
    # spare device (the parent backend is already locked to one)
    here = Path(__file__).resolve().parent
    # longer trace than the latency grid: enough decode ticks for several
    # promotions to land at the sampler's (backpressured) natural rate
    slo_requests = 64 if QUICK else 96
    child_src = textwrap.dedent(
        f"""
        import json, sys
        sys.path[:0] = [{str(here)!r}, {str(here.parent / "src")!r}]
        import serve_engine
        row = serve_engine.slo_pair({slo_requests}, {max_new}, {slots}, {k}, {inter})
        print("SLO=" + json.dumps(row), flush=True)
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", child_src],
        env=forced_device_env(2), capture_output=True, text=True, timeout=1200,
    )
    if out.returncode != 0:
        raise RuntimeError(f"refresh_slo child failed:\n{out.stderr[-3000:]}")
    row = json.loads(
        [ln for ln in out.stdout.splitlines() if ln.startswith("SLO=")][-1][4:]
    )
    emit(
        f"serve_s{slots}_k{k}_refresh_slo",
        row["step_us"],
        f"{row['tokens_per_s']:.1f}tok/s p99x{row['p99_ratio']:.2f}",
    )
    configs_out.append(row)
    record("serve", {"arch": ARCH, "configs": configs_out})
    return {"num_configs": len(configs_out)}
