"""EASGD family (Zhang et al. 2015) + the paper's §5 alternative.

Three deterministic optimizers over K-stacked params:

* ``easgd``      — plain elastic averaging SGD (no momentum);
* ``eamsgd``     — EASGD with momentum as rewritten in the paper's Eq. (10):
                   coupling force applied to the POSITION, center has no
                   momentum (the paper argues this breaks the generalized
                   coordinate/momentum interpretation);
* ``ec_msgd``    — the paper's Eq. (9): the deterministic limit of EC-SGHMC
                   (coupling through the momentum, center carries momentum).
                   Unit tests verify bit-equality with
                   ``ec_sghmc(temperature=0, noise_convention="eq6")`` under
                   the §5 variable substitution.

All three accept ``sync_every`` (s): Zhang et al. update the center and apply
coupling terms only every s steps, dropping them in intermittent steps — we
reproduce that literally for eamsgd/easgd; ec_msgd uses the EC stale-center
semantics (consistent with EC-SGHMC).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .schedules import as_schedule
from .tree_util import tree_mean_axis0
from .types import Sampler


class EASGDState(NamedTuple):
    center: any
    step: jnp.ndarray


def easgd(step_size, alpha: float = 1.0, sync_every: int = 1) -> Sampler:
    schedule = as_schedule(step_size)
    s = int(sync_every)

    def init(params):
        return EASGDState(
            center=tree_mean_axis0(jax.tree.map(lambda p: p.astype(jnp.float32), params)),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, rng=None):
        eps = schedule(state.step)
        couple = ((state.step % s) == 0).astype(jnp.float32)
        updates = jax.tree.map(
            lambda g, th, c: -eps * g.astype(jnp.float32)
            - couple * eps * alpha * (th.astype(jnp.float32) - c[None]),
            grads,
            params,
            state.center,
        )
        new_center = jax.tree.map(
            lambda c, th: c
            + couple * eps * alpha * (jnp.mean(th.astype(jnp.float32), 0) - c),
            state.center,
            params,
        )
        return updates, EASGDState(center=new_center, step=state.step + 1)

    return Sampler(init, update)


class EAMSGDState(NamedTuple):
    velocity: any  # (K, ...)
    center: any
    step: jnp.ndarray


def eamsgd(step_size, alpha: float = 1.0, xi: float = 0.1, sync_every: int = 1) -> Sampler:
    """Paper Eq. (10) — momentum EASGD, coupling applied to positions."""
    schedule = as_schedule(step_size)
    s = int(sync_every)

    def init(params):
        return EAMSGDState(
            velocity=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            center=tree_mean_axis0(jax.tree.map(lambda p: p.astype(jnp.float32), params)),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, rng=None):
        eps = schedule(state.step)
        couple = ((state.step % s) == 0).astype(jnp.float32)
        # theta_{t+1} = theta_t + v_t - eps*alpha*(theta_t - c_t)
        updates = jax.tree.map(
            lambda v, th, c: v
            - couple * eps * alpha * (th.astype(jnp.float32) - c[None]),
            state.velocity,
            params,
            state.center,
        )
        # c_{t+1} = c_t - eps*alpha*(1/K) sum_i (c_t - theta^i_t)
        new_center = jax.tree.map(
            lambda c, th: c
            - couple * eps * alpha * (c - jnp.mean(th.astype(jnp.float32), 0)),
            state.center,
            params,
        )
        # v_{t+1} = v_t - eps*grad - xi*v_t
        new_velocity = jax.tree.map(
            lambda v, g: v - eps * g.astype(jnp.float32) - xi * v,
            state.velocity,
            grads,
        )
        return updates, EAMSGDState(new_velocity, new_center, state.step + 1)

    return Sampler(init, update)


class ECMSGDState(NamedTuple):
    velocity: any  # v^i : (K, ...)
    center: any  # c
    center_velocity: any  # h
    step: jnp.ndarray


def ec_msgd(step_size, alpha: float = 1.0, xi: float = 0.1) -> Sampler:
    """Paper Eq. (9) — the physics-respecting momentum-EASGD suggested by the
    deterministic limit of EC-SGHMC (s=1 synchronous form)."""
    schedule = as_schedule(step_size)

    def init(params):
        center = tree_mean_axis0(jax.tree.map(lambda p: p.astype(jnp.float32), params))
        return ECMSGDState(
            velocity=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            center=center,
            center_velocity=jax.tree.map(jnp.zeros_like, center),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, rng=None):
        eps = schedule(state.step)
        updates = jax.tree.map(lambda v: v, state.velocity)  # theta += v_t
        new_center = jax.tree.map(lambda c, h: c + h, state.center, state.center_velocity)
        # v_{t+1} = v_t - eps*grad - xi*v_t - eps*alpha*(theta - c)
        new_velocity = jax.tree.map(
            lambda v, g, th, c: v
            - eps * g.astype(jnp.float32)
            - xi * v
            - eps * alpha * (th.astype(jnp.float32) - c[None]),
            state.velocity,
            grads,
            params,
            state.center,
        )
        # h_{t+1} = h_t - xi*h_t - eps*alpha*(1/K) sum_i (c - theta^i)
        new_center_velocity = jax.tree.map(
            lambda h, c, th: h - xi * h - eps * alpha * (c - jnp.mean(th.astype(jnp.float32), 0)),
            state.center_velocity,
            state.center,
            params,
        )
        return updates, ECMSGDState(
            new_velocity, new_center, new_center_velocity, state.step + 1
        )

    return Sampler(init, update)
