"""Snapshot registry: the serving engine's source of ensemble members,
refreshed live from a background coupled-sampler run.

The paper's robustness argument is exactly what makes this sound: EC-SGHMC
is *designed* to tolerate a noisy/stale center (the staleness and
quantization perturbations are absorbed into the center-noise covariance C
of Eq. 6), so serving from members that lag the live chains by up to one
executor chunk — and swapping them mid-flight — is a controlled
perturbation of the same kind, unlike naive async whose stale gradients
enter the dynamics directly (Chen et al., stale-gradient SG-MCMC).

Promotion is GATED: ``propose`` runs ``ensemble_diagnostics`` on the
candidate stack and refuses a collapsed ensemble (spread below
``min_rel_spread``) — K identical members silently degrade Bayesian model
averaging to one model's predictions, and the registry is where that must
be caught, before the stack ever serves.  Stale members keep serving until
a candidate passes.

``ChainRefresher`` drives the background run cooperatively through
``ChainExecutor.stream`` (the chunk-boundary snapshot hook): each
``refresh()`` advances the sampler one chunk and proposes the live chain
stack.  Cooperative (caller-paced) rather than threaded keeps the whole
engine deterministic — the serving loop decides how often it pays the
refresh cost, and a given (trace, seed, cadence) always reproduces.
"""
from __future__ import annotations

import time
from typing import Any

import jax

from repro.run import ChainExecutor
from repro.serve.loop import ensemble_diagnostics


class SnapshotRegistry:
    """Holds the currently-serving (K, ...)-stacked ensemble; ``propose``
    swaps it atomically iff the candidate passes the spread gate."""

    def __init__(self, members, *, min_rel_spread: float = 1e-6, validate: bool = False):
        self.min_rel_spread = float(min_rel_spread)
        self.members = members
        self.num_members = int(jax.tree.leaves(members)[0].shape[0])
        self.version = 0
        self.promoted = 0
        self.rejected = 0
        self.last_health: dict | None = None
        if validate:
            health = ensemble_diagnostics(members, min_rel_spread=self.min_rel_spread)
            self.last_health = health
            if health["collapsed"]:
                raise ValueError(
                    f"initial ensemble is collapsed (rel_spread={health['rel_spread']:.3e})"
                )

    def propose(self, candidate) -> bool:
        """Gate + swap.  Returns True iff ``candidate`` was promoted; on
        rejection the previous members keep serving unchanged."""
        k = int(jax.tree.leaves(candidate)[0].shape[0])
        if k != self.num_members:
            raise ValueError(f"candidate has K={k}, registry serves K={self.num_members}")
        health = ensemble_diagnostics(candidate, min_rel_spread=self.min_rel_spread)
        self.last_health = health
        if health["collapsed"]:
            self.rejected += 1
            return False
        self.members = candidate
        self.version += 1
        self.promoted += 1
        return True

    def stats(self) -> dict:
        return {
            "version": self.version,
            "promoted": self.promoted,
            "rejected": self.rejected,
            "num_members": self.num_members,
            "last_health": self.last_health,
        }


class ChainRefresher:
    """Cooperative background sampler feeding a :class:`SnapshotRegistry`.

    ``params`` must be the (K, ...)-stacked chain state of a chain-parallel
    sampler (EC-SGLD / EC-SGHMC / chainwise SGLD) whose live stack IS the
    candidate ensemble.  Each ``refresh()`` advances exactly one executor
    chunk (``chunk_steps`` sampler steps) and proposes the resulting stack;
    after ``total_steps`` the run is exhausted and ``refresh()`` returns
    False forever.  ``members_of`` maps the raw chain stack to the served
    parameter stack (default: identity)."""

    def __init__(
        self,
        registry: SnapshotRegistry,
        sampler,
        grad_fn,
        params,
        *,
        key,
        state=None,
        chunk_steps: int = 64,
        total_steps: int = 1 << 30,
        members_of=None,
    ):
        self.registry = registry
        self.members_of = members_of or (lambda p: p)
        ex = ChainExecutor(
            sampler=sampler,
            grad_fn=lambda targets, _batch: grad_fn(targets),
            chunk_steps=chunk_steps,
            key_mode="fold",
        )
        if state is None:
            state = sampler.init(params)
        self._stream = ex.stream(params, state, num_steps=total_steps, key=key)
        self.chunk_steps = int(chunk_steps)
        self.steps_done = 0
        self.refreshes = 0
        self.refresh_wall_s = 0.0
        self.exhausted = False

    def refresh(self) -> bool:
        """Advance one chunk, propose the live stack.  Returns True iff a
        new snapshot was promoted."""
        if self.exhausted:
            return False
        t0 = time.perf_counter()
        try:
            snap = next(self._stream)
        except StopIteration:
            self.exhausted = True
            return False
        self.refresh_wall_s += time.perf_counter() - t0
        self.steps_done = snap.step
        self.refreshes += 1
        return self.registry.propose(self.members_of(snap.params))

    def stats(self) -> dict:
        return {
            "refreshes": self.refreshes,
            "steps_done": self.steps_done,
            "refresh_wall_s": round(self.refresh_wall_s, 4),
            "exhausted": self.exhausted,
        }
