"""Ablation: elastic-coupling strength alpha (EXPERIMENTS.md §Findings F2).

Sweeps alpha on the 2-D Gaussian target and reports per-chain marginal
variance (coupling shrinkage) and cross-chain spread (coherence) —
quantifying the exploration/agreement trade-off the paper's Fig. 1 shows
qualitatively.

    PYTHONPATH=src python examples/alpha_ablation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core

MU = jnp.array([2.0, -1.0])
K, STEPS, BURN = 4, 8000, 2000


def run_alpha(alpha: float):
    sampler = core.ec_sghmc(step_size=5e-2, alpha=alpha, sync_every=4,
                            noise_convention="eq4", center_noise_in_p=False)
    params = jnp.zeros((K, 2))
    state = sampler.init(params)

    def body(carry, key):
        p, st = carry
        upd, st = sampler.update(p - MU, st, params=p, rng=key)
        return (core.apply_updates(p, upd), st), p

    keys = jax.random.split(jax.random.PRNGKey(0), STEPS)
    (_, _), traj = jax.lax.scan(body, (params, state), keys)
    t = np.asarray(traj[BURN:])  # (T, K, 2)
    marg_var = float(t.reshape(-1, 2).var(0).mean())  # posterior target: 1.0
    spread = float(t.var(axis=1).mean())  # cross-chain coherence
    return marg_var, spread


def main():
    print(f"{'alpha':>8} {'marginal var (→1.0)':>22} {'cross-chain spread':>20}")
    for alpha in (0.0, 0.01, 0.1, 0.5, 1.0, 2.0, 5.0):
        v, s = run_alpha(alpha)
        print(f"{alpha:8.2f} {v:22.3f} {s:20.4f}")
    print("\nF2: coupling buys coherence (spread ↓) at the cost of marginal"
          "\nvariance shrinkage (var < 1) — choose alpha per use-case.")


if __name__ == "__main__":
    main()
