"""Pallas kernel validation: interpret-mode execution vs. pure-jnp oracles,
swept over shapes and dtypes (+ hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from util import import_hypothesis

given, settings, st = import_hypothesis()  # deterministic tests run bare

from repro.kernels import flash_attention, fused_ec_update, rglru_scan
from repro.kernels import ref

HYPER = dict(eps=1e-2, friction=1.0, mass=1.0, alpha=0.7, sigma_p=0.05)


class TestFusedECSGHMC:
    @pytest.mark.parametrize("shape", [(64,), (1000,), (8, 128), (3, 5, 7), (2, 4096)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, shape, dtype):
        k = jax.random.PRNGKey(0)
        kt, kp, kg, kc, kk = jax.random.split(k, 5)
        theta = jax.random.normal(kt, shape, jnp.float32).astype(dtype)
        p = (0.1 * jax.random.normal(kp, shape, jnp.float32)).astype(dtype)
        g = jax.random.normal(kg, shape, jnp.float32)
        c = jax.random.normal(kc, shape, jnp.float32)

        t_new, p_new = fused_ec_update(theta, p, g, c, kk, stochastic_round=False, **HYPER)
        assert t_new.shape == shape and t_new.dtype == dtype
        # reference with the same bits (reproduce the wrapper's padding)
        from repro.kernels.ops import _pad_flat

        t2, n = _pad_flat(theta)
        k1, k2 = jax.random.split(kk)
        bits1 = jax.random.bits(k1, t2.shape, jnp.uint32)
        bits2 = jax.random.bits(k2, t2.shape, jnp.uint32)
        rt, rp = ref.fused_ec_update(
            t2, _pad_flat(p)[0], _pad_flat(g)[0], _pad_flat(jnp.broadcast_to(c, shape))[0],
            bits1, bits2, **HYPER,
        )
        rt = rt.reshape(-1)[:n].reshape(shape).astype(dtype)
        rp = rp.reshape(-1)[:n].reshape(shape).astype(dtype)
        tol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(t_new, np.float32), np.asarray(rt, np.float32), rtol=tol, atol=tol
        )
        np.testing.assert_allclose(
            np.asarray(p_new, np.float32), np.asarray(rp, np.float32), rtol=tol, atol=tol
        )

    def test_noise_is_standard_normal(self):
        """Box-Muller inside the kernel must produce N(0, sigma_p^2) noise."""
        shape = (200_000,)
        zeros = jnp.zeros(shape, jnp.float32)
        hyper = dict(eps=0.0, friction=0.0, mass=1.0, alpha=0.0, sigma_p=1.0)
        _, p_new = fused_ec_update(
            zeros, zeros, zeros, zeros, jax.random.PRNGKey(3),
            stochastic_round=False, **hyper,
        )
        s = np.asarray(p_new)
        assert abs(s.mean()) < 0.01
        assert abs(s.std() - 1.0) < 0.01
        assert abs(np.mean(s**3)) < 0.05  # symmetry

    def test_stochastic_rounding_unbiased(self):
        """bf16 SR: E[sr(x)] == x to high precision (vs round-to-nearest
        which is deterministically biased for off-grid values)."""
        val = 1.0 + 2.0 ** -10  # exactly between bf16 grid points
        n = 65536
        theta = jnp.full((n,), val, jnp.bfloat16) * 0 + jnp.bfloat16(0)  # zeros
        # drive theta' = val via momentum: theta'=theta+eps*p, eps=1, p=val
        p = jnp.full((n,), val, jnp.float32)
        hyper = dict(eps=1.0, friction=0.0, mass=1.0, alpha=0.0, sigma_p=0.0)
        t_new, _ = fused_ec_update(
            theta, p.astype(jnp.bfloat16) * 0 + p.astype(jnp.bfloat16),  # p in bf16? keep f32 path
            jnp.zeros((n,), jnp.float32), jnp.zeros((n,), jnp.float32),
            jax.random.PRNGKey(1), stochastic_round=True, **hyper,
        )
        # p stored bf16 loses the off-grid part; instead check mean ≈ bf16(val)
        got = np.asarray(t_new, np.float32).mean()
        p_b = float(jnp.bfloat16(val))
        # SR mean must sit strictly between the bf16 neighbors, near val
        assert abs(got - float(p_b)) < 2 ** -9

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 5000),
        eps=st.floats(1e-4, 0.5),
        alpha=st.floats(0.0, 2.0),
    )
    def test_property_shapes_and_finiteness(self, n, eps, alpha):
        k = jax.random.PRNGKey(n)
        x = jax.random.normal(k, (n,), jnp.float32)
        t_new, p_new = fused_ec_update(
            x, x, x, x, k, eps=eps, friction=1.0, mass=1.0, alpha=alpha,
            sigma_p=0.01, stochastic_round=False,
        )
        assert t_new.shape == (n,)
        assert bool(jnp.all(jnp.isfinite(t_new))) and bool(jnp.all(jnp.isfinite(p_new)))


class TestFusedSamplerIntegration:
    def test_fused_ec_sghmc_matches_reference_deterministic(self):
        """ec_sghmc(fused=True) dispatches the Pallas kernel; with
        temperature=0 it must match the jnp path bit-for-bit."""
        from repro import core

        mu = jnp.array([1.0, -2.0, 0.5, 0.25])
        grad = lambda th: th - mu
        p0 = jax.random.normal(jax.random.PRNGKey(0), (3, 4))

        def run(ec, steps=30):
            params, st = p0, ec.init(p0)
            for t in range(steps):
                g = jax.vmap(grad)(params)
                upd, st = ec.update(g, st, params=params, rng=jax.random.PRNGKey(t))
                params = core.apply_updates(params, upd)
            return np.asarray(params)

        a = run(core.ec_sghmc(step_size=3e-2, alpha=0.8, sync_every=2, temperature=0.0))
        b = run(core.ec_sghmc(step_size=3e-2, alpha=0.8, sync_every=2, temperature=0.0, fused=True))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


class TestFlashAttention:
    @pytest.mark.parametrize("S,window,causal", [
        (256, None, True), (256, 64, True), (256, None, False),
        (512, 128, True), (128, 16, True),
    ])
    def test_matches_reference(self, S, window, causal):
        B, Hq, Hkv, d = 2, 4, 2, 64
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (B, Hq, S, d), jnp.float32)
        k = jax.random.normal(k2, (B, Hkv, S, d), jnp.float32)
        v = jax.random.normal(k3, (B, Hkv, S, d), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
        want = ref.attention(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_softcap_and_dtype(self, dtype):
        B, Hq, Hkv, S, d = 1, 2, 1, 128, 64
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(keys[0], (B, Hq, S, d), jnp.float32).astype(dtype)
        k = jax.random.normal(keys[1], (B, Hkv, S, d), jnp.float32).astype(dtype)
        v = jax.random.normal(keys[2], (B, Hkv, S, d), jnp.float32).astype(dtype)
        out = flash_attention(q, k, v, softcap=20.0, block_q=64, block_k=64)
        want = ref.attention(q, k, v, softcap=20.0)
        tol = 3e-4 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
        )

    def test_head_dim_padding(self):
        """d=80 (danube) exercises the pad-to-128 path with correct scale."""
        B, Hq, Hkv, S, d = 1, 4, 1, 128, 80
        keys = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(keys[0], (B, Hq, S, d), jnp.float32)
        k = jax.random.normal(keys[1], (B, Hkv, S, d), jnp.float32)
        v = jax.random.normal(keys[2], (B, Hkv, S, d), jnp.float32)
        out = flash_attention(q, k, v, window=32, block_q=64, block_k=64)
        want = ref.attention(q, k, v, window=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_matches_model_attention(self):
        """Kernel agrees with the model layer's chunked-jnp attention."""
        from repro import configs
        from repro.models import layers as L

        cfg = configs.get_config("h2o-danube-1.8b", smoke=True)
        from repro.models.common import ParamSpec
        from repro.models import init_params

        specs = L.attn_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(0))
        B, S = 2, 64
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        want = L.attention(cfg, params, x, pos, window=8)
        # same computation via the kernel
        q, k, v = L._qk(cfg, params, x, pos)
        q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
        out = flash_attention(
            jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
            window=8, scale=L._scale(cfg), block_q=32, block_k=32,
        )
        out = jnp.einsum("bshk,hkd->bsd", jnp.moveaxis(out, 1, 2), params["wo"])
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-4, atol=3e-4)


class TestPagedAttention:
    """Paged decode kernel vs the gather-everything dense reference (the
    deep grid lives in tests/test_paged_attention.py; this pins the kernel
    next to its flash sibling over the contract block sizes)."""

    @pytest.mark.parametrize("bs", [8, 16, 64])
    def test_matches_dense_reference(self, bs):
        from repro.kernels import paged_attention

        B, Hkv, G, d, M = 3, 2, 2, 64, 3
        keys = jax.random.split(jax.random.PRNGKey(bs), 4)
        q = jax.random.normal(keys[0], (B, Hkv, G, d), jnp.float32)
        k = jax.random.normal(keys[1], (B * M + 1, bs, Hkv, d), jnp.float32)
        v = jax.random.normal(keys[2], (B * M + 1, bs, Hkv, d), jnp.float32)
        tables = (1 + jnp.arange(B * M, dtype=jnp.int32)).reshape(B, M)
        ctx = jax.random.randint(keys[3], (B,), 0, M * bs)  # ragged
        out = paged_attention(q, k, v, tables, ctx, window=bs + 3, softcap=30.0)
        want = ref.paged_attention(q, k, v, tables, ctx, window=bs + 3, softcap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-6)


class TestRGLRU:
    @pytest.mark.parametrize("B,S,R,bs", [(2, 64, 128, 32), (1, 256, 256, 64), (3, 128, 96, 128)])
    def test_matches_reference(self, B, S, R, bs):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.uniform(k1, (B, S, R), jnp.float32, 0.5, 0.999)
        x = jax.random.normal(k2, (B, S, R), jnp.float32)
        out = rglru_scan(a, x, block_s=bs)
        want = ref.rglru_scan(a, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_carry_across_blocks(self):
        """Initial state must propagate through every sequence block."""
        B, S, R = 1, 128, 128
        a = jnp.full((B, S, R), 0.99, jnp.float32)
        x = jnp.zeros((B, S, R), jnp.float32)
        h0 = jnp.ones((B, R), jnp.float32)
        out = rglru_scan(a, x, h0, block_s=32)
        want = 0.99 ** jnp.arange(1, S + 1)
        np.testing.assert_allclose(np.asarray(out[0, :, 0]), np.asarray(want), rtol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(s_pow=st.integers(5, 8), r=st.sampled_from([64, 128, 200]))
    def test_property_matches_reference(self, s_pow, r):
        S = 2**s_pow
        k1, k2 = jax.random.split(jax.random.PRNGKey(S + r))
        a = jax.random.uniform(k1, (1, S, r), jnp.float32, 0.0, 1.0)
        x = jax.random.normal(k2, (1, S, r), jnp.float32)
        out = rglru_scan(a, x, block_s=32)
        want = ref.rglru_scan(a, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_matches_model_block_state(self):
        """Kernel scan == the recurrent.py associative scan used in models."""
        from repro.models import recurrent as R_

        B, S, R = 2, 64, 64
        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        a = jax.random.uniform(k1, (B, S, R), jnp.float32, 0.9, 0.999)
        xin = jax.random.normal(k2, (B, S, R), jnp.float32)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        _, want = jax.lax.associative_scan(combine, (a, xin), axis=1)
        got = rglru_scan(a, xin, block_s=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
