"""Decoder-only LM engine covering 8/10 assigned archs (dense / MoE /
hybrid-recurrent / ssm / vlm backbones).

Layers are organized as a repeating block *pattern* (e.g. gemma3 = 5 local +
1 global) and scanned over pattern periods: params for pattern position i
are stacked with a leading (num_periods,) axis, so compile time is O(pattern)
instead of O(depth).  Remainder layers (depth % period) are applied unrolled.

Three entry points per model:
  train_nll(cfg, params, batch)            -> (sum_nll, token_count)
  prefill(cfg, params, batch)              -> (last_logits, cache)
  decode_step(cfg, params, cache, tokens)  -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as M
from . import recurrent as R
from .common import LayerKind, ModelConfig, ParamSpec


# ---------------------------------------------------------------------------
# Spec stacking (scan-over-periods)
# ---------------------------------------------------------------------------


def stack_specs(specs, n: int, axis_name=None):
    def f(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, shape=(n,) + s.shape, axes=(axis_name,) + s.axes)

    return jax.tree.map(f, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _block_specs(cfg: ModelConfig, kind: LayerKind) -> dict:
    sp = {"ln1": L.norm_spec(cfg)}
    if kind.kind == "attn":
        sp["attn"] = L.attn_specs(cfg)
        sp["ln2"] = L.norm_spec(cfg)
        sp["mlp"] = M.moe_specs(cfg) if kind.moe else L.mlp_specs(cfg)
        if cfg.sandwich_norm:
            sp["post_ln1"] = L.norm_spec(cfg)
            sp["post_ln2"] = L.norm_spec(cfg)
    elif kind.kind == "rglru":
        sp["mix"] = R.rglru_specs(cfg)
        sp["ln2"] = L.norm_spec(cfg)
        sp["mlp"] = L.mlp_specs(cfg)
    elif kind.kind == "mlstm":
        sp["mix"] = R.mlstm_specs(cfg)
    elif kind.kind == "slstm":
        sp["mix"] = R.slstm_specs(cfg)
    else:
        raise ValueError(kind.kind)
    return sp


def _layout(cfg: ModelConfig):
    """(pattern P, num_periods, remainder kinds)."""
    P = len(cfg.pattern)
    n_periods = cfg.num_layers // P
    rem_kinds = cfg.layer_kinds[n_periods * P :]
    return P, n_periods, rem_kinds


def param_specs(cfg: ModelConfig) -> dict:
    P, n_periods, rem_kinds = _layout(cfg)
    specs = {
        "embed": L.embed_specs(cfg),
        "layers": {
            str(i): stack_specs(_block_specs(cfg, cfg.pattern[i]), n_periods)
            for i in range(P)
        },
        "final_norm": L.norm_spec(cfg),
    }
    if rem_kinds:
        specs["rem"] = {
            str(i): _block_specs(cfg, k) for i, k in enumerate(rem_kinds)
        }
    return specs


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _norm(cfg, x, w):
    return L.rms_norm(x, w, cfg.norm_eps, cfg.norm_scale_offset)


def apply_block(cfg: ModelConfig, kind: LayerKind, p, x, positions):
    if kind.kind == "attn":
        h = L.attention(cfg, p["attn"], _norm(cfg, x, p["ln1"]), positions, kind.window)
        if cfg.sandwich_norm:
            h = _norm(cfg, h, p["post_ln1"])
        x = x + h
        h_in = _norm(cfg, x, p["ln2"])
        h = M.moe_ffn(cfg, p["mlp"], h_in) if kind.moe else L.mlp(cfg, p["mlp"], h_in)
        if cfg.sandwich_norm:
            h = _norm(cfg, h, p["post_ln2"])
        return x + h
    if kind.kind == "rglru":
        x = x + R.rglru_block(cfg, p["mix"], _norm(cfg, x, p["ln1"]))
        return x + L.mlp(cfg, p["mlp"], _norm(cfg, x, p["ln2"]))
    if kind.kind == "mlstm":
        return x + R.mlstm_block(cfg, p["mix"], _norm(cfg, x, p["ln1"]))
    if kind.kind == "slstm":
        return x + R.slstm_block(cfg, p["mix"], _norm(cfg, x, p["ln1"]))
    raise ValueError(kind.kind)


def decode_block(cfg: ModelConfig, kind: LayerKind, p, x, cache, t):
    if kind.kind == "attn":
        h, new_attn = L.decode_attention(
            cfg, p["attn"], _norm(cfg, x, p["ln1"]), cache["attn"], t, kind.window
        )
        if cfg.sandwich_norm:
            h = _norm(cfg, h, p["post_ln1"])
        x = x + h
        h_in = _norm(cfg, x, p["ln2"])
        h = M.moe_ffn(cfg, p["mlp"], h_in) if kind.moe else L.mlp(cfg, p["mlp"], h_in)
        if cfg.sandwich_norm:
            h = _norm(cfg, h, p["post_ln2"])
        return x + h, {"attn": new_attn}
    if kind.kind == "rglru":
        h, new_mix = R.rglru_decode(cfg, p["mix"], _norm(cfg, x, p["ln1"]), cache["mix"])
        x = x + h
        return x + L.mlp(cfg, p["mlp"], _norm(cfg, x, p["ln2"])), {"mix": new_mix}
    if kind.kind == "mlstm":
        h, new_mix = R.mlstm_decode(cfg, p["mix"], _norm(cfg, x, p["ln1"]), cache["mix"])
        return x + h, {"mix": new_mix}
    if kind.kind == "slstm":
        h, new_mix = R.slstm_decode(cfg, p["mix"], _norm(cfg, x, p["ln1"]), cache["mix"])
        return x + h, {"mix": new_mix}
    raise ValueError(kind.kind)


def _block_cache(cfg: ModelConfig, kind: LayerKind, batch: int, max_seq: int, dtype, abstract: bool):
    if kind.kind == "attn":
        fn = L.cache_specs if abstract else L.init_cache
        return {"attn": fn(cfg, batch, max_seq, kind.window, dtype)}
    fn = {
        "rglru": R.rglru_state_specs if abstract else R.rglru_init_state,
        "mlstm": R.mlstm_state_specs if abstract else R.mlstm_init_state,
        "slstm": R.slstm_state_specs if abstract else R.slstm_init_state,
    }[kind.kind]
    return {"mix": fn(cfg, batch, dtype)}


def _stack_cache(tree, n: int, abstract: bool):
    if abstract:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree
        )
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


def make_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype, abstract: bool = False):
    P, n_periods, rem_kinds = _layout(cfg)
    cache = {
        "layers": {
            str(i): _stack_cache(
                _block_cache(cfg, cfg.pattern[i], batch, max_seq, dtype, abstract),
                n_periods,
                abstract,
            )
            for i in range(P)
        },
        "t": jax.ShapeDtypeStruct((), jnp.int32) if abstract else jnp.zeros((), jnp.int32),
    }
    if rem_kinds:
        cache["rem"] = {
            str(i): _block_cache(cfg, k, batch, max_seq, dtype, abstract)
            for i, k in enumerate(rem_kinds)
        }
    return cache


def _block_cache_axes(kind: LayerKind, stacked: bool):
    lead = (None,) if stacked else ()
    if kind.kind == "attn":
        kv = lead + ("batch", "kvseq", "kv_heads", None)
        return {"attn": {"k": kv, "v": kv}}
    if kind.kind == "rglru":
        return {
            "mix": {"h": lead + ("batch", "rnn"), "conv": lead + ("batch", None, "rnn")}
        }
    if kind.kind == "mlstm":
        return {
            "mix": {
                "C": lead + ("batch", "heads", None, None),
                "n": lead + ("batch", "heads", None),
                "m": lead + ("batch", "heads"),
                "conv": lead + ("batch", None, "mlp"),
            }
        }
    if kind.kind == "slstm":
        ax = lead + ("batch", "heads", None)
        return {"mix": {"h": ax, "c": ax, "n": ax, "m": ax}}
    raise ValueError(kind.kind)


def cache_axes(cfg: ModelConfig):
    """Logical-axis tree matching make_cache structure (for sharding)."""
    P, n_periods, rem_kinds = _layout(cfg)
    out = {
        "layers": {str(i): _block_cache_axes(cfg.pattern[i], True) for i in range(P)},
        "t": (),
    }
    if rem_kinds:
        out["rem"] = {str(i): _block_cache_axes(k, False) for i, k in enumerate(rem_kinds)}
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _positions(cfg: ModelConfig, batch, B, S):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def _embed_inputs(cfg: ModelConfig, params, batch):
    """tokens (+ optional precomputed patch/frame embeddings prepended)."""
    x = L.embed(cfg, params["embed"], batch["tokens"])
    if "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    return x


def backbone(cfg: ModelConfig, params, x, positions, remat: bool | None = None):
    P, n_periods, rem_kinds = _layout(cfg)
    if remat is None:
        remat = cfg.remat == "full"

    def period(x, pslice):
        for i in range(P):
            x = apply_block(cfg, cfg.pattern[i], pslice[str(i)], x, positions)
        return x, None

    body = jax.checkpoint(period, policy=jax.checkpoint_policies.nothing_saveable) if remat else period
    x, _ = jax.lax.scan(body, x, params["layers"])
    for i, kind in enumerate(rem_kinds):
        x = apply_block(cfg, kind, params["rem"][str(i)], x, positions)
    return _norm(cfg, x, params["final_norm"])


def train_nll(cfg: ModelConfig, params, batch):
    """batch: tokens (B,S), labels (B,S), optional mask/positions/patch_embeds.
    Returns (sum_nll, token_count)."""
    B = batch["tokens"].shape[0]
    x = _embed_inputs(cfg, params, batch)
    S = x.shape[1]
    positions = _positions(cfg, batch, B, S)
    x = backbone(cfg, params, x, positions)
    n_prefix = x.shape[1] - batch["labels"].shape[1]
    if n_prefix:
        x = x[:, n_prefix:]
    return L.chunked_xent(cfg, params["embed"], x, batch["labels"], batch.get("mask"))


def prefill(cfg: ModelConfig, params, batch, max_seq: int, cache_dtype=None):
    """Run the full prompt, building the decode cache; returns
    (last_token_logits (B,1,V), cache).  Implemented as backbone + cache
    construction via decode-compatible state extraction."""
    B = batch["tokens"].shape[0]
    x = _embed_inputs(cfg, params, batch)
    S = x.shape[1]
    positions = _positions(cfg, batch, B, S)
    cache = make_cache(cfg, B, max_seq, cache_dtype or cfg.compute_dtype)
    P, n_periods, rem_kinds = _layout(cfg)

    def period(carry, xs):
        x = carry
        pslice, cslice = xs
        new_c = {}
        for i in range(P):
            x, new_c[str(i)] = _prefill_block(
                cfg, cfg.pattern[i], pslice[str(i)], x, cslice[str(i)], positions, max_seq
            )
        return x, new_c

    x, new_layer_caches = jax.lax.scan(period, x, (params["layers"], cache["layers"]))
    out_cache = {"layers": new_layer_caches, "t": jnp.asarray(S, jnp.int32)}
    if rem_kinds:
        out_cache["rem"] = {}
        for i, kind in enumerate(rem_kinds):
            x, out_cache["rem"][str(i)] = _prefill_block(
                cfg, kind, params["rem"][str(i)], x, cache["rem"][str(i)], positions, max_seq
            )
    x = _norm(cfg, x, params["final_norm"])
    logits = L.final_logits(cfg, params["embed"], x[:, -1:])
    return logits, out_cache


def _prefill_block(cfg, kind, p, x, cache, positions, max_seq):
    """apply_block + fill this layer's cache from the full-sequence pass."""
    if kind.kind == "attn":
        # recompute k/v once more for cache write (cheap vs attention itself)
        xin = _norm(cfg, x, p["ln1"])
        _, k, v = L._qk(cfg, p["attn"], xin, positions)
        Lc = cache["attn"]["k"].shape[1]
        S = k.shape[1]
        if S >= Lc:  # window (or exactly-full) cache: keep last Lc entries
            new_cache = {
                "k": k[:, S - Lc :].astype(cache["attn"]["k"].dtype),
                "v": v[:, S - Lc :].astype(cache["attn"]["v"].dtype),
            }
            if kind.window and S > Lc:
                # ring-buffer alignment: slot j holds pos with pos % Lc == j
                shift = S % Lc
                new_cache = {
                    kk: jnp.roll(vv, shift, axis=1) for kk, vv in new_cache.items()
                }
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["attn"]["k"], k.astype(cache["attn"]["k"].dtype), 0, axis=1
                ),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["attn"]["v"], v.astype(cache["attn"]["v"].dtype), 0, axis=1
                ),
            }
        return apply_block(cfg, kind, p, x, positions), {"attn": new_cache}
    # recurrent kinds: run the parallel block for outputs, then one scan pass
    # to extract the final state cheaply where possible.
    if kind.kind == "rglru":
        xin = _norm(cfg, x, p["ln1"])
        out, state = _rglru_with_state(cfg, p["mix"], xin)
        x = x + out
        x = x + L.mlp(cfg, p["mlp"], _norm(cfg, x, p["ln2"]))
        return x, {"mix": state}
    if kind.kind in ("mlstm", "slstm"):
        xin = _norm(cfg, x, p["ln1"])
        if kind.kind == "mlstm":
            out, state = _mlstm_with_state(cfg, p["mix"], xin)
        else:
            out, state = _slstm_with_state(cfg, p["mix"], xin)
        return x + out, {"mix": state}
    raise ValueError(kind.kind)


def _rglru_with_state(cfg, p, x):
    out = R.rglru_block(cfg, p, x)
    # final state: rerun last conv inputs; h from scan end. To stay O(S) we
    # recompute the recurrence's final h via a short scan over the sequence.
    B, S, D = x.shape
    cd = cfg.compute_dtype
    u = x.astype(cd) @ p["w_x"].astype(cd)
    W = p["conv_w"].shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    conv_state = pad[:, S : S + W - 1, :]  # last W-1 raw inputs
    uc = sum(pad[:, i : i + S, :] * p["conv_w"][i].astype(cd) for i in range(W)) + p[
        "conv_b"
    ].astype(cd)
    a, x_in = R._rglru_gates(p, uc)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    state = {"h": h[:, -1], "conv": conv_state.astype(x.dtype)}
    return out, state


def _mlstm_with_state(cfg, p, x):
    out = R.mlstm_block(cfg, p, x)
    # final (C, n, m) via a scan over tokens (state extraction only).
    B, S, D = x.shape
    state = R.mlstm_init_state(cfg, B, x.dtype)

    def step(st, i):
        _, st2 = R.mlstm_decode(cfg, p, jax.lax.dynamic_slice_in_dim(x, i, 1, 1), st)
        return st2, None

    state, _ = jax.lax.scan(step, state, jnp.arange(S))
    return out, state


def _slstm_with_state(cfg, p, x):
    B, S, D = x.shape
    state0 = R.slstm_init_state(cfg, B, x.dtype)

    def step(st, xt):
        new = R._slstm_cell(p, xt, st)
        return new, new["h"]

    state, hs = jax.lax.scan(step, state0, jnp.moveaxis(x.astype(jnp.float32), 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, D)
    return R._slstm_out(cfg, p, hs), state


# ---------------------------------------------------------------------------
# Paged decode (block-paged KV pools; DESIGN.md §8)
# ---------------------------------------------------------------------------


def check_paged_support(cfg: ModelConfig) -> None:
    """Paged pools hold absolute-position pages, so every layer must be
    non-windowed attention (windowed dense caches are ring buffers whose
    slot->position map does not survive the page indirection) and RoPE must
    be single-stream."""
    for kind in cfg.layer_kinds:
        if kind.kind != "attn":
            raise ValueError(f"paged decode supports attn-only models, got {kind.kind!r}")
        if kind.window:
            raise ValueError("paged decode does not support sliding-window layers")
    if cfg.mrope_sections is not None:
        raise ValueError("paged decode does not support M-RoPE position streams")


def make_paged_pools(cfg: ModelConfig, num_pages: int, block_size: int, dtype,
                     abstract: bool = False):
    """Flat page pools mirroring the make_cache layer structure: leaves
    (n_periods, num_pages, bs, Hkv, dh) for pattern layers (page 0 is the
    reserved sink).  No "t" leaf — positions live in the engine's per-slot
    context lengths."""
    check_paged_support(cfg)
    P, n_periods, rem_kinds = _layout(cfg)
    fn = L.page_pool_specs if abstract else L.init_page_pool
    pools = {
        "layers": {
            str(i): _stack_cache(
                {"attn": fn(cfg, num_pages, block_size, dtype)}, n_periods, abstract
            )
            for i in range(P)
        }
    }
    if rem_kinds:
        pools["rem"] = {
            str(i): {"attn": fn(cfg, num_pages, block_size, dtype)}
            for i in range(len(rem_kinds))
        }
    return pools


def _scatter_pages(pool_leaf, cache_leaf, table_row, block_size, stacked):
    """Write one slot's dense prefill cache (.., 1, L, Hkv, dh) into its
    table row's pages.  L is ceil-padded to M*bs; overflow blocks land in
    whatever table_row maps them to — the sink for unallocated tails."""
    M = table_row.shape[0]
    c = cache_leaf[:, 0] if stacked else cache_leaf[0]  # (P?, L, Hkv, dh)
    seq_ax = 1 if stacked else 0
    pad = M * block_size - c.shape[seq_ax]
    if pad:
        widths = [(0, 0)] * c.ndim
        widths[seq_ax] = (0, pad)
        c = jnp.pad(c, widths)
    blocks = c.reshape(c.shape[:seq_ax] + (M, block_size) + c.shape[seq_ax + 1 :])
    if stacked:
        return pool_leaf.at[:, table_row].set(blocks.astype(pool_leaf.dtype))
    return pool_leaf.at[table_row].set(blocks.astype(pool_leaf.dtype))


def paged_prefill_write(cfg: ModelConfig, pools, slot_cache, table_row, block_size: int):
    """Scatter a freshly prefilled slot cache (from :func:`prefill` with
    batch=1) into the paged pools along ``table_row`` (M,) int32.  Shared
    prefix pages are rewritten with bit-identical content (KV at position p
    depends only on (token_p, p)), so refcounted sharing stays exact."""
    P, n_periods, rem_kinds = _layout(cfg)
    out = {"layers": {}}
    for i in range(P):
        out["layers"][str(i)] = {
            "attn": {
                kk: _scatter_pages(
                    pools["layers"][str(i)]["attn"][kk],
                    slot_cache["layers"][str(i)]["attn"][kk],
                    table_row, block_size, stacked=True,
                )
                for kk in ("k", "v")
            }
        }
    if rem_kinds:
        out["rem"] = {
            str(i): {
                "attn": {
                    kk: _scatter_pages(
                        pools["rem"][str(i)]["attn"][kk],
                        slot_cache["rem"][str(i)]["attn"][kk],
                        table_row, block_size, stacked=False,
                    )
                    for kk in ("k", "v")
                }
            }
            for i in range(len(rem_kinds))
        }
    return out


def _paged_decode_block(cfg, kind, p, x, pool, block_tables, context_lens, write_block):
    h, new_attn = L.paged_decode_attention(
        cfg, p["attn"], _norm(cfg, x, p["ln1"]), pool["attn"],
        block_tables, context_lens, write_block,
    )
    if cfg.sandwich_norm:
        h = _norm(cfg, h, p["post_ln1"])
    x = x + h
    h_in = _norm(cfg, x, p["ln2"])
    h = M.moe_ffn(cfg, p["mlp"], h_in) if kind.moe else L.mlp(cfg, p["mlp"], h_in)
    if cfg.sandwich_norm:
        h = _norm(cfg, h, p["post_ln2"])
    return x + h, {"attn": new_attn}


def paged_decode_step(cfg: ModelConfig, params, pools, tokens, block_tables,
                      context_lens, write_block):
    """All-slots-jointly decode: tokens (S, 1), block_tables (S, M) int32,
    context_lens (S,) int32 current positions, write_block (S,) int32
    destination pages.  Returns (logits (S, 1, V), new pools).  The shared
    page pools preclude a slot vmap — the slot axis is the batch axis."""
    x = L.embed(cfg, params["embed"], tokens)
    P, n_periods, rem_kinds = _layout(cfg)

    def period(carry, xs):
        x = carry
        pslice, poolslice = xs
        new_p = {}
        for i in range(P):
            x, new_p[str(i)] = _paged_decode_block(
                cfg, cfg.pattern[i], pslice[str(i)], x, poolslice[str(i)],
                block_tables, context_lens, write_block,
            )
        return x, new_p

    x, new_layer_pools = jax.lax.scan(period, x, (params["layers"], pools["layers"]))
    new_pools = {"layers": new_layer_pools}
    if rem_kinds:
        new_pools["rem"] = {}
        for i, kind in enumerate(rem_kinds):
            x, new_pools["rem"][str(i)] = _paged_decode_block(
                cfg, kind, params["rem"][str(i)], x, pools["rem"][str(i)],
                block_tables, context_lens, write_block,
            )
    x = _norm(cfg, x, params["final_norm"])
    logits = L.final_logits(cfg, params["embed"], x)
    return logits, new_pools


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """tokens: (B, 1) -> (logits (B,1,V), new cache). One new position."""
    t = cache["t"]
    x = L.embed(cfg, params["embed"], tokens)
    P, n_periods, rem_kinds = _layout(cfg)

    def period(carry, xs):
        x = carry
        pslice, cslice = xs
        new_c = {}
        for i in range(P):
            x, new_c[str(i)] = decode_block(cfg, cfg.pattern[i], pslice[str(i)], x, cslice[str(i)], t)
        return x, new_c

    x, new_layer_caches = jax.lax.scan(period, x, (params["layers"], cache["layers"]))
    new_cache = {"layers": new_layer_caches, "t": t + 1}
    if rem_kinds:
        new_cache["rem"] = {}
        for i, kind in enumerate(rem_kinds):
            x, new_cache["rem"][str(i)] = decode_block(
                cfg, kind, params["rem"][str(i)], x, cache["rem"][str(i)], t
            )
    x = _norm(cfg, x, params["final_norm"])
    logits = L.final_logits(cfg, params["embed"], x)
    return logits, new_cache
