"""Shared test helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import core


def run_sampler(sampler, params, grad_fn, num_steps, seed=0, collect_from=0):
    """Drive a sampler with exact gradients via lax.scan; return trajectory
    (num_steps, *params.shape) of the param vector."""
    state = sampler.init(params)

    def body(carry, key):
        p, st = carry
        targets = sampler.grad_targets(st, p) if sampler.grad_targets else p
        g = grad_fn(targets)
        upd, st = sampler.update(g, st, params=p, rng=key)
        p = core.apply_updates(p, upd)
        return (p, st), p

    keys = jax.random.split(jax.random.PRNGKey(seed), num_steps)
    (_, _), traj = jax.lax.scan(body, (params, state), keys)
    return np.asarray(traj[collect_from:])


def gaussian_grad(mu, prec=1.0):
    """grad U for N(mu, prec^-1 I): U = 0.5 * prec * ||x - mu||^2.
    Handles a leading chain axis transparently (elementwise)."""

    def grad(theta):
        return prec * (theta - mu)

    return grad


def import_hypothesis():
    """(given, settings, st) — real hypothesis when installed, else no-op
    stubs that mark @given tests as skipped.  Unlike a module-level
    ``pytest.importorskip``, this keeps every DETERMINISTIC test in a
    property-test module running in a bare environment (the kernel-vs-
    reference and codec round-trip checks must not vanish just because
    requirements-dev.txt isn't installed)."""
    try:
        from hypothesis import given, settings, strategies as st

        return given, settings, st
    except ModuleNotFoundError:
        import pytest

        def given(*args, **kwargs):
            del args, kwargs
            return pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")

        def settings(*args, **kwargs):
            del args, kwargs
            return lambda f: f

        class _StrategyStub:
            """st.integers(...) etc. evaluate at decoration time; any
            attribute is a callable returning None."""

            def __getattr__(self, name):
                return lambda *a, **k: None

        return given, settings, _StrategyStub()
