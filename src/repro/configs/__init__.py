"""Architecture registry: ``--arch <id>`` -> ModelConfig (+ SMOKE variant),
the input-shape grid, and per-arch deployment metadata (EC chain counts,
long-context applicability).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

ARCH_IDS = (
    "gemma3-27b",
    "gemma2-27b",
    "h2o-danube-1.8b",
    "qwen3-0.6b",
    "grok-1-314b",
    "olmoe-1b-7b",
    "whisper-base",
    "recurrentgemma-2b",
    "xlstm-350m",
    "qwen2-vl-7b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str, smoke: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# long_500k requires sub-quadratic attention: archs whose layers are all (or
# majority) windowed-local / recurrent run it; pure full-attention archs are
# skipped (recorded in DESIGN.md §Arch-applicability).
LONG_OK = frozenset(
    {"gemma3-27b", "gemma2-27b", "h2o-danube-1.8b", "recurrentgemma-2b", "xlstm-350m"}
)

# EC-SGHMC chain count per arch on the single-pod (16x16) mesh, memory-bound:
# chain axis is carved out of the data axis (chains * per_chain_data = 16).
# Multi-pod runs additionally map chains over the pod axis.
EC_CHAINS = {
    "gemma3-27b": 2,
    "gemma2-27b": 2,
    "h2o-danube-1.8b": 4,
    "qwen3-0.6b": 4,
    "grok-1-314b": 1,  # 314B: one chain fills a pod; EC couples across pods
    "olmoe-1b-7b": 4,
    "whisper-base": 4,
    "recurrentgemma-2b": 4,
    "xlstm-350m": 4,
    "qwen2-vl-7b": 2,
}


def cells(arch: str):
    """The shape cells this arch runs (assignment grid minus documented skips)."""
    out = []
    for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if s == "long_500k" and arch not in LONG_OK:
            continue
        out.append(SHAPES[s])
    return tuple(out)


def all_cells():
    return tuple((a, c) for a in ARCH_IDS for c in cells(a))
