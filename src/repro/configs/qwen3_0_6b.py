"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk-norm, GQA, full attention. [hf:Qwen/Qwen3-8B family]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    vocab_size=151936,
    d_model=1024,
    num_layers=28,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    pattern=(LayerKind("attn"),),
    act="silu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    param_dtype=jnp.float32,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=3,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
