"""Training launcher: EC-SGHMC posterior sampling over any assigned arch.

The step loop is device-resident (``repro.run.ChainExecutor`` via
``train.loop``): whole chunks of sampler steps compile as one scan program,
and the sampler's jit-safe ``stats`` hook is logged at chunk boundaries.

CPU-runnable end-to-end with --smoke (reduced config); the production mesh
path is exercised by dryrun.py.  Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 100 --chains 4 --sync-every 4 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro import obs
from repro.core import tree_broadcast_axis0
from repro.data import synthetic_token_stream
from repro.data.pipeline import chain_batches
from repro.launch.specs import default_sampler, vlm_patches
from repro.models import get_model, init_params
from repro.train.loop import LoopConfig, run
from repro.train.step import make_train_step

log = obs.get_logger("train")


def build_batch_fn(cfg, num_chains: int, per_chain: int, seq_len: int, seed: int = 0):
    sampler = synthetic_token_stream(cfg.vocab_size, seed)

    def fn(step: int):
        batch = chain_batches(sampler, step, num_chains, per_chain, seq_len)
        if cfg.family == "audio":
            key = jax.random.fold_in(jax.random.PRNGKey(seed + 7), step)
            batch["frame_embeds"] = 0.02 * jax.random.normal(
                key, (num_chains, per_chain, cfg.enc_seq, cfg.d_model), jnp.float32
            ).astype(cfg.compute_dtype)
        if cfg.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(seed + 8), step)
            n_patch = vlm_patches(seq_len)
            n_text = seq_len - n_patch
            batch["tokens"] = batch["tokens"][..., :n_text]
            batch["labels"] = batch["labels"][..., :n_text]
            batch["patch_embeds"] = 0.02 * jax.random.normal(
                key, (num_chains, per_chain, n_patch, cfg.d_model), jnp.float32
            ).astype(cfg.compute_dtype)
        return batch

    return fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--chains", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4, help="per-chain batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--step-size", type=float, default=1e-6)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--n-data", type=float, default=100_000,
                    help="corpus size for the N/|B| potential scale")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--preempt-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Perfetto trace.json of the run to PATH")
    args = ap.parse_args(argv)

    tracer, trace_path = obs.configure(args.trace)
    cfg = configs.get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    sampler = default_sampler(cfg, args.arch, args.chains, args.sync_every)
    # override the conservative default step size
    from repro.core import ec_sghmc, sghmc

    if args.chains > 1:
        sampler = ec_sghmc(
            step_size=args.step_size, alpha=args.alpha, sync_every=args.sync_every,
            state_dtype=cfg.param_dtype,
        )
    else:
        sampler = sghmc(step_size=args.step_size, state_dtype=cfg.param_dtype)

    train_step = make_train_step(cfg, model, sampler, n_data=int(args.n_data))
    params1 = init_params(model.param_specs(cfg), jax.random.PRNGKey(args.seed))
    params = tree_broadcast_axis0(params1, args.chains)
    state = sampler.init(params)
    batch_fn = build_batch_fn(cfg, args.chains, args.batch, args.seq, args.seed)

    loop_cfg = LoopConfig(
        num_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        preempt_at=args.preempt_at,
        seed=args.seed,
    )
    params, state, history = run(
        train_step, params, state, batch_fn, loop_cfg,
        num_chains=args.chains, alpha=args.alpha, sampler=sampler,
    )
    if history:
        log.info(f"final nll/token: {history[-1]['nll_per_token']:.4f}")
    if trace_path:
        tracer.export(trace_path)
        log.info(f"trace written to {trace_path} ({len(tracer)} events)")
    return history


if __name__ == "__main__":
    main()
