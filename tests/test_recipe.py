"""Theory-layer tests: Ma et al. complete recipe (Eq. 1-3) and the paper's
claims that SGHMC / EC-SGHMC are valid instances (§1.1.1, Prop. 3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import recipe


def grad_U_gauss(theta):
    return theta  # U = ||theta||^2 / 2, posterior N(0, I)


class TestRecipeValidity:
    def test_sghmc_instance_is_valid(self):
        r = recipe.sghmc_recipe(grad_U_gauss, dim=3, friction=1.0)
        recipe.validate(r)  # D PSD, Q skew-symmetric

    def test_ec_sghmc_instance_is_valid(self):
        """Prop 3.1's D = diag([0, V, 0, C]) and symplectic Q."""
        r = recipe.ec_sghmc_recipe(grad_U_gauss, dim=2, num_chains=3, alpha=0.7)
        recipe.validate(r)

    def test_invalid_q_rejected(self):
        r = recipe.Recipe(grad_U_gauss, D=jnp.eye(2), Q=jnp.eye(2))
        with pytest.raises(ValueError):
            recipe.validate(r)

    def test_invalid_d_rejected(self):
        r = recipe.Recipe(grad_U_gauss, D=-jnp.eye(2), Q=jnp.zeros((2, 2)))
        with pytest.raises(ValueError):
            recipe.validate(r)


class TestRecipeDynamics:
    def test_sghmc_recipe_targets_gaussian(self):
        r = recipe.sghmc_recipe(grad_U_gauss, dim=2, friction=1.0)
        z0 = jnp.zeros(4)
        traj = recipe.simulate(r, z0, eps=5e-2, num_steps=8000, rng=jax.random.PRNGKey(0))
        theta = np.asarray(traj[2000:, :2])
        np.testing.assert_allclose(theta.mean(0), 0.0, atol=0.15)
        np.testing.assert_allclose(theta.var(0), 1.0, atol=0.35)

    def test_ec_recipe_marginal_mean(self):
        K, d = 3, 2
        r = recipe.ec_sghmc_recipe(grad_U_gauss, dim=d, num_chains=K, alpha=0.5)
        m = (K + 1) * d
        z0 = jnp.zeros(2 * m)
        traj = recipe.simulate(r, z0, eps=5e-2, num_steps=6000, rng=jax.random.PRNGKey(1))
        thetas = np.asarray(traj[2000:, : K * d]).reshape(-1, d)
        np.testing.assert_allclose(thetas.mean(0), 0.0, atol=0.2)

    def test_gamma_zero_for_constant_dq(self):
        """Γ_i = Σ_j ∂(D+Q)_ij/∂z_j = 0 for constant matrices — the recipe
        step we implement assumes this; sanity-check the math by finite
        differences of the drift field."""
        r = recipe.sghmc_recipe(grad_U_gauss, dim=1)
        z = jnp.array([0.3, -0.7])
        drift = -(r.D + r.Q) @ r.grad_H(z)
        # For H = theta^2/2 + p^2/2: drift = [p, -theta - V p]
        np.testing.assert_allclose(
            np.asarray(drift), [z[1], -z[0] - z[1]], rtol=1e-6
        )
