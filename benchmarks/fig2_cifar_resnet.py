"""Paper Fig. 2 (right): posterior sampling of a 32-layer residual network
(no batch-norm) on (synthetic) CIFAR-10 — EC-SGHMC speedup over SGHMC at
larger scale.  QUICK mode shrinks width/steps to stay CPU-viable; the full
configuration matches the paper (ResNet-32, width 16)."""
from __future__ import annotations

import time

import jax

from repro import core
from repro.data import synthetic_cifar10
from repro.models import resnet, init_params

from common import QUICK, emit
from posterior_driver import run_sampling, sgd_map

EPS, FRIC = sgd_map(lr=3e-7, beta=0.9)


def run():
    width = 8 if QUICK else 16
    n_train = 4000 if QUICK else 50_000
    steps = 60 if QUICK else 2000
    K = 4 if QUICK else 6
    x, y = synthetic_cifar10(n_train + 1000)
    train, test = (x[:n_train], y[:n_train]), (x[n_train:], y[n_train:])
    specs = resnet.param_specs(width=width)
    init_fn = lambda rng: init_params(specs, rng)
    results = {}
    for name, (sampler, chains) in {
        "sghmc": (core.sghmc(step_size=EPS, friction=FRIC), 1),
        "ec_s4": (core.ec_sghmc(step_size=EPS, friction=FRIC, center_friction=FRIC,
                                alpha=1.0, sync_every=4, noise_convention="eq4",
                                center_noise_in_p=False), K),
    }.items():
        t0 = time.time()
        _, curve = run_sampling(
            resnet.apply, resnet.nll_fn, init_fn, sampler, chains, train, test,
            n_data=n_train, steps=steps, eval_every=max(steps // 5, 5), batch_size=50,
        )
        dt = time.time() - t0
        results[name] = curve[-1]["nll_bma"]
        emit(f"fig2_resnet/{name}_final_nll", 1e6 * dt / steps, f"{curve[-1]['nll_bma']:.4f}")
    ok = results["ec_s4"] <= results["sghmc"] * 1.05
    emit("fig2_resnet/claim_ec_speedup", 0, "CONFIRMED" if ok else "REFUTED")
    return results


if __name__ == "__main__":
    run()
