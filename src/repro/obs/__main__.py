"""``python -m repro.obs <trace.json> [--require PROFILE]`` — the
validation CLI (same surface as ``repro.obs.validate``, without runpy's
re-import warning for the submodule)."""
from repro.obs.validate import main

raise SystemExit(main())
