"""Paged KV-cache gate (DESIGN.md §8): the dense-equivalence differential
harness plus allocator property tests.

Three tiers:

* ``BlockAllocator`` unit + property tests — freelist/refcount/reservation
  invariants under arbitrary admit/grow/release interleavings (hypothesis
  when installed, a deterministic randomized sweep always);
* ``PagedCachePool`` park/restore — raw round-trips bit-exact into fresh
  pages, int8 parking is idempotent after the first lossy pass;
* the engine differential: a paged ``ServeEngine`` must produce tokens
  and (recorded) mixture logprobs equal to the DENSE engine — the oracle
  pinned against the sequential reference elsewhere — across block sizes,
  ragged prompt lengths, prefix-share patterns, EOS/budget slot recycling,
  mid-batch page reuse, and (in the multidevice child) a sharded mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import util

from repro import configs
from repro.models import get_model, init_params
from repro.serve.engine import (
    BlockAllocator,
    PagedCachePool,
    Request,
    ServeEngine,
    synthetic_trace,
)
from repro.serve.sampling import SamplingParams

given, settings, st = util.import_hypothesis()


def tiny_cfg():
    return configs.get_config("qwen3-0.6b", smoke=True).replace(
        vocab_size=64, d_model=32, num_layers=2, num_heads=2, num_kv_heads=1,
        head_dim=16, d_ff=48,
    )


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    model = get_model(cfg)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    members = jax.vmap(lambda k: init_params(model.param_specs(cfg), k))(keys)
    return cfg, model, members


def _requests(lens, max_new=5, stagger=1, vocab=64, seed=0, shared_every=0):
    """Ragged request list; every ``shared_every``-th request reuses the
    first prompt of its length (prefix-share pattern)."""
    rng = np.random.default_rng(seed)
    first: dict[int, np.ndarray] = {}
    reqs = []
    for i, L in enumerate(lens):
        p = rng.integers(0, vocab, size=int(L)).astype(np.int32)
        if L not in first:
            first[L] = p
        elif shared_every and i % shared_every == 0:
            p = first[L].copy()
        reqs.append(Request(rid=i, prompt=p, max_new=max_new,
                            arrival_step=i * stagger))
    return reqs


def _run(cfg, model, members, reqs, **kw):
    eng = ServeEngine(cfg, model, members, record_logprobs=True, **kw)
    rep = eng.run([Request(r.rid, r.prompt.copy(), r.max_new, r.arrival_step)
                   for r in reqs])
    return eng, rep


def _assert_equal_reports(dense, paged, atol=2e-5):
    assert len(dense.results) == len(paged.results)
    for a, b in zip(dense.results, paged.results):
        assert a.rid == b.rid
        np.testing.assert_array_equal(a.tokens, b.tokens, err_msg=f"rid {a.rid}")
        assert a.hit_eos == b.hit_eos and a.truncated == b.truncated
        np.testing.assert_allclose(a.logprobs, b.logprobs, atol=atol,
                                   err_msg=f"rid {a.rid}")


# ---------------------------------------------------------------------------
# BlockAllocator
# ---------------------------------------------------------------------------


class TestBlockAllocator:
    def _alloc(self, **kw):
        base = dict(num_blocks=17, block_size=4, max_seq=32, num_slots=4)
        base.update(kw)
        return BlockAllocator(**base)

    def test_sink_reserved_and_conservation(self):
        a = self._alloc()
        assert a.free_blocks == 16  # page 0 excluded
        row = a.admit(0, np.arange(6, dtype=np.int32), 4)
        assert 0 not in row[row != 0]
        a.check()
        a.release(0)
        assert a.free_blocks == 16
        a.check()

    def test_admit_maps_prompt_blocks_and_reserves_growth(self):
        a = self._alloc()
        a.admit(0, np.arange(6, dtype=np.int32), 8)  # 2 blocks now
        assert int((a.tables[0] != 0).sum()) == 2
        assert a.ctx[0] == 6
        # worst case 6 + 8 - 1 = 13 positions -> 4 blocks, 2 reserved
        assert a.reserved_blocks == 2
        a.check()

    def test_ensure_decode_block_draws_down_reservation(self):
        a = self._alloc()
        a.admit(0, np.arange(4, dtype=np.int32), 5)  # ctx = 4 (block boundary)
        used0, res0 = a.used_blocks, a.reserved_blocks
        a.ensure_decode_block(0)  # position 4 -> new block
        assert a.used_blocks == used0 + 1 and a.reserved_blocks == res0 - 1
        a.ensure_decode_block(0)  # idempotent: same block
        assert a.used_blocks == used0 + 1
        a.check()

    def test_admission_gate_is_exhaustion_proof(self):
        """Every request that passes can_admit decodes to its full max_new
        without ever raising pool-exhausted — the reservation accounting
        charges worst-case growth up front."""
        a = self._alloc(num_blocks=9)  # 8 usable pages, tight
        rng = np.random.default_rng(0)
        live = {}
        admitted = rejected = 0
        for i in range(40):
            if live and rng.random() < 0.4:
                slot = rng.choice(list(live))
                for _ in range(live.pop(slot)):
                    a.ensure_decode_block(slot)
                    a.advance(slot)
                a.release(slot)
            else:
                slot = next((s for s in range(4) if s not in live), None)
                plen, mn = int(rng.integers(1, 9)), int(rng.integers(1, 8))
                if slot is None or not a.can_admit(np.arange(plen), mn):
                    rejected += 1
                    continue
                a.admit(slot, np.arange(plen, dtype=np.int32), mn)
                live[slot] = mn
                admitted += 1
            a.check()
        assert admitted and rejected  # the gate actually bit both ways

    def test_prefix_sharing_refcounts(self):
        a = self._alloc()
        prompt = np.arange(8, dtype=np.int32)  # 2 full blocks
        r0 = a.admit(0, prompt, 4)
        r1 = a.admit(1, prompt.copy(), 4)
        np.testing.assert_array_equal(r0[:2], r1[:2])  # shared pages
        assert a.prefix_hits == 1
        assert all(a.refcount[b] == 2 for b in r0[:2])
        a.release(0)
        assert all(a.refcount[b] == 1 for b in r1[:2])  # survivor keeps them
        a.check()
        a.release(1)
        assert a.free_blocks == 16
        a.check()

    def test_partial_tail_block_not_shared(self):
        a = self._alloc()
        prompt = np.arange(6, dtype=np.int32)  # 1 full + 1 partial block
        r0 = a.admit(0, prompt, 4)
        r1 = a.admit(1, prompt.copy(), 4)
        assert r0[0] == r1[0] and r0[1] != r1[1]
        a.check()

    def test_prefix_entry_dies_with_last_sharer(self):
        a = self._alloc()
        prompt = np.arange(4, dtype=np.int32)
        a.admit(0, prompt, 2)
        a.release(0)
        r1 = a.admit(1, prompt.copy(), 2)  # entry gone -> fresh pages, no hit
        assert a.prefix_hits == 0 and a.prefix_queries == 2
        assert a.refcount[r1[0]] == 1
        a.check()

    def test_sharing_disabled(self):
        a = self._alloc(prefix_sharing=False)
        prompt = np.arange(8, dtype=np.int32)
        r0, r1 = a.admit(0, prompt, 2), a.admit(1, prompt.copy(), 2)
        assert not set(r0[r0 != 0]) & set(r1[r1 != 0])
        assert a.prefix_queries == 0
        a.check()

    def test_version_isolates_prefix_keys(self):
        a = self._alloc()
        prompt = np.arange(8, dtype=np.int32)
        r0 = a.admit(0, prompt, 2, version=0)
        r1 = a.admit(1, prompt.copy(), 2, version=1)  # refreshed members
        assert not set(r0[:2]) & set(r1[:2])
        a.check()

    def test_invalidate_version_drops_stale_entries(self):
        """Promotion-time eager invalidation (the engine calls this on every
        registry version bump): superseded entries vanish immediately, a
        same-prompt re-admit at the old version misses, live sharers keep
        their pages and free them exactly once."""
        a = self._alloc()
        prompt = np.arange(8, dtype=np.int32)  # 2 full blocks
        r0 = a.admit(0, prompt, 2, version=0)
        a.admit(1, prompt.copy(), 2, version=0)  # sharer of the v0 entry
        assert a.prefix_hits == 1
        dropped = a.invalidate_version(1)
        assert dropped == 1 and a.prefix_invalidated == 1
        assert not a._prefix and not a._block_prefix  # no stale residue
        a.check()
        # a v0 re-admit can no longer hit the dead entry
        r2 = a.admit(2, prompt.copy(), 2, version=0)
        assert a.prefix_hits == 1  # still just the pre-invalidation hit
        assert not set(r0[:2]) & set(r2[:2])
        # sharers of the invalidated entry still refcount their pages...
        assert all(a.refcount[b] == 2 for b in r0[:2])
        a.release(0)
        assert all(a.refcount[b] == 1 for b in r0[:2])
        a.check()
        # ...and the pages are freed exactly once, by the last sharer
        a.release(1)
        a.release(2)
        assert a.free_blocks == 16
        a.check()
        # invalidating the current version's own entries is a no-op
        a.admit(0, prompt.copy(), 2, version=1)
        assert a.invalidate_version(1) == 0
        a.check()

    def test_oversized_request_refused(self):
        a = self._alloc()
        assert not a.can_admit(np.arange(30), 8)  # 37 positions > max_seq
        with pytest.raises(ValueError, match="blocks_per_slot"):
            a.admit(0, np.arange(30, dtype=np.int32), 8)

    def test_double_admit_and_bad_release(self):
        a = self._alloc()
        a.admit(0, np.arange(4, dtype=np.int32), 2)
        with pytest.raises(ValueError, match="already admitted"):
            a.admit(0, np.arange(4, dtype=np.int32), 2)
        with pytest.raises(ValueError, match="non-admitted"):
            a.release(3)


class TestAllocatorProperties:
    """Arbitrary operation interleavings preserve every invariant in
    ``BlockAllocator.check``.  The hypothesis variant explores adversarial
    schedules; the deterministic sweep below always runs (tests/util.py
    convention — property modules must not vanish without hypothesis)."""

    @staticmethod
    def _interleave(a: BlockAllocator, ops, lens, max_news):
        """ops: ints; even -> try admit, odd -> advance-or-release."""
        live: dict[int, int] = {}
        for k, op in enumerate(ops):
            if op % 2 == 0:
                slot = next((s for s in range(a.num_slots) if s not in live), None)
                plen = lens[k % len(lens)]
                mn = max_news[k % len(max_news)]
                if slot is not None and a.can_admit(np.arange(plen), mn):
                    a.admit(slot, np.arange(plen, dtype=np.int32), mn)
                    live[slot] = mn
            elif live:
                slot = sorted(live)[op % len(live)]
                if live[slot] > 0 and op % 3:
                    a.ensure_decode_block(slot)
                    a.advance(slot)
                    live[slot] -= 1
                else:
                    a.release(slot)
                    del live[slot]
            a.check()
        for slot in list(live):
            a.release(slot)
        a.check()
        assert a.free_blocks == a.num_blocks - 1  # everything returned

    def test_deterministic_interleavings(self):
        rng = np.random.default_rng(7)
        for trial in range(8):
            a = BlockAllocator(
                num_blocks=int(rng.integers(5, 20)), block_size=int(rng.integers(1, 6)),
                max_seq=16, num_slots=int(rng.integers(1, 5)),
                prefix_sharing=bool(trial % 2),
            )
            self._interleave(
                a, rng.integers(0, 100, size=30).tolist(),
                lens=[1, 3, 4, 8], max_news=[1, 2, 5],
            )

    @given(
        ops=st.lists(st.integers(0, 99), min_size=1, max_size=60),
        num_blocks=st.integers(3, 24),
        block_size=st.integers(1, 5),
        num_slots=st.integers(1, 5),
        sharing=st.booleans(),
    )
    @settings(max_examples=60, deadline=None)
    def test_hypothesis_interleavings(self, ops, num_blocks, block_size,
                                      num_slots, sharing):
        a = BlockAllocator(num_blocks=num_blocks, block_size=block_size,
                           max_seq=16, num_slots=num_slots,
                           prefix_sharing=sharing)
        self._interleave(a, ops, lens=[1, 2, 5, 8], max_news=[1, 3, 6])


# ---------------------------------------------------------------------------
# PagedCachePool park / restore
# ---------------------------------------------------------------------------


class TestPagedCachePool:
    def _pool(self, setup, **kw):
        cfg, model, _ = setup
        return PagedCachePool(cfg, model, num_members=2, num_slots=2,
                              max_seq=32, block_size=8, **kw)

    def _fill_random(self, pool, seed=7):
        pool.caches = jax.tree.map(
            lambda x: jax.random.normal(jax.random.PRNGKey(seed), x.shape, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            pool.caches,
        )

    @staticmethod
    def _gather(pool, slot):
        """One slot's pages in LOGICAL block order (restore relocates)."""
        row = pool.tables[slot]
        idx = jnp.asarray(row[row != 0], jnp.int32)
        return jax.tree.map(
            lambda leaf: np.asarray(jnp.take(leaf, idx, axis=leaf.ndim - 4)),
            pool.caches,
        )

    def test_raw_roundtrip_bit_exact(self, setup):
        pool = self._pool(setup)
        slot = pool.acquire()
        pool.admit_blocks(slot, np.arange(9, dtype=np.int32), 4)
        self._fill_random(pool)
        before = self._gather(pool, slot)
        parked = pool.park(slot)
        assert pool.active_slots == 0 and pool.alloc.used_blocks == 0
        slot2 = pool.restore(parked, max_new=4)
        pool.alloc.check()
        assert pool.alloc.ctx[slot2] == 9
        after = self._gather(pool, slot2)
        jax.tree.map(np.testing.assert_array_equal, before, after)

    def test_int8_roundtrip_idempotent(self, setup):
        pool = self._pool(setup, compress_parked=True)
        slot = pool.acquire()
        pool.admit_blocks(slot, np.arange(9, dtype=np.int32), 4)
        self._fill_random(pool)
        orig = self._gather(pool, slot)
        slot = pool.restore(pool.park(slot), max_new=4)
        once = self._gather(pool, slot)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=0.05), orig, once
        )
        slot = pool.restore(pool.park(slot), max_new=4)
        twice = self._gather(pool, slot)
        # second lossy pass lands on the same quantization points: bit-exact
        jax.tree.map(np.testing.assert_array_equal, once, twice)

    def test_restore_reserves_remaining_growth(self, setup):
        pool = self._pool(setup)
        slot = pool.acquire()
        pool.admit_blocks(slot, np.arange(8, dtype=np.int32), 9)  # 1 block now
        parked = pool.park(slot)
        slot2 = pool.restore(parked, max_new=9)
        # 8 + 9 - 1 = 16 positions -> 2 blocks total, 1 held, 1 re-reserved
        assert pool.alloc._reserved[slot2] == 1
        pool.alloc.check()

    def test_stats_report_paged_memory(self, setup):
        pool = self._pool(setup)
        slot = pool.acquire()
        pool.admit_blocks(slot, np.arange(16, dtype=np.int32), 2)
        s = pool.stats()
        assert s["paged"] and s["bytes_per_page"] > 0
        assert s["bytes_used"] == s["blocks_used"] * s["bytes_per_page"]
        assert s["bytes_high_water"] >= s["bytes_used"]
        assert s["bytes_total"] == (s["num_blocks"] - 1) * s["bytes_per_page"]

    def test_unsupported_model_refused(self, setup):
        cfg, model, _ = setup
        import dataclasses

        windowed = cfg.replace(pattern=(dataclasses.replace(cfg.pattern[0], window=8),))
        with pytest.raises(ValueError, match="sliding-window"):
            PagedCachePool(windowed, model, num_members=1, num_slots=1,
                           max_seq=16, block_size=8)


# ---------------------------------------------------------------------------
# engine differential: paged == dense
# ---------------------------------------------------------------------------


class TestPagedEngineDifferential:
    @pytest.mark.parametrize("block_size", [4, 8, 16])
    def test_ragged_lengths_match_dense(self, setup, block_size):
        cfg, model, members = setup
        reqs = _requests((3, 8, 5, 13, 16, 7), max_new=6, stagger=1, seed=1)
        _, dense = _run(cfg, model, members, reqs, num_slots=2, max_seq=32)
        eng, paged = _run(cfg, model, members, reqs, num_slots=2, max_seq=32,
                          paged=True, block_size=block_size)
        _assert_equal_reports(dense, paged)
        assert eng.decode_trace_count == 1, paged.trace_counts
        eng.pool.alloc.check()
        assert eng.pool.alloc.used_blocks == 0  # all pages returned

    @pytest.mark.parametrize("sharing", [True, False])
    def test_prefix_share_patterns_match_dense(self, setup, sharing):
        cfg, model, members = setup
        # every other request repeats an earlier prompt -> live page sharing
        reqs = _requests((8, 8, 16, 8, 16, 8), max_new=5, stagger=1, seed=2,
                         shared_every=2)
        _, dense = _run(cfg, model, members, reqs, num_slots=3, max_seq=32)
        eng, paged = _run(cfg, model, members, reqs, num_slots=3, max_seq=32,
                          paged=True, block_size=8, prefix_sharing=sharing)
        _assert_equal_reports(dense, paged)
        st = eng.pool.stats()
        if sharing:
            assert st["prefix_hits"] > 0  # the pattern actually shared
        else:
            assert st["prefix_queries"] == 0
        eng.pool.alloc.check()

    def test_eos_recycling_matches_dense(self, setup):
        """Slots finish at different ticks (EOS + ragged budgets), freeing
        pages that later admissions reuse mid-batch."""
        cfg, model, members = setup
        reqs = _requests((5, 9, 4, 12, 6, 8, 10), max_new=7, stagger=2, seed=3)
        kw = dict(num_slots=2, max_seq=32, eos_id=3)
        _, dense = _run(cfg, model, members, reqs, **kw)
        eng, paged = _run(cfg, model, members, reqs, paged=True, block_size=4, **kw)
        _assert_equal_reports(dense, paged)
        assert eng.decode_trace_count == 1

    def test_tight_pool_defers_admission_but_completes(self, setup):
        """A page pool too small for all slots at once: head-of-line waits
        for completions, every request still finishes, and the admission
        gate never lets decode hit pool exhaustion."""
        cfg, model, members = setup
        reqs = _requests((8, 8, 8, 8), max_new=5, stagger=0, seed=4)
        # 7 usable pages; each request needs 3 worst-case -> 2 concurrent max
        eng, paged = _run(cfg, model, members, reqs, num_slots=3, max_seq=32,
                          paged=True, block_size=4, num_blocks=8)
        assert sorted(r.rid for r in paged.results) == [0, 1, 2, 3]
        assert all(r.num_tokens == 5 for r in paged.results)
        _, dense = _run(cfg, model, members, reqs, num_slots=3, max_seq=32)
        for a, b in zip(dense.results, paged.results):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        eng.pool.alloc.check()

    def test_impossible_request_raises_not_deadlocks(self, setup):
        cfg, model, members = setup
        reqs = _requests((8,), max_new=5)
        eng = ServeEngine(cfg, model, members, num_slots=2, max_seq=32,
                          paged=True, block_size=4, num_blocks=3)
        with pytest.raises(ValueError, match="can never fit"):
            eng.run(reqs)

    def test_truncation_recycles_pages(self, setup):
        cfg, model, members = setup
        reqs = _requests((6, 11), max_new=10, stagger=0, seed=5)
        eng, rep = _run(cfg, model, members, reqs, num_slots=2, max_seq=32,
                        paged=True, block_size=8)
        # rerun with a hard step cap: in-flight requests truncate, pages free
        eng2 = ServeEngine(cfg, model, members, num_slots=2, max_seq=32,
                           paged=True, block_size=8, record_logprobs=True)
        rep2 = eng2.run([Request(r.rid, r.prompt.copy(), r.max_new, r.arrival_step)
                         for r in reqs], max_steps=4)
        assert all(r.truncated for r in rep2.results)
        assert eng2.pool.alloc.used_blocks == 0
        eng2.pool.alloc.check()
        # truncated prefixes match the untruncated run (same tokens early on)
        by_rid = {r.rid: r for r in rep.results}
        for r in rep2.results:
            np.testing.assert_array_equal(r.tokens, by_rid[r.rid].tokens[: r.num_tokens])

    def test_recycled_blocks_mid_batch_regression(self, setup):
        """Satellite regression: a done slot keeps computing until its slot
        is re-admitted, and its garbage decode writes MUST land in the sink
        page — not in pages recycled to a still-live request.  A tiny pool
        forces immediate reuse of freed pages while the other slot decodes."""
        cfg, model, members = setup
        reqs = _requests((4, 8, 4, 4), max_new=(3), stagger=0, seed=6)
        reqs = [Request(r.rid, r.prompt, 3 + 2 * (r.rid % 2), r.arrival_step)
                for r in reqs]
        kw = dict(num_slots=2, max_seq=16)
        _, dense = _run(cfg, model, members, reqs, **kw)
        eng, paged = _run(cfg, model, members, reqs, paged=True, block_size=4,
                          num_blocks=9, **kw)
        _assert_equal_reports(dense, paged)
        eng.pool.alloc.check()

    def test_sampled_fused_select_matches_unfused(self, setup):
        """The fused mixture+selection kernel is a drop-in: identical token
        draws (Gumbel-argmax identity, same key) on the paged engine."""
        cfg, model, members = setup
        reqs = _requests((7, 13, 9, 16), max_new=5, stagger=2, seed=8)
        sp = SamplingParams(temperature=0.9, top_k=8)
        kw = dict(num_slots=2, max_seq=32, paged=True, block_size=8,
                  sampling=sp, seed=11)
        _, a = _run(cfg, model, members, reqs, fused_select=False, **kw)
        _, b = _run(cfg, model, members, reqs, fused_select=True, **kw)
        for x, y in zip(a.results, b.results):
            np.testing.assert_array_equal(x.tokens, y.tokens)
            np.testing.assert_allclose(x.logprobs, y.logprobs, atol=1e-5)

    def test_paged_memory_beats_dense_at_equal_tokens(self, setup):
        """The acceptance axis the bench records: for the same trace, the
        paged pool's high-water bytes stay below the dense pool's static
        footprint (which pays max_seq for every slot up front)."""
        cfg, model, members = setup
        reqs = _requests((8, 8, 8, 8, 8, 8), max_new=4, stagger=1, seed=9,
                         shared_every=2)
        deng, dense = _run(cfg, model, members, reqs, num_slots=3, max_seq=32)
        peng, paged = _run(cfg, model, members, reqs, num_slots=3, max_seq=32,
                           paged=True, block_size=8)
        assert dense.total_tokens == paged.total_tokens
        dense_bytes = sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(deng.pool.caches)
        )
        assert peng.pool.stats()["bytes_high_water"] < dense_bytes


# ---------------------------------------------------------------------------
# mesh-sharded paged engine (multidevice child only)
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
class TestShardedPagedServeEngine:
    """DESIGN.md §7 x §8: the paged engine under a device mesh — tokens
    identical to the unsharded paged run (itself pinned to dense above),
    and still exactly one compiled decode program across block-table churn."""

    def test_mesh_paged_matches_unsharded_one_program(self, setup):
        util.require_devices(util.MULTIDEVICE_DEVICES)
        from repro.launch.mesh import make_engine_mesh

        cfg, model, members = setup
        reqs = _requests((5, 9, 7, 12, 6), max_new=5, stagger=1, seed=10)
        kw = dict(num_slots=2, max_seq=32, paged=True, block_size=8)
        _, rep0 = _run(cfg, model, members, reqs, **kw)
        eng, rep1 = _run(cfg, model, members, reqs,
                         mesh=make_engine_mesh(2, 4), **kw)
        assert eng.decode_trace_count == 1, rep1.trace_counts
        _assert_equal_reports(rep0, rep1)
        eng.pool.alloc.check()
