"""Sharding-rule unit tests: divisibility fallback, axis-reuse, priority."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # 8 host devices arranged as a mini production mesh analog
    devs = np.array(jax.devices()[:1] * 8).reshape(2, 4) if len(jax.devices()) < 8 else None
    if devs is not None:
        pytest.skip("needs >= 8 devices (covered by dryrun smoke)")
    return jax.make_mesh((2, 4), ("data", "model"))


class TestBuildSpecSingleDevice:
    """Pure-logic tests via a fabricated mesh shape (no real devices)."""

    def _mesh(self):
        import os
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_divisibility_fallback(self):
        mesh = self._mesh()
        # model axis size 1 always divides — spec granted
        spec = shd.build_spec((16, 8), ("embed", "heads"), {"embed": "data", "heads": "model"}, mesh)
        assert spec == P("data", "model")

    def test_axis_reuse_blocked(self):
        mesh = self._mesh()
        spec = shd.build_spec(
            (16, 8), ("embed", "mlp"), {"embed": "model", "mlp": "model"}, mesh
        )
        # mlp has priority over embed; embed must NOT reuse "model"
        assert spec == P(None, "model")

    def test_priority_kv_heads_over_seq(self):
        mesh = self._mesh()
        spec = shd.build_spec(
            (4, 128, 8, 64),
            ("batch", "kvseq", "kv_heads", None),
            {"batch": "data", "kvseq": "model", "kv_heads": "model"},
            mesh,
        )
        # kv_heads claims "model" first (priority), kvseq falls back
        assert spec == P("data", None, "model", None)

    def test_tuple_rules(self):
        mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
        spec = shd.build_spec((32,), ("embed",), {"embed": ("pod", "data")}, mesh)
        assert spec == P(("pod", "data"))

    def test_indivisible_dim_replicates(self):
        mesh = jax.make_mesh((1, 2) if len(jax.devices()) >= 2 else (1, 1), ("data", "model"))
        if mesh.shape["model"] == 1:
            pytest.skip("single device")
        spec = shd.build_spec((7,), ("heads",), {"heads": "model"}, mesh)
        assert spec == P(None)
