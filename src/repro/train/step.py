"""train_step builder: model + sampler -> one posterior-sampling step.

Params/grads carry a leading chain axis K (EC-SGHMC); the model forward is
vmapped over it.  Because chains are independent in the likelihood, the
gradient of the *summed* potential yields exactly the per-chain gradients.
The elastic-coupling collective lives inside ``sampler.update``.

Two layers, both consumed by ``repro.run.ChainExecutor``:

* ``make_grad_fn`` — ``(targets, batch) -> (grads, metrics)``: the piece
  an executor in sampler mode scans (gradients evaluated wherever
  ``Sampler.grad_targets`` points, e.g. stale worker snapshots) — pass it
  as ``ChainExecutor(sampler=..., grad_fn=make_grad_fn(...))``;
* ``make_train_step`` — the classic fused step
  ``(params, state, batch, rng) -> (params, state, metrics)`` built from
  the same grad_fn (and honoring ``grad_targets`` itself), for the
  executor's ``step_fn`` mode (what ``train/loop.py`` runs) and for tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import apply_updates, gaussian_prior
from repro.models import ModelDef
from repro.models.common import ModelConfig


def make_grad_fn(
    cfg: ModelConfig,
    model: ModelDef,
    n_data: int,
    weight_decay: float = 1e-5,
):
    """Gradient-of-potential closure: (targets, batch) -> (grads, metrics)."""
    prior = gaussian_prior(weight_decay)

    def potential(params, batch):
        def per_chain(p, b):
            sum_nll, count = model.train_nll(cfg, p, b)
            scale = jnp.float32(n_data) / jnp.maximum(count, 1.0)
            return scale * sum_nll + prior.energy(p), (sum_nll, count)

        u, aux = jax.vmap(per_chain)(params, batch)
        return jnp.sum(u), aux

    def grad_fn(targets, batch):
        (u, (sum_nll, count)), grads = jax.value_and_grad(potential, has_aux=True)(
            targets, batch
        )
        metrics = {
            "potential": u,
            "nll_per_token": jnp.sum(sum_nll) / jnp.maximum(jnp.sum(count), 1.0),
        }
        return grads, metrics

    return grad_fn


def make_train_step(
    cfg: ModelConfig,
    model: ModelDef,
    sampler,
    n_data: int,
    weight_decay: float = 1e-5,
):
    grad_fn = make_grad_fn(cfg, model, n_data, weight_decay)

    def train_step(params, state, batch, rng):
        targets = sampler.grad_targets(state, params) if sampler.grad_targets else params
        grads, metrics = grad_fn(targets, batch)
        updates, new_state = sampler.update(grads, state, params, rng)
        return apply_updates(params, updates), new_state, metrics

    return train_step
