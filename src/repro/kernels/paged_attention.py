"""Paged-attention decode Pallas kernel: one query token per sequence
against a block-paged KV cache (DESIGN.md §8).

The cache is a flat pool of fixed-size pages ``(num_pages, block_size,
Hkv, d)``; each sequence owns an int32 block-table row mapping its logical
KV blocks to pool pages.  Both the table ``(B, M)`` and the inclusive
context positions ``(B,)`` ride in through
``pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=2)`` so the k/v
BlockSpec index maps can chase ``tab[b, j]`` — page indirection costs a
scalar lookup at grid-index time, not a gather in the kernel body.

TPU-native design mirrors ``flash_attention.py``:
  * grid (B, Hkv, M) with the block axis innermost ("arbitrary") carrying
    online-softmax state (m/l lane-replicated, acc (G, d)) in VMEM,
  * whole irrelevant pages are SKIPPED via ``pl.when`` — a sequence at
    context length c touches ceil((c+1)/bs) pages, not M,
  * GQA is laid out as (B, Hkv, G, d) queries so each page is fetched once
    per kv-head and hit by all G query heads on the MXU,
  * optional sliding window (page skip + in-page mask) and logit softcap.

Validated in interpret mode on CPU against ``ref.paged_attention``;
compiled on real TPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flash_attention import _CompilerParams, NEG_INF


def _paged_kernel(
    tab_ref, ctx_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, window, softcap, bs, num_blocks,
):
    b = pl.program_id(0)
    j = pl.program_id(2)  # logical kv block (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ctx = ctx_ref[b]  # inclusive current position: valid kpos <= ctx
    relevant = j * bs <= ctx
    if window is not None:
        relevant &= j * bs + bs - 1 >= ctx - window + 1

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, d)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, d)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, bs)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= ctx
        if window is not None:
            mask &= (ctx - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]  # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_scr[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == num_blocks - 1)
    def _finish():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_attention(
    q, k_pages, v_pages, block_tables, context_lens,
    *, scale=None, window=None, softcap=None, interpret: bool = True,
):
    """Single-token decode over a paged KV pool.

    q: (B, Hkv, G, d) current-position queries; k_pages/v_pages:
    (num_pages, block_size, Hkv, d); block_tables: (B, M) int32 page ids;
    context_lens: (B,) int32 INCLUSIVE current position (the token being
    decoded sits at kpos == context_lens[b], already written to its page).
    Returns (B, Hkv, G, d).
    """
    B, Hkv, G, d = q.shape
    _, bs, _, _ = k_pages.shape
    M = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _paged_kernel,
        scale=scale, window=window, softcap=softcap, bs=bs, num_blocks=M,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, M),
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, h, j, tab, ctx: (b, h, 0, 0)),
            # the indirection: logical block j of sequence b lives at page
            # tab[b, j] — resolved in the index map from the prefetched table
            pl.BlockSpec((1, bs, 1, d), lambda b, h, j, tab, ctx: (tab[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda b, h, j, tab, ctx: (tab[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, h, j, tab, ctx: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),  # m (lane-replicated)
            pltpu.VMEM((G, 128), jnp.float32),  # l
            pltpu.VMEM((G, d), jnp.float32),  # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pages, v_pages)
