"""Adaptive sampler tier on an ill-conditioned Gaussian (ROADMAP item 4).

Target: diagonal Gaussian with precisions log-spaced over [0.04, 100]
(condition number 2500).  A single global step size must respect the STIFF
dims (stability ~ ε·√λmax < O(1) for the underdamped samplers, ε·λmax for
SGLD), so every other dim mixes at a rate suppressed by λmax.  The diagonal
preconditioner (frozen M⁻¹ ≈ λ^(-1/2) under eq4 noise, see DESIGN.md §6)
flattens the per-dim frequencies to λ^(1/4), raising the stable step budget
by λmax^(1/4) (λmax^(1/2) for SGLD) — which is what ESS/sec measures here:

  * ``preconditioned EC-SGHMC`` vs plain ``ec_sghmc`` at each sampler's own
    near-stability step size (the ISSUE-6 acceptance comparison);
  * ``preconditioned_sgld`` vs plain ``sgld``, same protocol;
  * a ``FeedbackESS`` demo: the controller grows a deliberately timid ε
    toward the stability budget from in-carry streaming ESS alone.

Where the win lives: with the FD-consistent friction (damping rate εVM⁻¹,
the form the exact oracle gates), the overdamped relaxation rate λ/V is
MASS-INDEPENDENT, so preconditioning cannot speed up dims that are already
friction-dominated — the decisive gain is on the worst-mixing (softest and
stiffest-limited) dims via the larger stable ε.  The gate therefore
compares worst-dim ESS/sec for the EC pair (total ESS/sec is reported but
dominated by fast dims both samplers handle) and both metrics for SGLD,
where the drift IS preconditioned and the total-ESS win is unambiguous.

Execution follows fig1: each sampler is one device-resident
``ChainExecutor`` program, compiled once and re-run for the measurement, so
wall times are compute, not tracing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro import diagnostics as diag
from repro.run import ChainExecutor, ess_feedback_adapter

from common import QUICK, emit, record

D = 8
K = 4
LAM = jnp.logspace(jnp.log10(0.04), jnp.log10(100.0), D).astype(jnp.float32)
MU = 0.5
STEPS = 6_000 if QUICK else 24_000
BURN = 1_000  # preconditioner freeze AND measurement cut, both samplers
LMAX = float(LAM[-1])

# shared EC configuration — the stationary-battery regime (eq4 noise keeps
# the frozen M⁻¹ ≈ λ^(-1/2), see tests/test_stationary.py)
EC_KW = dict(alpha=1.0, friction=1.0, center_friction=1.0, sync_every=4,
             noise_convention="eq4", center_noise_in_p=False)


def grad_U(theta):
    return LAM * (theta - MU)


def _measure(sampler, shape, seed):
    """Compile once, then median-of-3 timed runs (wall noise on a shared CPU
    would otherwise swamp a 10–20%% ESS/sec edge); ESS from the final run.
    Worst-dim ESS is floored at 1.0 — the FFT estimator degenerates below
    one effective sample, and a chain always contains at least one."""
    ex = ChainExecutor(sampler=sampler, grad_fn=lambda t, _b: grad_U(t),
                       trace_fn=lambda p: p, chunk_steps=STEPS, key_mode="keys")
    keys = jax.random.split(jax.random.PRNGKey(seed), STEPS)

    def go():
        p0 = jnp.zeros(shape, jnp.float32)
        return ex.run(p0, sampler.init(p0), num_steps=STEPS, keys=keys)

    go()  # compile
    walls, res = [], None
    for _ in range(3):
        res = go()
        walls.append(res.wall_s)
    wall = float(np.median(walls))
    traj = np.moveaxis(np.asarray(res.trace)[BURN:], 0, 1)  # (K, T', D)
    per_dim = np.asarray(diag.effective_sample_size_nd(traj))  # (D,) pooled
    return {
        "wall_s": wall,
        "ess": float(np.sum(per_dim)),
        "ess_min": max(float(np.min(per_dim)), 1.0),
        "ess_per_s": float(np.sum(per_dim)) / wall,
        "min_ess_per_s": max(float(np.min(per_dim)), 1.0) / wall,
    }


def run():
    # -- EC-SGHMC: plain vs preconditioned --------------------------------
    eps_plain = 0.3 / np.sqrt(LMAX)  # stiff-dim stability budget
    eps_pre = 0.3 / LMAX ** 0.25  # budget after M⁻¹ ≈ λ^(-1/2) flattening
    plain = core.ec_sghmc(step_size=float(eps_plain), **EC_KW)
    pre = core.scale_adapted_ec_sghmc(step_size=float(eps_pre), burnin=BURN,
                                      decay=0.99, **EC_KW)
    ec = _measure(plain, (K, D), seed=0)
    sa = _measure(pre, (K, D), seed=1)
    emit("adaptive/ec_sghmc_ess_per_s", 1e6 * ec["wall_s"] / STEPS,
         f"{ec['ess_per_s']:.1f}")
    emit("adaptive/sa_ec_sghmc_ess_per_s", 1e6 * sa["wall_s"] / STEPS,
         f"{sa['ess_per_s']:.1f}")
    emit("adaptive/sa_ec_speedup", 1e6 * sa["wall_s"] / STEPS,
         f"{sa['ess_per_s'] / max(ec['ess_per_s'], 1e-9):.2f}x")
    emit("adaptive/sa_ec_worst_dim_speedup", 1e6 * sa["wall_s"] / STEPS,
         f"{sa['min_ess_per_s'] / max(ec['min_ess_per_s'], 1e-9):.2f}x")

    # -- SGLD: plain vs preconditioned ------------------------------------
    eps_sgld = 0.3 / LMAX  # overdamped stability ~ ε·λmax
    eps_psgld = 0.3 / np.sqrt(LMAX)
    sg = _measure(core.sgld(step_size=float(eps_sgld)), (K, D), seed=2)
    ps = _measure(
        core.preconditioned_sgld(step_size=float(eps_psgld), burnin=BURN, decay=0.99),
        (K, D), seed=3)
    emit("adaptive/sgld_ess_per_s", 1e6 * sg["wall_s"] / STEPS,
         f"{sg['ess_per_s']:.1f}")
    emit("adaptive/psgld_ess_per_s", 1e6 * ps["wall_s"] / STEPS,
         f"{ps['ess_per_s']:.1f}")
    emit("adaptive/psgld_speedup", 1e6 * ps["wall_s"] / STEPS,
         f"{ps['ess_per_s'] / max(sg['ess_per_s'], 1e-9):.2f}x")
    emit("adaptive/psgld_worst_dim_speedup", 1e6 * ps["wall_s"] / STEPS,
         f"{ps['min_ess_per_s'] / max(sg['min_ess_per_s'], 1e-9):.2f}x")

    # the acceptance gate (see module docstring for why the EC pair is
    # judged on the worst-mixing dim): preconditioning must win worst-dim
    # ESS/sec on both pairs, and total ESS/sec where the drift itself is
    # preconditioned (SGLD)
    ok = (sa["min_ess_per_s"] > ec["min_ess_per_s"]
          and ps["min_ess_per_s"] > sg["min_ess_per_s"]
          and ps["ess_per_s"] > sg["ess_per_s"])
    emit("adaptive/claim_preconditioning_wins_ess_per_s",
         1e6 * (sa["wall_s"] + ec["wall_s"]) / (2 * STEPS),
         "CONFIRMED" if ok else "REFUTED")

    # -- FeedbackESS demo: grow a timid ε from streaming ESS --------------
    controller = core.feedback_ess(float(eps_plain) / 10.0, target_ess_rate=0.25,
                                   gain=0.5, bounds=(0.1, 20.0))
    ex = ChainExecutor(
        sampler_factory=lambda h: core.sghmc(step_size=h["step_size"], friction=1.0),
        grad_fn=lambda t, _b: grad_U(t), chunk_steps=512, key_mode="keys",
        ess_probe_fn=lambda p: p[0], ess_batch_len=64,
    )
    n_fb = 4_096
    keys = jax.random.split(jax.random.PRNGKey(4), n_fb)
    p0 = jnp.zeros((K, D), jnp.float32)
    eps_path = [controller.value]
    res = ex.run(p0, core.sghmc(step_size=controller.eps0, friction=1.0).init(p0),
                 num_steps=n_fb, keys=keys,
                 hyper={"step_size": jnp.asarray(controller.eps0, jnp.float32)},
                 sweep=False,
                 adapt_fn=(lambda inner: lambda s, c, h:
                           (eps_path.append(controller.value), inner(s, c, h))[1])(
                               ess_feedback_adapter(controller)))
    assert res.steps == n_fb
    emit("adaptive/feedback_eps_growth", 1e6 * res.wall_s / n_fb,
         f"{controller.value / controller.eps0:.2f}x")

    record("adaptive", {
        "ec_sghmc": {"eps": float(eps_plain), **ec},
        "sa_ec_sghmc": {"eps": float(eps_pre), **sa},
        "sgld": {"eps": float(eps_sgld), **sg},
        "psgld": {"eps": float(eps_psgld), **ps},
        "feedback": {"eps0": controller.eps0, "eps_final": controller.value,
                     "eps_path": [float(e) for e in eps_path]},
        "config": {"d": D, "chains": K, "steps": STEPS, "burnin": BURN,
                   "cond": float(LAM[-1] / LAM[0]), "quick": QUICK, **{
                       k: v for k, v in EC_KW.items()}},
    })
    return {
        "sa_ec_speedup": sa["ess_per_s"] / max(ec["ess_per_s"], 1e-9),
        "sa_ec_worst_dim_speedup": sa["min_ess_per_s"] / max(ec["min_ess_per_s"], 1e-9),
        "psgld_speedup": ps["ess_per_s"] / max(sg["ess_per_s"], 1e-9),
        "psgld_worst_dim_speedup": ps["min_ess_per_s"] / max(sg["min_ess_per_s"], 1e-9),
        "feedback_growth": controller.value / controller.eps0,
        "preconditioning_wins": ok,
    }


if __name__ == "__main__":
    run()
