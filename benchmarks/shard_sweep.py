"""Multi-device scale-out sweep (DESIGN.md §7): ``run_sharded`` on a
(chain,) mesh at every device count in {1, 2, 4, 8}, raw vs int8-compressed
center exchange.

Each device count runs in its OWN subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag must be
set before jax picks a backend, and this parent has usually already locked
one) — ``repro.launch.mesh.forced_device_env`` builds the environment, the
same fallback the multidevice test harness uses.  On a real multi-device
install the forced flag is inert surplus and the children see the actual
accelerators.

Recorded per (device count, mode): steps/s of the compiled sharded program
and the per-device sync wire payload of one s-periodic center exchange
(``sync_wire_bytes``) — the compressed path's ~4x smaller operand is the
point of the packed int8 all_gather.  CPU-forced devices share one socket,
so QUICK steps/s across device counts measures overhead, not speedup; the
wire-bytes column is the hardware-independent signal.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

from common import QUICK, emit, record

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.distributed import sync_wire_bytes  # noqa: E402
from repro.launch.mesh import forced_device_env  # noqa: E402

K = 8
D = 16_384 if QUICK else 262_144
STEPS = 256 if QUICK else 2_048
SYNC = 4
DEVICE_COUNTS = (1, 2, 4, 8)

_CHILD = textwrap.dedent(
    """
    import sys
    import jax, jax.numpy as jnp, numpy as np
    n, D, steps, sync = map(int, sys.argv[1:5])
    assert jax.device_count() >= n, (jax.device_count(), n)
    from repro import core
    from repro.distributed import int8_codec
    from repro.run import ChainExecutor

    K = 8
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:n]), ("chain",))
    mu = jnp.zeros((D,), jnp.float32)
    params0 = 0.1 * jax.random.normal(jax.random.PRNGKey(0), (K, D), jnp.float32)
    for mode in ("raw", "compressed"):
        sampler = core.ec_sghmc(
            step_size=1e-3, alpha=1.0, sync_every=sync, noise_convention="eq6",
            chain_axis="chain", per_chain_noise=True,
            compression=int8_codec() if mode == "compressed" else None)
        ex = ChainExecutor(sampler=sampler, grad_fn=lambda t, _b: t - mu,
                           chunk_steps=steps, key_mode="fold")
        # first call compiles; the second re-runs the cached executable so
        # steps_per_s measures compute
        ex.run_sharded(params0 + 0.0, sampler.init(params0), num_steps=steps,
                       key=jax.random.key(0), mesh=mesh)
        res = ex.run_sharded(params0 + 0.0, sampler.init(params0), num_steps=steps,
                             key=jax.random.key(0), mesh=mesh)
        ok = bool(np.all(np.isfinite(np.asarray(res.params))))
        print(f"RESULT devices={n} mode={mode} steps_per_s={res.steps_per_s:.2f} "
              f"ok={ok}", flush=True)
    """
)


def _child_env(n: int) -> dict:
    env = forced_device_env(n)
    env["PYTHONPATH"] = os.pathsep.join(
        p
        for p in (str(Path(__file__).resolve().parent.parent / "src"), env.get("PYTHONPATH"))
        if p
    )
    return env


def run():
    rows = []
    for n in DEVICE_COUNTS:
        out = subprocess.run(
            [sys.executable, "-c", _CHILD, str(n), str(D), str(STEPS), str(SYNC)],
            env=_child_env(n),
            capture_output=True,
            text=True,
            timeout=1200,
        )
        if out.returncode != 0:
            raise RuntimeError(f"shard child (n={n}) failed:\n{out.stderr[-3000:]}")
        for line in out.stdout.splitlines():
            if not line.startswith("RESULT"):
                continue
            kv = dict(p.split("=") for p in line.split()[1:])
            assert kv["ok"] == "True", line
            mode = kv["mode"]
            sps = float(kv["steps_per_s"])
            wire = sync_wire_bytes(D, compressed=(mode == "compressed"))
            emit(f"shard_{mode}_dev{n}", 1e6 / max(sps, 1e-9), f"{sps:.1f} steps/s")
            rows.append(
                {
                    "devices": n,
                    "mode": mode,
                    "steps_per_s": round(sps, 2),
                    "sync_wire_bytes_per_device": wire,
                    "syncs_per_run": STEPS // SYNC,
                }
            )
    raw = sync_wire_bytes(D, compressed=False)
    comp = sync_wire_bytes(D, compressed=True)
    record(
        "shard_sweep",
        {
            "num_chains": K,
            "num_params": D,
            "steps": STEPS,
            "sync_every": SYNC,
            "device_counts": list(DEVICE_COUNTS),
            "wire_compression_ratio": round(comp / raw, 4),
            "rows": rows,
        },
    )
    return {"wire_ratio": round(comp / raw, 4), "device_counts": len(DEVICE_COUNTS)}
