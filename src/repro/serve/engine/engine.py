"""Posterior-predictive serving engine: continuous batching over a fixed
slot axis, Bayesian model averaging over K ensemble members, live snapshot
refresh from the coupled sampler.

The structural invariant (pinned by ``tests/test_serve_engine.py``): the
decode hot path is ONE compiled program.  Its signature is
``(members (K,...), pooled caches (K, S, ...), tokens (S,1), done (S,),
budget (S,), key)`` — every quantity that changes as requests join, finish,
or the ensemble refreshes is *data* (masks, slot-indexed writes, swapped
member pytrees of identical shape), never a shape.  Admission compiles once
per distinct prompt length (prefill is length-shaped by nature; bucket
prompts upstream if that matters), and writes the new request's K member
caches into its slot with a traced slot index.

Per-slot decode runs as ``vmap(member) ∘ vmap(slot)`` over the model's
single-stream ``decode_step``, which gives every slot its own cache time
pointer ``t`` — the property continuous batching needs and the batched
legacy path lacked (one scalar ``t`` for the whole batch).  Done/free slots
keep computing (fixed-shape batching burns their FLOPs regardless); their
emissions are masked to ``pad_id`` and their cache writes land in slots
whose validity masks hide them from any later request (positions are
rewritten by the next prefill before they become attendable).

``paged=True`` swaps the dense per-slot stripes for the block-paged pool
(DESIGN.md §8): same invariant, but block tables and context lengths are
extra DATA arguments to the decode program, admission additionally gates on
the page allocator's worst-case reservation, and done-slot writes are
redirected in-program to the reserved sink page so recycled pages can never
be corrupted mid-batch.  ``fused_select`` routes the mixture + selection
through one Pallas kernel (token draws bit-identical via Gumbel-argmax).

The scheduler clock, admission policy and latency accounting live in
``scheduler.py``; member health gating and live refresh in ``registry.py``;
see DESIGN.md §5 for the full contract.
"""
from __future__ import annotations

import time
from collections import Counter
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.sampling import GREEDY, SamplingParams, select_tokens

from .bma import BMA_MODES, fused_mixture_select, mixture_logprobs
from .cache_pool import CachePool, PagedCachePool
from .registry import ChainRefresher, SnapshotRegistry
from .scheduler import FCFSQueue, Request, RequestResult


@dataclass
class _Active:
    result: RequestResult
    submit_s: float
    tokens: list = field(default_factory=list)
    logprobs: list = field(default_factory=list)


@dataclass
class ServeReport:
    """Aggregate outcome of one ``ServeEngine.run``: per-request results +
    the latency/throughput numbers the serving benchmark records."""

    results: list
    wall_s: float
    decode_steps: int
    total_tokens: int
    trace_counts: dict
    pool: dict
    registry: dict
    refresher: dict | None

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-12)

    def latency_percentiles(self) -> dict:
        """p50/p99 of request completion latency and first-token latency
        (seconds, queueing included)."""
        lat = np.asarray([r.latency_s for r in self.results], np.float64)
        ftl = np.asarray([r.first_token_s for r in self.results], np.float64)
        pct = lambda a, q: float(np.percentile(a, q)) if a.size else float("nan")
        return {
            "latency_p50_s": pct(lat, 50),
            "latency_p99_s": pct(lat, 99),
            "first_token_p50_s": pct(ftl, 50),
            "first_token_p99_s": pct(ftl, 99),
        }


class ServeEngine:
    """Continuous-batching BMA decode over a pooled slot axis.

    ``members``: a (K, ...)-stacked parameter pytree or a
    :class:`SnapshotRegistry` (live refresh).  ``refresher`` (optional, a
    :class:`ChainRefresher` or overlapped
    :class:`~repro.serve.engine.refresh.RefreshScheduler` feeding the same
    registry) is bound at construction and pumped EVERY decode tick; it
    amortizes one sampler chunk per ``refresh_every`` ticks — stale members
    serve until the registry promotes a candidate that passes the spread
    gate."""

    def __init__(
        self,
        cfg,
        model,
        members,
        *,
        num_slots: int,
        max_seq: int,
        sampling: SamplingParams = GREEDY,
        bma: str = "probs",
        eos_id: int | None = None,
        pad_id: int = 0,
        cache_dtype=None,
        refresher: ChainRefresher | None = None,
        refresh_every: int = 0,
        compress_parked: bool = False,
        record_logprobs: bool = False,
        seed: int = 0,
        mesh=None,
        member_axis: str = "member",
        slot_axis: str = "slot",
        paged: bool = False,
        block_size: int = 16,
        num_blocks: int | None = None,
        prefix_sharing: bool = True,
        fused_select: bool | None = None,
    ):
        if bma not in BMA_MODES:
            raise ValueError(f"bma must be one of {BMA_MODES}")
        self.cfg, self.model = cfg, model
        self.registry = members if isinstance(members, SnapshotRegistry) else SnapshotRegistry(members)
        self.sampling = sampling
        self.bma = bma
        self.eos_id = eos_id
        self.pad_id = int(pad_id)
        self.max_seq = int(max_seq)
        self.cache_dtype = cache_dtype
        self.refresher = refresher
        self.refresh_every = int(refresh_every)
        if refresher is not None and refresher.registry is not self.registry:
            raise ValueError("refresher must feed this engine's registry")
        self._seen_version = self.registry.version
        self.record_logprobs = bool(record_logprobs)
        self.paged = bool(paged)
        # Fused mixture+selection kernel: on by default where it compiles to
        # a real kernel (TPU); the unfused jnp path stays the default on CPU
        # so interpret-mode overhead never taxes the test/bench hot loop.
        # Either way the numerics are pinned equal by tests/test_paged_attention.py.
        self._fused_select = (
            jax.default_backend() == "tpu" if fused_select is None else bool(fused_select)
        )
        if self.paged:
            self.pool = PagedCachePool(
                cfg,
                model,
                num_members=self.registry.num_members,
                num_slots=num_slots,
                max_seq=max_seq,
                block_size=block_size,
                num_blocks=num_blocks,
                dtype=cache_dtype or cfg.compute_dtype,
                compress_parked=compress_parked,
                prefix_sharing=prefix_sharing,
            )
        else:
            self.pool = CachePool(
                cfg,
                model,
                num_members=self.registry.num_members,
                num_slots=num_slots,
                max_seq=max_seq,
                dtype=cache_dtype or cfg.compute_dtype,
                compress_parked=compress_parked,
            )
        S = self.pool.num_slots
        self._tokens = jnp.full((S, 1), self.pad_id, jnp.int32)
        self._done = jnp.ones((S,), bool)
        self._budget = jnp.zeros((S,), jnp.int32)
        base = jax.random.PRNGKey(seed)
        self._key_decode = jax.random.fold_in(base, 0)
        self._key_admit = jax.random.fold_in(base, 1)
        self.trace_counts: Counter = Counter()
        self.decode_steps = 0
        # Multi-device layout (DESIGN.md §7): pooled caches (K, S, ...) shard
        # member/slot over their two leading dims, slot-state arrays shard
        # over slot, members over member; any dim a mesh axis does not divide
        # evenly replicates.  Every sharding is pinned explicitly on BOTH
        # sides of the two jitted programs so the donated-buffer feedback
        # loop (decode output -> next decode input) has fixed-point layouts —
        # that is what preserves the one-compiled-decode-program invariant
        # under a mesh.
        self.mesh = mesh
        self._member_axis, self._slot_axis = member_axis, slot_axis
        self._placed_version: int | None = None
        # the unsharded "home" of the member stack — where a pre-staged
        # candidate must land for promotion to be a pure pointer flip
        leaf = jax.tree.leaves(self.registry.members)[0]
        devs = leaf.devices() if hasattr(leaf, "devices") else set()
        self._home_device = next(iter(devs)) if len(devs) == 1 else None
        if mesh is None and self._home_device is not None:
            # Commit every buffer feeding the compiled programs NOW.  A
            # promoted candidate arrives COMMITTED (device_put at the flip),
            # and committed vs uncommitted arguments are different lowerings
            # even under one trace — left uncommitted, the first
            # post-promotion decode and admit each silently re-lower and
            # re-run XLA (~0.6s stalls on the serving path: exactly the
            # bimodal p99 this engine exists to avoid).  Committing up front
            # puts every program in the committed fixed point from the
            # first trace; the mesh path gets the same effect from its
            # pinned in/out shardings.
            put = lambda t: jax.device_put(t, self._home_device)
            self.registry.members = put(self.registry.members)
            self.pool.caches = put(self.pool.caches)
            self._tokens = put(self._tokens)
            self._done = put(self._done)
            self._budget = put(self._budget)
            self._placed_version = self.registry.version
        if mesh is None:
            # the two compiled entry points; caches are donated through both
            # so the pool's buffers are recycled in place, never copied
            if self.paged:
                self._decode = jax.jit(self._decode_paged_fn, donate_argnums=(1,))
                self._admit = jax.jit(self._admit_paged_fn, donate_argnums=(1,))
            else:
                self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))
                self._admit = jax.jit(self._admit_fn, donate_argnums=(1,))
        else:
            from jax.sharding import NamedSharding, PartitionSpec

            from repro.distributed.sharding import leading_axes_shardings

            rep = NamedSharding(mesh, PartitionSpec())
            # Paged pools have no slot axis — pages are shared across slots —
            # so they shard over members only and the page pool replicates
            # along the slot mesh axis.  Dense pools shard (member, slot).
            cache_axes = (member_axis,) if self.paged else (member_axis, slot_axis)
            cache_s = leading_axes_shardings(self.pool.caches, cache_axes, mesh)
            mem_s = leading_axes_shardings(self.registry.members, (member_axis,), mesh)
            tok_s = leading_axes_shardings(self._tokens, (slot_axis,), mesh)
            slot_s = leading_axes_shardings(self._done, (slot_axis,), mesh)
            self._cache_shardings, self._member_shardings = cache_s, mem_s
            self.pool.caches = jax.device_put(self.pool.caches, cache_s)
            self._tokens = jax.device_put(self._tokens, tok_s)
            self._done = jax.device_put(self._done, slot_s)
            self._budget = jax.device_put(self._budget, slot_s)
            if self.paged:
                tab_s = leading_axes_shardings(
                    jnp.zeros((S, self.pool.alloc.blocks_per_slot), jnp.int32),
                    (slot_axis,),
                    mesh,
                )
                self._decode = jax.jit(
                    self._decode_paged_fn,
                    donate_argnums=(1,),
                    in_shardings=(mem_s, cache_s, tok_s, slot_s, slot_s, tab_s, slot_s, rep),
                    out_shardings=(slot_s, tok_s, cache_s, slot_s, slot_s, slot_s),
                )
                self._admit = jax.jit(
                    self._admit_paged_fn,
                    donate_argnums=(1,),
                    in_shardings=(mem_s, cache_s, tok_s, slot_s, slot_s, rep, rep, rep, rep, rep),
                    out_shardings=(cache_s, tok_s, slot_s, slot_s, rep, rep, rep),
                )
            else:
                self._decode = jax.jit(
                    self._decode_fn,
                    donate_argnums=(1,),
                    in_shardings=(mem_s, cache_s, tok_s, slot_s, slot_s, rep),
                    # (emit, feed, caches, done, budget, logp) — logp is (S, V),
                    # slot-leading like the masks
                    out_shardings=(slot_s, tok_s, cache_s, slot_s, slot_s, slot_s),
                )
                self._admit = jax.jit(
                    self._admit_fn,
                    donate_argnums=(1,),
                    in_shardings=(mem_s, cache_s, tok_s, slot_s, slot_s, rep, rep, rep, rep),
                    out_shardings=(cache_s, tok_s, slot_s, slot_s, rep, rep, rep),
                )
        if refresher is not None and hasattr(refresher, "bind"):
            # pacing, spare-device placement and warm-up compilation happen
            # here, at construction — never on a serving request
            refresher.bind(self)

    # -- compiled programs --------------------------------------------------

    def _members(self):
        """Registry members, placed on the mesh.  ``device_put`` with the
        member sharding is cached on ``registry.version`` so a live refresh
        re-places exactly once per promotion, not per decode tick (re-putting
        an already-placed tree is a no-op but still walks the pytree)."""
        if self.mesh is not None and self._placed_version != self.registry.version:
            self.registry.members = jax.device_put(
                self.registry.members, self._member_shardings
            )
            self._placed_version = self.registry.version
        elif self._home_device is not None and self._placed_version != self.registry.version:
            # unsharded: promotions from ANY source (overlapped scheduler,
            # sync ChainRefresher, manual propose) are re-committed to the
            # home device before decode consumes them, so the decode/admit
            # lowerings never see a committedness change (see __init__);
            # the overlapped flip pre-places and marks, making this a no-op
            self.registry.members = jax.device_put(
                self.registry.members, self._home_device
            )
            self._placed_version = self.registry.version
        return self.registry.members

    def _place_members(self, tree):
        """Pre-stage a candidate member stack with the engine's pinned
        placement — the mesh ``NamedSharding``s, or the unsharded home
        device — so a later promotion is a pure pointer flip that the
        compiled decode program cannot distinguish from the old buffers.
        The ``device_put`` is async-dispatched (no host sync)."""
        if self.mesh is not None:
            return jax.device_put(tree, self._member_shardings)
        if self._home_device is not None:
            return jax.device_put(tree, self._home_device)
        return tree

    def mark_members_placed(self) -> None:
        """Tell :meth:`_members` the current registry version is already in
        engine placement (the overlapped refresher pre-stages candidates
        through :meth:`_place_members`, so the per-promotion re-put would be
        redundant pytree work)."""
        self._placed_version = self.registry.version

    def _note_version(self) -> None:
        """Per-tick version watch: on a promotion, eagerly invalidate the
        paged pool's stale-version prefix entries (they can never be hit
        again — the sharing key includes the version)."""
        if self.registry.version != self._seen_version:
            self._seen_version = self.registry.version
            if self.paged:
                self.pool.invalidate_version(self.registry.version)

    @property
    def decode_trace_count(self) -> int:
        """How many times the decode program has been (re)traced — the
        continuous-batching acceptance pin asserts this stays at 1."""
        return self.trace_counts["decode"]

    def _eos_hits(self, tok):
        if self.eos_id is None:
            return jnp.zeros(tok.shape, bool)
        return tok == self.eos_id

    def _mix_select(self, logits, key):
        """Per-tick BMA mixture + token selection over the slot axis:
        (K, S, V) member logits -> (tokens (S,), mixture logprobs (S, V)).
        Fused (one Pallas kernel) or unfused — same numerics, pinned by
        tests/test_paged_attention.py."""
        if self._fused_select:
            return fused_mixture_select(logits, key, mode=self.bma, sampling=self.sampling)
        logp = mixture_logprobs(logits, self.bma)
        return select_tokens(logp, key, self.sampling), logp

    def _select_tail(self, tok, logp, done, budget):
        """Shared emit/feed/done bookkeeping after token selection."""
        newly_done = (~done) & (self._eos_hits(tok) | (budget <= 1))
        emit = jnp.where(done, jnp.int32(self.pad_id), tok)
        next_done = done | newly_done
        feed = jnp.where(next_done, jnp.int32(self.pad_id), tok)[:, None]
        return emit, feed, next_done, budget - 1, logp

    def _decode_fn(self, members, caches, tokens, done, budget, key):
        self.trace_counts["decode"] += 1  # trace-time side effect only

        def member_step(p, c):
            def slot_step(cs, tok):
                logits, new_cs = self.model.decode_step(self.cfg, p, cs, tok[None])
                return logits[0, 0], new_cs  # (V,), slot cache

            return jax.vmap(slot_step)(c, tokens)

        logits, new_caches = jax.vmap(member_step)(members, caches)  # (K, S, V)
        tok, logp = self._mix_select(logits, key)
        emit, feed, next_done, budget, logp = self._select_tail(tok, logp, done, budget)
        return emit, feed, new_caches, next_done, budget, logp

    def _decode_paged_fn(self, members, pools, tokens, done, budget, tables, ctx, key):
        """Paged twin of :meth:`_decode_fn`.  Block tables (S, M) and context
        lengths (S,) are DATA — page churn never retraces.  The destination
        page for each slot's write is computed in-program, with done/free
        slots redirected to the sink page 0 so their garbage writes can never
        land in a page that was recycled to another request mid-batch."""
        self.trace_counts["decode"] += 1

        S, M = tables.shape
        j = jnp.clip(ctx // self.pool.block_size, 0, M - 1)
        write_block = jnp.where(done, 0, tables[jnp.arange(S), j])  # (S,)

        def member_step(p, pool):
            return self.model.paged.decode_step(
                self.cfg, p, pool, tokens, tables, ctx, write_block
            )

        logits, new_pools = jax.vmap(member_step)(members, pools)  # (K, S, 1, V)
        tok, logp = self._mix_select(logits[:, :, 0], key)
        emit, feed, next_done, budget, logp = self._select_tail(tok, logp, done, budget)
        return emit, feed, new_pools, next_done, budget, logp

    def _admit_fn(self, members, caches, tokens, done, budget, prompt, slot, max_new, key):
        self.trace_counts[f"admit_len{prompt.shape[-1]}"] += 1

        def member_prefill(p):
            return self.model.prefill(
                self.cfg, p, {"tokens": prompt}, self.max_seq, self.cache_dtype
            )

        logits, slot_cache = jax.vmap(member_prefill)(members)  # (K,1,1,V), (K,...)
        new_caches = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_index_in_dim(
                full, one.astype(full.dtype), slot, 1
            ),
            caches,
            slot_cache,
        )
        logp = mixture_logprobs(logits[:, 0, -1], self.bma)  # (V,)
        tok = select_tokens(logp, key, self.sampling)  # scalar
        slot_done = self._eos_hits(tok) | (max_new <= 1)
        feed = jnp.where(slot_done, jnp.int32(self.pad_id), tok)
        tokens = tokens.at[slot, 0].set(feed)
        done = done.at[slot].set(slot_done)
        budget = budget.at[slot].set(max_new - 1)
        return new_caches, tokens, done, budget, tok, slot_done, logp

    def _admit_paged_fn(self, members, pools, tokens, done, budget, prompt,
                        table_row, slot, max_new, key):
        """Paged twin of :meth:`_admit_fn`: dense prefill (length-shaped,
        same bucketing caveat) scattered into the slot's table-row pages.
        Shared prefix pages get rewritten with bit-identical KV (position-
        local), so concurrent sharers are unaffected."""
        self.trace_counts[f"admit_len{prompt.shape[-1]}"] += 1

        def member_prefill(p, pool):
            logits, slot_cache = self.model.prefill(
                self.cfg, p, {"tokens": prompt}, self.max_seq, self.cache_dtype
            )
            new_pool = self.model.paged.prefill_write(
                self.cfg, pool, slot_cache, table_row, self.pool.block_size
            )
            return logits, new_pool

        logits, new_pools = jax.vmap(member_prefill)(members, pools)  # (K,1,1,V)
        logp = mixture_logprobs(logits[:, 0, -1], self.bma)  # (V,)
        tok = select_tokens(logp, key, self.sampling)  # scalar
        slot_done = self._eos_hits(tok) | (max_new <= 1)
        feed = jnp.where(slot_done, jnp.int32(self.pad_id), tok)
        tokens = tokens.at[slot, 0].set(feed)
        done = done.at[slot].set(slot_done)
        budget = budget.at[slot].set(max_new - 1)
        return new_pools, tokens, done, budget, tok, slot_done, logp

    # -- serving loop -------------------------------------------------------

    def _finalize(self, slot, act: _Active, step: int, now: float, results: list):
        r = act.result
        r.tokens = np.asarray(act.tokens, np.int32)
        r.finished_step = step
        r.latency_s = now - act.submit_s
        r.hit_eos = self.eos_id is not None and r.num_tokens > 0 and int(r.tokens[-1]) == self.eos_id
        if self.record_logprobs:
            r.logprobs = np.asarray(act.logprobs, np.float32)
        results.append(r)
        self.pool.release(slot)
        obs_trace.get().instant(
            "serve.retire", cat="serve", rid=r.rid, slot=slot,
            tokens=r.num_tokens, eos=bool(r.hit_eos),
        )

    def _do_admit(self, req: Request, step: int, submit_s: float, active: dict, results: list, wall):
        need = int(req.prompt.size) + req.max_new
        if need > self.max_seq:
            # the non-windowed cache write clamps at max_seq-1, which would
            # silently corrupt the tail — refuse instead
            raise ValueError(
                f"request {req.rid}: prompt_len + max_new = {need} exceeds "
                f"engine max_seq={self.max_seq}"
            )
        slot = self.pool.acquire()
        key = jax.random.fold_in(self._key_admit, req.rid)
        prompt = jnp.asarray(req.prompt)[None]
        admit_span = obs_trace.get().span(
            "serve.admit", cat="serve", rid=req.rid, slot=slot,
            prompt_len=int(req.prompt.size), step=step,
        )
        admit_span.__enter__()
        if self.paged:
            table_row = self.pool.admit_blocks(
                slot, req.prompt, req.max_new, self.registry.version
            )
            out = self._admit(
                self._members(),
                self.pool.caches,
                self._tokens,
                self._done,
                self._budget,
                prompt,
                jnp.asarray(table_row),
                jnp.int32(slot),
                jnp.int32(req.max_new),
                key,
            )
        else:
            out = self._admit(
                self._members(),
                self.pool.caches,
                self._tokens,
                self._done,
                self._budget,
                prompt,
                jnp.int32(slot),
                jnp.int32(req.max_new),
                key,
            )
        self.pool.caches, self._tokens, self._done, self._budget, tok, slot_done, logp = out
        admit_span.__exit__(None, None, None)
        now = wall()
        res = RequestResult(rid=req.rid, prompt_len=int(req.prompt.size), admitted_step=step)
        res.first_token_s = now - submit_s
        act = _Active(result=res, submit_s=submit_s, tokens=[int(tok)])
        if self.record_logprobs:
            act.logprobs.append(np.asarray(logp))
        if bool(slot_done):
            self._finalize(slot, act, step, now, results)
        else:
            active[slot] = act

    def run(self, requests, *, max_steps: int | None = None) -> ServeReport:
        """Serve ``requests`` (a list of :class:`Request`) to completion.

        The loop per scheduler tick: (1) admit pending arrivals into free
        slots (prefill-on-admit, first token emitted), (2) pump the snapshot
        refresher (amortized: a whole sampler chunk lands once per
        ``refresh_every`` ticks, but its cost is spread over every tick in
        between), (3) one compiled decode step for the whole
        slot axis, (4) collect emissions, finalize and recycle finished
        slots.  Idle periods (no active slots, future arrivals) fast-forward
        the tick clock.  Hitting ``max_steps`` finalizes the in-flight
        requests with whatever they emitted (``truncated=True``) and
        recycles their slots; still-pending requests are simply dropped."""
        queue = FCFSQueue(requests)
        active: dict[int, _Active] = {}
        results: list[RequestResult] = []
        submit_s: dict[int, float] = {}
        step = 0
        steps_at_start = self.decode_steps
        t0 = time.perf_counter()
        wall = lambda: time.perf_counter() - t0
        budget_steps = max_steps if max_steps is not None else 1 << 60
        while (len(queue) or active) and step < budget_steps:
            if not active and len(queue) and queue.next_arrival() > step:
                step = queue.next_arrival()  # idle: jump to the next arrival
            for r in queue.visible(step):
                submit_s.setdefault(r.rid, wall())  # schedulable => clock starts
            while self.pool.free_slots:
                req = queue.peek(step)
                if req is None:
                    break
                if not self.pool.can_admit(req.prompt, req.max_new, self.registry.version):
                    # FCFS head-of-line: not enough free pages for this
                    # request's worst-case growth — wait for completions to
                    # free pages.  If nothing is in flight no pages will
                    # ever free, so an empty-pool refusal is permanent.
                    if not active and self.pool.active_slots == 0:
                        raise ValueError(
                            f"request {req.rid}: prompt_len + max_new = "
                            f"{int(req.prompt.size) + req.max_new} can never fit the "
                            f"page pool (free={self.pool.alloc.free_blocks} blocks "
                            f"of {self.pool.block_size})"
                        )
                    break
                queue.pop()
                self._do_admit(req, step, submit_s[req.rid], active, results, wall)
            if self.refresher is not None and self.refresh_every:
                # every tick: flip-if-ready + credit-paced micro-chunk
                # dispatch (one full chunk per refresh_every ticks) — no
                # single request ever eats a whole chunk
                self.refresher.pump(step)
            self._note_version()  # promotions (any source) invalidate stale prefixes
            if active:
                # span covers dispatch AND the emissions fetch below — the
                # true per-tick wall time including device compute
                tick_span = obs_trace.get().span(
                    "serve.decode_tick", cat="serve", step=step, active=len(active),
                )
                tick_span.__enter__()
                key = jax.random.fold_in(self._key_decode, step)
                if self.paged:
                    # Host-side growth first: make sure every live slot owns
                    # the page its fed token writes into, then ship the
                    # tables/positions as data.
                    for slot in active:
                        self.pool.ensure_decode_block(slot)
                    emit, feed, caches, done, budget, logp = self._decode(
                        self._members(),
                        self.pool.caches,
                        self._tokens,
                        self._done,
                        self._budget,
                        # jnp.array COPIES (asarray may zero-copy alias the
                        # allocator's live numpy buffers, which mutate under
                        # the async dispatch — advance()/ensure_decode_block
                        # run before the tick's compute necessarily does)
                        jnp.array(self.pool.tables),
                        jnp.array(self.pool.ctx),
                        key,
                    )
                    for slot in active:  # fed token consumed position ctx
                        self.pool.advance(slot)
                else:
                    emit, feed, caches, done, budget, logp = self._decode(
                        self._members(),
                        self.pool.caches,
                        self._tokens,
                        self._done,
                        self._budget,
                        key,
                    )
                self.pool.caches = caches
                self._tokens, self._done, self._budget = feed, done, budget
                self.decode_steps += 1
                emit_np = np.asarray(emit)
                done_np = np.asarray(done)
                logp_np = np.asarray(logp) if self.record_logprobs else None
                tick_span.__exit__(None, None, None)
                now = wall()
                for slot, act in list(active.items()):
                    act.tokens.append(int(emit_np[slot]))
                    if self.record_logprobs:
                        act.logprobs.append(logp_np[slot])
                    if done_np[slot]:
                        self._finalize(slot, act, step, now, results)
                        del active[slot]
            step += 1
        if active:  # max_steps truncation: finalize + recycle in-flight slots
            self._done = self._done.at[jnp.asarray(sorted(active), jnp.int32)].set(True)
            now = wall()
            for slot, act in list(active.items()):
                act.result.truncated = True
                self._finalize(slot, act, step, now, results)
                del active[slot]
        results.sort(key=lambda r: r.rid)
        report = ServeReport(
            results=results,
            wall_s=wall(),
            decode_steps=self.decode_steps - steps_at_start,
            total_tokens=sum(r.num_tokens for r in results),
            trace_counts=dict(self.trace_counts),
            pool=self.pool.stats(),
            registry=self.registry.stats(),
            refresher=self.refresher.stats() if self.refresher else None,
        )
        self._absorb_metrics(report)
        return report

    def _absorb_metrics(self, report: ServeReport) -> None:
        """Fold the run's legacy stats() dicts + per-request latencies into
        the canonical metrics registry (DESIGN.md §11).  Host-side, once per
        run, on already-materialized values — no device syncs added."""
        reg = obs_metrics.default_registry()
        reg.absorb("serve.engine", {
            "decode_steps": self.decode_steps,
            "total_tokens": report.total_tokens,
            "retired": len(report.results),
            "wall_s": report.wall_s,
            "tokens_per_s": report.tokens_per_s,
        })
        if self.paged:
            # PagedCachePool.stats() merges the allocator dict in; absorb the
            # allocator under its own namespace and the rest under the pool's
            alloc = self.pool.alloc.stats()
            reg.absorb("serve.alloc", alloc)
            reg.absorb("serve.pool", {
                k: v for k, v in report.pool.items() if k not in alloc
            })
        else:
            reg.absorb("serve.pool", report.pool)
        reg.absorb("serve.registry", report.registry)
        if report.refresher:
            reg.absorb("serve.refresh", report.refresher)
        lat = reg.histogram("serve.request.latency_s")
        ftl = reg.histogram("serve.request.first_token_s")
        for r in report.results:
            lat.observe(r.latency_s)
            ftl.observe(r.first_token_s)
