"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution; vision tower STUBBED
(input_specs provides precomputed patch embeddings). [arXiv:2409.12191]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    vocab_size=152064,
    d_model=3584,
    num_layers=28,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    pattern=(LayerKind("attn"),),
    act="silu",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # (t, h, w) of head_dim/2 = 64
    tie_embeddings=False,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=3,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    mrope_sections=(4, 2, 2),  # head_dim/2 = 8
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
