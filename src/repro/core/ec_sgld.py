"""Elastic coupling applied to SGLD — the paper notes (§3, last paragraph)
that the coupling idea is independent of the base Hamiltonian and applies to
any SG-MCMC variant; with first-order Langevin dynamics the center keeps a
momentum r but chains are momentum-free:

    theta^i_{t+1} = theta^i_t - eps [ grad Ũ(theta^i_t) + alpha (theta^i_t - c̃_t) ]
                    + N(0, 2 eps)
    c_{t+1}       = c_t + eps M^-1 r_t
    r_{t+1}       = r_t - eps C M^-1 r_t - eps alpha (c_t - mean_thetã_t)
                    + N(0, 2 eps^2 C)

This is also the bridge to plain EASGD (paper §5): removing all noise and the
center momentum recovers EASGD exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .schedules import as_schedule
from .tree_util import (
    count_params,
    global_norm,
    tree_mean_axis0,
    tree_random_normal,
    tree_random_normal_per_chain,
)
from .types import Sampler


class ECSGLDState(NamedTuple):
    center: any
    center_momentum: any
    center_stale: any
    mean_theta_stale: any
    step: jnp.ndarray


def ec_sgld(
    step_size,
    alpha: float = 1.0,
    center_friction: float = 1.0,
    mass: float = 1.0,
    sync_every: int = 1,
    temperature: float = 1.0,
    chain_axis: str | None = None,
    per_chain_noise: bool | None = None,
) -> Sampler:
    """``chain_axis``: mesh axis name for shard_map SPMD (see ec_sghmc /
    DESIGN.md §2) — the s-periodic chain mean pmean-reduces over it.
    ``per_chain_noise`` (default: on under ``chain_axis``) keys each
    chain's noise by its GLOBAL index, making the stream invariant to the
    mesh layout — the DESIGN.md §7 equivalence contract."""
    schedule = as_schedule(step_size)
    minv = 1.0 / mass
    s = int(sync_every)
    if per_chain_noise is None:
        per_chain_noise = chain_axis is not None

    def init(params):
        center = tree_mean_axis0(jax.tree.map(lambda p: p.astype(jnp.float32), params))
        return ECSGLDState(
            center=center,
            center_momentum=jax.tree.map(jnp.zeros_like, center),
            center_stale=center,
            mean_theta_stale=center,
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, rng):
        eps = schedule(state.step)
        # shard_map contract (DESIGN.md §2): per-chain noise decorrelates
        # across shards; the center noise k_r must stay shard-invariant
        # so the replicated center state does not diverge.
        k_t, k_r = jax.random.split(rng)
        if per_chain_noise:
            local_k = jax.tree.leaves(grads)[0].shape[0]
            offset = (
                jax.lax.axis_index(chain_axis) * local_k
                if chain_axis is not None
                else 0
            )
            noise_t = tree_random_normal_per_chain(k_t, grads, offset, jnp.float32)
        else:
            if chain_axis is not None:
                k_t = jax.random.fold_in(k_t, jax.lax.axis_index(chain_axis))
            noise_t = tree_random_normal(k_t, grads, jnp.float32)
        noise_r = tree_random_normal(k_r, state.center_momentum, jnp.float32)
        sig_t = jnp.sqrt(2.0 * eps * temperature)
        sig_r = temperature**0.5 * eps * jnp.sqrt(2.0 * center_friction)

        updates = jax.tree.map(
            lambda g, th, ct, n: -eps
            * (g.astype(jnp.float32) + alpha * (th.astype(jnp.float32) - ct))
            + sig_t * n,
            grads,
            params,
            state.center_stale,
            noise_t,
        )
        new_center = jax.tree.map(
            lambda c, r: c + eps * minv * r, state.center, state.center_momentum
        )
        new_center_momentum = jax.tree.map(
            lambda r, c, mth, n: r
            - eps * center_friction * minv * r
            - eps * alpha * (c - mth)
            + sig_r * n,
            state.center_momentum,
            state.center,
            state.mean_theta_stale,
            noise_r,
        )

        def do_sync(operand):
            new_c, upd = operand
            new_params = jax.tree.map(lambda th, u: th.astype(jnp.float32) + u, params, upd)
            return new_c, tree_mean_axis0(new_params, chain_axis)

        def no_sync(operand):
            del operand
            return state.center_stale, state.mean_theta_stale

        is_sync = (state.step + 1) % s == 0
        new_stale, new_mth = jax.lax.cond(is_sync, do_sync, no_sync, (new_center, updates))

        return updates, ECSGLDState(
            center=new_center,
            center_momentum=new_center_momentum,
            center_stale=new_stale,
            mean_theta_stale=new_mth,
            step=state.step + 1,
        )

    def stats(state, params):
        diff = jax.tree.map(
            lambda th, c: th.astype(jnp.float32) - c[None], params, state.center
        )
        n_elem = max(count_params(params), 1)
        return {
            "step": state.step,
            "center_momentum_norm": global_norm(state.center_momentum),
            "chain_center_rms": global_norm(diff) / jnp.sqrt(jnp.float32(n_elem)),
        }

    return Sampler(init, update, stats=stats)
