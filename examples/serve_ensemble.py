"""Serving example: the posterior-predictive engine under a concurrent
synthetic request trace.

This is the paper's deliverable end to end: K elastically coupled chains
produce a posterior ensemble; the engine serves Bayesian-model-averaged
predictions with continuous batching (requests join decode slots
mid-flight), and — second run — keeps refreshing the ensemble from a live
sampler run at chunk boundaries, gated by the ensemble-spread check.

    PYTHONPATH=src python examples/serve_ensemble.py
"""
from repro.launch.serve import main as serve_main


def main():
    print("== continuous batching, frozen 3-member ensemble ==")
    serve_main(["--arch", "qwen3-0.6b", "--smoke", "--engine",
                "--slots", "4", "--requests", "10", "--prompt-len", "16",
                "--gen", "8", "--ensemble", "3", "--interarrival", "2"])
    print()
    print("== live snapshot refresh + temperature/top-k sampling ==")
    serve_main(["--arch", "qwen3-0.6b", "--smoke", "--engine",
                "--slots", "4", "--requests", "10", "--prompt-len", "16",
                "--gen", "8", "--ensemble", "3", "--interarrival", "2",
                "--refresh-every", "6", "--temperature", "0.8", "--top-k", "40"])


if __name__ == "__main__":
    main()
