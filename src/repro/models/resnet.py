"""The paper's CIFAR-10 experiment model: 32-layer residual network
(He et al. 2016) with batch-normalization REMOVED (paper Fig. 2 right) —
BN breaks the i.i.d.-likelihood interpretation needed for posterior
sampling, so the paper drops it; we follow.

ResNet-32 = 3 stages x 5 basic blocks x 2 convs + stem + head.
Implemented with lax.conv_general_dilated; weight-standardization-free,
plain residual blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec


def _conv_spec(cin, cout, k=3):
    return ParamSpec((k, k, cin, cout), (None, None, None, "mlp"), scale=0.05)


def param_specs(width: int = 16, num_classes: int = 10):
    w = width
    specs = {"stem": _conv_spec(3, w)}
    for stage in range(3):
        cin = w * (2 ** max(stage - 0, 0)) if stage == 0 else w * 2 ** (stage - 1)
        cout = w * 2**stage
        for blk in range(5):
            bin_ = cin if blk == 0 else cout
            specs[f"s{stage}b{blk}c1"] = _conv_spec(bin_, cout)
            specs[f"s{stage}b{blk}c2"] = _conv_spec(cout, cout)
            if bin_ != cout:
                specs[f"s{stage}b{blk}proj"] = _conv_spec(bin_, cout, k=1)
    specs["head_w"] = ParamSpec((w * 4, num_classes), ("mlp", None))
    specs["head_b"] = ParamSpec((num_classes,), (None,), init="zeros")
    return specs


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def apply(params, x):
    """x: (B, 32, 32, 3) -> logits (B, 10)."""
    h = _conv(x, params["stem"])
    for stage in range(3):
        for blk in range(5):
            stride = 2 if (stage > 0 and blk == 0) else 1
            r = h
            h1 = _conv(jax.nn.relu(h), params[f"s{stage}b{blk}c1"], stride)
            h2 = _conv(jax.nn.relu(h1), params[f"s{stage}b{blk}c2"])
            if f"s{stage}b{blk}proj" in params:
                r = _conv(r, params[f"s{stage}b{blk}proj"], stride)
            h = r + h2
    h = jax.nn.relu(h).mean(axis=(1, 2))
    return h @ params["head_w"] + params["head_b"]


def nll_fn(params, batch):
    logits = apply(params, batch["x"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    return -jnp.sum(gold), jnp.asarray(batch["y"].shape[0], jnp.float32)
