from .pipeline import ShardedLoader, chain_batches
from .synthetic import (
    synthetic_cifar10,
    synthetic_mnist,
    synthetic_token_stream,
    token_batch,
)
