"""Minimal structured logger replacing the repo's raw ``print()`` calls.

Design constraints that ruled out stdlib ``logging``: the default output
must stay byte-compatible-ish with the existing ``[loop] step 12: ...``
style (tests and humans read it), level control is a single env var
(``REPRO_LOG=debug|info|warning|error|off``) read lazily at call time so
tests can flip it without re-importing, and there is no handler tree to
misconfigure.  ``REPRO_LOG_FORMAT=json`` switches to one-JSON-object-per-
line for machine consumption.
"""
from __future__ import annotations

import json
import os
import sys

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40, "off": 99}
_DEFAULT = "info"


def _threshold() -> int:
    # read at call time: REPRO_LOG set mid-process takes effect immediately
    return LEVELS.get(os.environ.get("REPRO_LOG", _DEFAULT).lower(), LEVELS[_DEFAULT])


class Logger:
    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def _emit(self, level: str, msg: str, fields: dict) -> None:
        if LEVELS[level] < _threshold():
            return
        if os.environ.get("REPRO_LOG_FORMAT", "").lower() == "json":
            rec = {"level": level, "logger": self.name, "msg": msg}
            rec.update(fields)
            line = json.dumps(rec)
        else:
            # human default matches the repo's historical print style
            line = f"[{self.name}] {msg}"
            if fields:
                line += " " + " ".join(f"{k}={v}" for k, v in fields.items())
        stream = sys.stderr if LEVELS[level] >= LEVELS["warning"] else sys.stdout
        print(line, file=stream, flush=True)

    def debug(self, msg: str, **fields) -> None:
        self._emit("debug", msg, fields)

    def info(self, msg: str, **fields) -> None:
        self._emit("info", msg, fields)

    def warning(self, msg: str, **fields) -> None:
        self._emit("warning", msg, fields)

    def error(self, msg: str, **fields) -> None:
        self._emit("error", msg, fields)


_LOGGERS: dict = {}


def get_logger(name: str) -> Logger:
    lg = _LOGGERS.get(name)
    if lg is None:
        lg = _LOGGERS[name] = Logger(name)
    return lg
