"""Exact-distribution stationary battery: every sampler's empirical
moments on a Gaussian target are gated against the CLOSED-FORM oracle for
the discrete-time recursion (repro.diagnostics.oracle) — not against the
continuum limit, so there is no discretization slack to hide behind.

Tolerances are pure Monte-Carlo: 3σ bands sized from the empirical ESS,
computed CONSERVATIVELY on the chain-mean series (treating the K coupled
chains as fully correlated), plus a safety floor.  Every config uses a
fixed seed, so failures are deterministic, and a failure means the sampler
does not draw from the distribution the math says it draws from.

This file is the acceptance gate future perf/sharding PRs run against:
change the update rule, and the oracle will notice.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro import diagnostics as diag
from repro.run import rollout

MU = 1.5  # per-dimension target mean (non-zero to catch mean bugs)
LAM = 1.0  # target precision: U = (lam/2)||theta - mu||^2
D = 2  # parameter dimensions (iid under the isotropic target)


def run_chains(sampler, shape, steps, burn, seed=0):
    """Drive a sampler with exact gradients through the device-resident
    executor (``repro.run.rollout`` — the same chunked-scan program every
    production driver uses); return (K, T, D) trajectory (K=1 axis inserted
    for unstacked samplers).  Moments are ALSO streamed through the Welford
    accumulator riding the scan carry and cross-checked, so the battery
    exercises the in-carry diagnostics path every run.  Gradients are
    evaluated at ``Sampler.grad_targets`` (stale worker snapshots for the
    approach-I baseline), which the battery's old hand-rolled scan got
    wrong — it could not have gated ``async_sghmc`` at all."""
    params0 = jnp.full(shape, MU + 1.0, jnp.float32)  # off-target start
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    res = rollout(
        sampler, lambda th: LAM * (th - MU), params0,
        num_steps=steps, keys=keys, moments=True, chunk_steps=8192,
    )
    wf = res.moments
    traj = np.asarray(res.trace)  # (steps, *shape)

    # Welford over the full run must equal the trajectory moments exactly
    # (the scan-streaming path is what big runs use instead of a trajectory).
    np.testing.assert_allclose(
        np.asarray(diag.welford_mean(wf)), traj.mean(axis=0), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(diag.welford_var(wf)), traj.var(axis=0), rtol=2e-3, atol=2e-4
    )

    traj = traj[burn:]
    if traj.ndim == 2:  # (T, D) -> (1, T, D)
        return traj[None]
    return np.moveaxis(traj, 1, 0)  # (T, K, D) -> (K, T, D)


def conservative_ess(traj):
    """Conservative coupled-chain ESS (chain-mean series), summed over
    dims — treats the K chains as fully correlated, which lower-bounds the
    information and therefore widens the tolerance bands."""
    return float(np.sum(diag.coupled_ess_nd(traj)))


def assert_matches_oracle(traj, oracle, *, check_cross=False, label=""):
    emp_mean, emp_var = diag.pooled_moments(traj)  # (D,), (D,)
    ess = conservative_ess(traj)

    mean_tol = 3.0 * np.sqrt(oracle.theta_var / ess) + 1e-4
    assert abs(emp_mean.mean() - oracle.theta_mean) < mean_tol, (
        f"{label}: mean {emp_mean.mean():.5f} vs oracle {oracle.theta_mean} "
        f"(tol {mean_tol:.5f}, ess {ess:.0f})"
    )

    var_tol = diag.monte_carlo_tolerance(oracle.theta_var, ess) + 1e-6
    assert abs(emp_var.mean() - oracle.theta_var) < var_tol, (
        f"{label}: var {emp_var.mean():.6f} vs oracle {oracle.theta_var:.6f} "
        f"(tol {var_tol:.6f}, ess {ess:.0f})"
    )

    if check_cross and traj.shape[0] > 1:
        k = traj.shape[0]
        pairs = [
            np.mean((traj[i] - emp_mean) * (traj[j] - emp_mean))
            for i in range(k)
            for j in range(i + 1, k)
        ]
        emp_cross = float(np.mean(pairs))
        cross_tol = 3.0 * np.sqrt(
            (oracle.theta_var**2 + oracle.theta_cross_cov**2) / max(ess, 4.0)
        ) + 1e-6
        assert abs(emp_cross - oracle.theta_cross_cov) < cross_tol, (
            f"{label}: cross-cov {emp_cross:.6f} vs oracle {oracle.theta_cross_cov:.6f} "
            f"(tol {cross_tol:.6f})"
        )

    # convergence hygiene: the battery's own split-R̂ must be clean
    rhat = float(np.max(diag.split_rhat_nd(traj)))
    assert rhat < 1.05, f"{label}: split-Rhat {rhat:.3f} — trajectory not stationary"


class TestSGHMCStationary:
    def test_eq4(self):
        s = core.sghmc(step_size=0.1, friction=1.0)
        traj = run_chains(s, (4, D), steps=30_000, burn=2_000)
        oracle = diag.sghmc_stationary(
            step_size=0.1, friction=1.0, noise_convention="eq4", precision=LAM, mu=MU
        )
        assert_matches_oracle(traj, oracle, label="sghmc-eq4")

    def test_eq6(self):
        s = core.sghmc(step_size=0.1, friction=1.5, noise_convention="eq6")
        traj = run_chains(s, (4, D), steps=30_000, burn=2_000, seed=1)
        oracle = diag.sghmc_stationary(
            step_size=0.1, friction=1.5, noise_convention="eq6", precision=LAM, mu=MU
        )
        assert_matches_oracle(traj, oracle, label="sghmc-eq6")

    @pytest.mark.slow
    def test_cold_temperature(self):
        s = core.sghmc(step_size=0.1, friction=1.0, temperature=0.25)
        traj = run_chains(s, (4, D), steps=40_000, burn=2_000, seed=2)
        oracle = diag.sghmc_stationary(
            step_size=0.1, friction=1.0, temperature=0.25, precision=LAM, mu=MU
        )
        assert_matches_oracle(traj, oracle, label="sghmc-cold")


class TestSGLDStationary:
    def test_default(self):
        s = core.sgld(step_size=0.1)
        traj = run_chains(s, (4, D), steps=30_000, burn=2_000)
        oracle = diag.sgld_stationary(step_size=0.1, precision=LAM, mu=MU)
        assert_matches_oracle(traj, oracle, label="sgld")


class TestAsyncSGHMCStationary:
    """The paper's naive approach-I baseline, gated against the exact
    delay-augmented oracle: a worker arriving at step t pushes the gradient
    of the snapshot it pulled s steps earlier, so the server recursion has
    a pure feedback lag whose stationary variance the oracle solves in
    closed form.  s=1 is synchronous-parallel SGHMC; larger s inflates the
    variance — the degradation EC-SGHMC is designed to avoid."""

    @pytest.mark.parametrize("s", [1, 4])
    def test_oracle(self, s):
        sampler = core.async_sghmc(
            step_size=0.1, num_workers=4, friction=1.0, sync_every=s
        )
        traj = run_chains(sampler, (D,), steps=40_000, burn=4_000, seed=3 + s)
        oracle = diag.async_sghmc_stationary(
            step_size=0.1, friction=1.0, sync_every=s, precision=LAM, mu=MU
        )
        assert_matches_oracle(traj, oracle, label=f"async-s{s}")

    def test_s1_is_synchronous_sghmc(self):
        """With s=1 every worker reports every step at the current params:
        the oracle must coincide with plain SGHMC exactly."""
        o_async = diag.async_sghmc_stationary(step_size=0.1, friction=1.0,
                                              sync_every=1, precision=LAM, mu=MU)
        o_sg = diag.sghmc_stationary(step_size=0.1, friction=1.0,
                                     noise_convention="eq4", precision=LAM, mu=MU)
        assert o_async.theta_var == pytest.approx(o_sg.theta_var, rel=1e-12)

    def test_staleness_inflates_variance(self):
        """§2 of the paper, quantified: the oracle's θ-variance must grow
        monotonically with the staleness period."""
        vs = [
            diag.async_sghmc_stationary(step_size=0.1, friction=1.0, sync_every=s,
                                        precision=LAM, mu=MU).theta_var
            for s in (1, 2, 4, 8)
        ]
        assert vs == sorted(vs) and vs[-1] > 1.2 * vs[0], vs


# the acceptance grid: alpha in {0, 1} x sync_every in {1, 8}; eq6 noise,
# center staleness noise excluded so alpha=0 is EXACTLY independent SGHMC
EC_KW = dict(friction=1.0, center_friction=1.0, noise_convention="eq6",
             center_noise_in_p=False)
K = 4


def _ec_case(alpha, s, *, fused=False, steps=40_000, seed=None):
    eps = 0.1
    sampler = core.ec_sghmc(step_size=eps, alpha=alpha, sync_every=s, fused=fused, **EC_KW)
    seed = seed if seed is not None else int(17 * alpha + s + 100 * fused)
    traj = run_chains(sampler, (K, D), steps=steps, burn=4_000, seed=seed)
    oracle = diag.ec_sghmc_stationary(
        step_size=eps, alpha=alpha, num_chains=K, sync_every=s, precision=LAM, mu=MU,
        **EC_KW,
    )
    return traj, oracle


class TestECSGHMCStationary:
    @pytest.mark.parametrize("s", [1, 8])
    def test_alpha0_recovers_independent_sghmc(self, s):
        """Acceptance criterion: alpha=0 must reproduce independent-SGHMC
        moments — both in the oracle (exact identity) and empirically."""
        traj, oracle = _ec_case(0.0, s)
        sg = diag.sghmc_stationary(
            step_size=0.1, friction=1.0, noise_convention="eq6", precision=LAM, mu=MU
        )
        assert oracle.theta_var == pytest.approx(sg.theta_var, rel=1e-12)
        assert_matches_oracle(traj, oracle, label=f"ec-a0-s{s}")

    @pytest.mark.parametrize("s", [1, 8])
    def test_alpha1(self, s):
        traj, oracle = _ec_case(1.0, s)
        assert_matches_oracle(traj, oracle, check_cross=True, label=f"ec-a1-s{s}")

    @pytest.mark.slow
    def test_alpha1_s4(self):
        traj, oracle = _ec_case(1.0, 4)
        assert_matches_oracle(traj, oracle, check_cross=True, label="ec-a1-s4")

    @pytest.mark.slow
    def test_alpha1_int8_center_exchange(self):
        """Acceptance gate for the compressed exchange (DESIGN.md §7):
        EC-SGHMC whose s-periodic center exchange round-trips through the
        int8 codec must hold the SAME closed-form stationary bands — the
        <= scale/2 quantization error is absorbed into the center-noise
        covariance C of Eq. 6 and is statistically invisible at 3 sigma."""
        from repro.distributed import int8_codec

        sampler = core.ec_sghmc(step_size=0.1, alpha=1.0, sync_every=4,
                                compression=int8_codec(), **EC_KW)
        traj = run_chains(sampler, (K, D), steps=40_000, burn=4_000, seed=21)
        oracle = diag.ec_sghmc_stationary(
            step_size=0.1, alpha=1.0, num_chains=K, sync_every=4,
            precision=LAM, mu=MU, **EC_KW,
        )
        assert_matches_oracle(traj, oracle, check_cross=True, label="ec-int8-a1-s4")

    @pytest.mark.slow
    def test_eq4_convention(self):
        """The staleness-sweep configuration (eq4 noise, weaker coupling)."""
        kw = dict(friction=1.0, center_friction=1.0, noise_convention="eq4",
                  center_noise_in_p=False)
        sampler = core.ec_sghmc(step_size=0.1, alpha=0.5, sync_every=4, **kw)
        traj = run_chains(sampler, (K, D), steps=40_000, burn=4_000, seed=7)
        oracle = diag.ec_sghmc_stationary(
            step_size=0.1, alpha=0.5, num_chains=K, sync_every=4, precision=LAM, mu=MU, **kw
        )
        assert_matches_oracle(traj, oracle, check_cross=True, label="ec-eq4")

    @pytest.mark.slow
    def test_phase_resolved_variance(self):
        """The cyclostationary fingerprint: variance ramps between syncs and
        snaps back at the exchange — phase-resolved match against the
        oracle's per-phase solution."""
        s = 8
        traj, oracle = _ec_case(1.0, s, steps=80_000, seed=11)
        t = traj.shape[1]
        t = t - t % s
        ess_phase = conservative_ess(traj) / s
        # trajectory index i holds theta_{burn+i+1}; phase = (burn+i+1) % s
        burn = 4_000
        for phase in range(s):
            offset = (phase - burn - 1) % s
            sel = traj[:, offset:t:s, :]
            emp = float(sel.var())
            want = float(oracle.phase_theta_vars[phase])
            tol = diag.monte_carlo_tolerance(want, ess_phase) + 1e-6
            assert abs(emp - want) < tol, (
                f"phase {phase}: var {emp:.6f} vs oracle {want:.6f} (tol {tol:.6f})"
            )
        assert np.ptp(oracle.phase_theta_vars) > 3 * 1e-4  # the ramp is resolvable


class TestFusedECSGHMCStationary:
    """Same dynamics through the Pallas kernel (interpret mode on CPU):
    Box-Muller counter noise + fused update must hit the same oracle."""

    def test_alpha1_s1_fused(self):
        traj, oracle = _ec_case(1.0, 1, fused=True, steps=30_000)
        assert_matches_oracle(traj, oracle, check_cross=True, label="ec-fused-a1-s1")

    @pytest.mark.slow
    def test_alpha1_s8_fused(self):
        traj, oracle = _ec_case(1.0, 8, fused=True, steps=30_000)
        assert_matches_oracle(traj, oracle, check_cross=True, label="ec-fused-a1-s8")

    @pytest.mark.slow
    def test_alpha0_s1_fused_matches_sghmc_oracle(self):
        traj, oracle = _ec_case(0.0, 1, fused=True, steps=30_000)
        assert_matches_oracle(traj, oracle, label="ec-fused-a0-s1")


# ---------------------------------------------------------------------------
# Adaptive tier (ROADMAP item 4): post-burn-in battery against the
# frozen-preconditioner oracle.  Diagonal target so the frozen M⁻¹ is
# materially non-uniform; the oracle consumes the ACTUAL frozen M⁻¹ read
# back from the final sampler state (recover it by running one preconditioner
# update on the frozen state — a no-op that returns the exact frozen value),
# so there is no modeling of what adaptation "should" converge to.
# ---------------------------------------------------------------------------

PREC_DIAG = np.array([4.0, 0.25])  # per-dim precisions; cond(Σ) = 16
SA_BURNIN = 2_000
# eq4 noise keeps stationary θ-var ≈ T/λ, so V̂ ≈ λ and M⁻¹ ≈ λ^(-1/2):
# frozen masses differ 2.8× across dims — a real preconditioning test
SA_EC_KW = dict(friction=1.0, center_friction=1.0, noise_convention="eq4",
                center_noise_in_p=False)


def run_chains_prec(sampler, shape, steps, burn, seed=0, prec=PREC_DIAG):
    """``run_chains`` on the diagonal target N(MU, diag(prec)⁻¹); also
    returns the final sampler state so tests can read the frozen
    preconditioner."""
    params0 = jnp.full(shape, MU + 1.0, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    p = jnp.asarray(prec, jnp.float32)
    res = rollout(
        sampler, lambda th: p * (th - MU), params0,
        num_steps=steps, keys=keys, moments=False, chunk_steps=8192,
    )
    traj = np.asarray(res.trace)[burn:]
    traj = traj[None] if traj.ndim == 2 else np.moveaxis(traj, 1, 0)
    return traj, res.state


def frozen_minv_of(precond_state, p_update):
    """The frozen M⁻¹ a post-burn-in step actually used: one more
    preconditioner update on the frozen state changes nothing (adapt
    gate is closed) and returns exactly the frozen M⁻¹ — family-agnostic,
    no duplicated formula in the tests."""
    assert int(np.asarray(precond_state.step)) >= SA_BURNIN
    zeros = jax.tree.map(jnp.zeros_like, precond_state.v)
    minv, after = p_update(precond_state, zeros)
    np.testing.assert_array_equal(np.asarray(after.v), np.asarray(precond_state.v))
    return np.asarray(minv, np.float64)


def assert_matches_elementwise(traj, oracle, *, label=""):
    """Per-(chain, dim) gate for INDEPENDENT scalar recursions with
    distinct frozen masses — pooling across chains would blur genuinely
    different stationary variances.  3σ Monte-Carlo bands per series."""
    k, t, d = traj.shape
    want_var = np.asarray(oracle.theta_var).reshape(k, d)
    want_mean = np.asarray(oracle.theta_mean).reshape(k, d)
    for i in range(k):
        for j in range(d):
            x = traj[i, :, j]
            ess = diag.effective_sample_size(x)
            emp_var = float(x.var())
            vtol = diag.monte_carlo_tolerance(want_var[i, j], ess) + 1e-6
            assert abs(emp_var - want_var[i, j]) < vtol, (
                f"{label}[{i},{j}]: var {emp_var:.6f} vs oracle "
                f"{want_var[i, j]:.6f} (tol {vtol:.6f}, ess {ess:.0f})"
            )
            mtol = 3.0 * np.sqrt(want_var[i, j] / max(ess, 4.0)) + 1e-4
            assert abs(float(x.mean()) - want_mean[i, j]) < mtol, (
                f"{label}[{i},{j}]: mean {x.mean():.5f} vs {want_mean[i, j]} "
                f"(tol {mtol:.5f})"
            )
    rhat = float(np.max([diag.split_rhat(traj[i, :, j]) for i in range(k) for j in range(d)]))
    assert rhat < 1.05, f"{label}: split-Rhat {rhat:.3f}"


def assert_matches_diag_oracle(traj, oracle, *, check_cross=False, label=""):
    """Per-dim gate for the COUPLED adaptive sampler: oracle moments are
    chain-averaged per dimension (chains carry different frozen masses);
    the pooled empirical variance estimates exactly that average since all
    chain means equal μ.  Conservative coupled-chain ESS per dim."""
    k, t, d = traj.shape
    ess_nd = np.maximum(np.asarray(diag.coupled_ess_nd(traj)), 4.0)
    for j in range(d):
        x = traj[:, :, j]
        want_var = float(oracle.theta_var[j])
        emp_var = float(((x - x.mean()) ** 2).mean())
        vtol = diag.monte_carlo_tolerance(want_var, ess_nd[j]) + 1e-6
        assert abs(emp_var - want_var) < vtol, (
            f"{label}[dim{j}]: var {emp_var:.6f} vs oracle {want_var:.6f} "
            f"(tol {vtol:.6f}, ess {ess_nd[j]:.0f})"
        )
        mtol = 3.0 * np.sqrt(want_var / ess_nd[j]) + 1e-4
        assert abs(float(x.mean()) - float(oracle.theta_mean[j])) < mtol, (
            f"{label}[dim{j}]: mean {x.mean():.5f} vs {oracle.theta_mean[j]}"
        )
        if check_cross and k > 1:
            mu_j = float(oracle.theta_mean[j])
            pairs = [
                np.mean((x[i] - mu_j) * (x[l] - mu_j))
                for i in range(k) for l in range(i + 1, k)
            ]
            emp_cross = float(np.mean(pairs))
            want_cross = float(oracle.theta_cross_cov[j])
            ctol = 3.0 * np.sqrt(
                (want_var**2 + want_cross**2) / ess_nd[j]
            ) + 1e-6
            assert abs(emp_cross - want_cross) < ctol, (
                f"{label}[dim{j}]: cross {emp_cross:.6f} vs {want_cross:.6f} "
                f"(tol {ctol:.6f})"
            )
    rhat = float(np.max(diag.split_rhat_nd(traj)))
    assert rhat < 1.05, f"{label}: split-Rhat {rhat:.3f}"


class TestScaleAdaptedSGHMCStationary:
    """Satellite: oracle-gate the EXISTING scale-adapted sampler (it only
    had smoke tests).  Each (chain, dim) element is an independent SGHMC
    recursion with the frozen mass 1/m_e — certified exactly."""

    def test_frozen_oracle_elementwise(self):
        eps = 0.1
        s = core.scale_adapted_sghmc(step_size=eps, friction=1.0,
                                     burnin=SA_BURNIN, decay=0.99)
        traj, st = run_chains_prec(s, (4, D), steps=30_000, burn=4_000, seed=21)
        _, p_up = core.rmsprop_preconditioner(decay=0.99, eps=1e-8, burnin=SA_BURNIN)
        minv = frozen_minv_of(st.precond, p_up)  # (4, D)
        # adaptation did something: the stiff dim must get the smaller mass
        assert np.all(minv[:, 0] < 0.8 * minv[:, 1]), minv
        oracle = diag.preconditioned_sghmc_stationary(
            step_size=eps, mass_inv=minv.reshape(-1), friction=1.0,
            noise_convention="eq4",
            precision=np.broadcast_to(PREC_DIAG, (4, D)).reshape(-1), mu=MU,
        )
        assert_matches_elementwise(traj, oracle, label="sa-sghmc")

    def test_uniform_mass_reduces_to_plain_oracle(self):
        """Oracle self-consistency: M⁻¹ ≡ 1 must reproduce the scalar
        SGHMC oracle bit-for-bit (same Lyapunov solve)."""
        o = diag.preconditioned_sghmc_stationary(
            step_size=0.1, mass_inv=np.ones(3), friction=1.0, precision=LAM, mu=MU
        )
        s = diag.sghmc_stationary(step_size=0.1, friction=1.0, precision=LAM, mu=MU)
        np.testing.assert_array_equal(o.theta_var, np.full(3, s.theta_var))
        np.testing.assert_array_equal(o.momentum_var, np.full(3, s.momentum_var))


class TestPreconditionedSGLDStationary:
    def test_frozen_oracle_elementwise(self):
        eps = 0.05
        s = core.preconditioned_sgld(step_size=eps, burnin=SA_BURNIN, decay=0.99)
        traj, st = run_chains_prec(s, (4, D), steps=30_000, burn=4_000, seed=23)
        _, p_up = core.rmsprop_preconditioner(decay=0.99, eps=1e-8, burnin=SA_BURNIN)
        minv = frozen_minv_of(st.precond, p_up)
        assert np.all(minv[:, 0] < 0.8 * minv[:, 1]), minv
        oracle = diag.preconditioned_sgld_stationary(
            step_size=eps, mass_inv=minv.reshape(-1),
            precision=np.broadcast_to(PREC_DIAG, (4, D)).reshape(-1), mu=MU,
        )
        assert_matches_elementwise(traj, oracle, label="psgld")

    @pytest.mark.slow
    def test_adam_preconditioner_frozen_oracle(self):
        """Same gate through the Adam family (bias-corrected second moment;
        the correction counter saturates with the freeze)."""
        eps = 0.05
        s = core.preconditioned_sgld(step_size=eps, burnin=SA_BURNIN,
                                     decay=0.999, precond="adam")
        traj, st = run_chains_prec(s, (4, D), steps=34_000, burn=6_000, seed=29)
        _, p_up = core.adam_preconditioner(beta2=0.999, eps=1e-8, burnin=SA_BURNIN)
        minv = frozen_minv_of(st.precond, p_up)
        oracle = diag.preconditioned_sgld_stationary(
            step_size=eps, mass_inv=minv.reshape(-1),
            precision=np.broadcast_to(PREC_DIAG, (4, D)).reshape(-1), mu=MU,
        )
        assert_matches_elementwise(traj, oracle, label="psgld-adam")

    def test_identity_reduces_to_plain_oracle(self):
        o = diag.preconditioned_sgld_stationary(
            step_size=0.1, mass_inv=np.ones(2), precision=LAM, mu=MU
        )
        s = diag.sgld_stationary(step_size=0.1, precision=LAM, mu=MU)
        np.testing.assert_array_equal(o.theta_var, np.full(2, s.theta_var))


def _sa_ec_case(alpha, s, *, fused=False, steps=30_000, seed=None):
    eps = 0.1
    sampler = core.scale_adapted_ec_sghmc(
        step_size=eps, alpha=alpha, sync_every=s, fused=fused,
        burnin=SA_BURNIN, decay=0.99, **SA_EC_KW,
    )
    seed = seed if seed is not None else int(31 + 17 * alpha + s + 100 * fused)
    traj, st = run_chains_prec(sampler, (K, D), steps=steps, burn=4_000, seed=seed)
    _, p_up = core.rmsprop_preconditioner(decay=0.99, eps=1e-8, burnin=SA_BURNIN)
    minv = frozen_minv_of(st.precond, p_up)  # (K, D)
    oracle = diag.preconditioned_ec_sghmc_stationary(
        step_size=eps, alpha=alpha, num_chains=K, mass_inv=minv,
        sync_every=s, precision=PREC_DIAG, mu=MU, **SA_EC_KW,
    )
    return traj, oracle


class TestScaleAdaptedECSGHMCStationary:
    """The tentpole gate: preconditioned elastic coupling, post-freeze,
    certified by the per-chain-mass period-map oracle at 3σ — α ∈ {0, 1},
    s ∈ {1, 4, 8}, fused and unfused."""

    @pytest.mark.parametrize("s", [1, 8])
    def test_alpha0_is_independent_preconditioned_sghmc(self, s):
        traj, oracle = _sa_ec_case(0.0, s)
        # α=0 oracle must equal the decoupled preconditioned-SGHMC average
        assert np.all(np.isfinite(oracle.theta_var))
        assert_matches_diag_oracle(traj, oracle, label=f"sa-ec-a0-s{s}")

    @pytest.mark.parametrize("s", [1, 8])
    def test_alpha1(self, s):
        traj, oracle = _sa_ec_case(1.0, s)
        assert_matches_diag_oracle(traj, oracle, check_cross=True,
                                   label=f"sa-ec-a1-s{s}")

    @pytest.mark.slow
    def test_alpha1_s4(self):
        traj, oracle = _sa_ec_case(1.0, 4)
        assert_matches_diag_oracle(traj, oracle, check_cross=True, label="sa-ec-a1-s4")

    @pytest.mark.slow
    def test_alpha0_s4(self):
        traj, oracle = _sa_ec_case(0.0, 4)
        assert_matches_diag_oracle(traj, oracle, label="sa-ec-a0-s4")

    def test_alpha1_s1_fused(self):
        """Same dynamics through the preconditioned Pallas kernel
        (interpret mode on CPU, Box-Muller counter noise)."""
        traj, oracle = _sa_ec_case(1.0, 1, fused=True)
        assert_matches_diag_oracle(traj, oracle, check_cross=True,
                                   label="sa-ec-fused-a1-s1")

    @pytest.mark.slow
    def test_alpha1_s8_fused(self):
        traj, oracle = _sa_ec_case(1.0, 8, fused=True)
        assert_matches_diag_oracle(traj, oracle, check_cross=True,
                                   label="sa-ec-fused-a1-s8")

    def test_uniform_mass_reduces_to_ec_oracle(self):
        """Oracle self-consistency: uniform M⁻¹ = 1 must reproduce the
        existing EC-SGHMC oracle across the acceptance grid."""
        for alpha in (0.0, 1.0):
            for s in (1, 4, 8):
                kw = dict(step_size=0.1, alpha=alpha, num_chains=K,
                          sync_every=s, precision=LAM, mu=MU, **SA_EC_KW)
                o_pre = diag.preconditioned_ec_sghmc_stationary(
                    mass_inv=np.ones(K), **kw
                )
                o_ref = diag.ec_sghmc_stationary(mass=1.0, **kw)
                np.testing.assert_allclose(
                    o_pre.theta_var, np.full(1, o_ref.theta_var), rtol=1e-12
                )
                np.testing.assert_allclose(
                    o_pre.theta_cross_cov, np.full(1, o_ref.theta_cross_cov),
                    rtol=1e-9, atol=1e-15,
                )


class TestResampleChainFromCenter:
    """Satellite: the elastic-K chain-recovery path draws from the
    stationary conditional theta^i | c ~ N(c, (K/alpha) I)."""

    def test_moments_and_shapes(self):
        alpha, k_new = 2.0, 6
        ec = core.ec_sghmc(step_size=1e-2, alpha=alpha)
        params = jax.random.normal(jax.random.PRNGKey(0), (4, 2000))
        st = ec.init(params)
        new_params, new_state = core.resample_chain_from_center(
            st, alpha=alpha, rng=jax.random.PRNGKey(1), num_chains=k_new
        )
        draws = np.asarray(new_params)  # (k_new, 2000)
        center = np.asarray(st.center)

        assert draws.shape == (k_new, 2000)
        var_target = k_new / alpha
        n = draws.size
        # per-coordinate mean of the k_new draws: E|err| = sqrt(2 var / (pi k))
        mean_err = np.abs(draws.mean(axis=0) - center).mean()
        assert mean_err < 2.0 * np.sqrt(var_target / k_new)
        centered = draws - center[None]
        assert abs(centered.mean()) < 4 * np.sqrt(var_target / n)
        # variance K/alpha: 3σ band for a chi^2 with n dof
        assert abs(centered.var() - var_target) < 3 * var_target * np.sqrt(2 / n)

    def test_state_shape_consistency(self):
        """Returned state must be consistent with the NEW chain count while
        keeping center buffers at their (chain-free) shapes."""
        ec = core.ec_sghmc(step_size=1e-2, alpha=1.0)
        params = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
        st = ec.init(params)
        for k_new in (4, 6, 2):
            new_params, new_state = core.resample_chain_from_center(
                st, alpha=1.0, rng=jax.random.PRNGKey(3), num_chains=k_new
            )
            assert new_params.shape == (k_new, 8)
            assert new_state.momentum.shape == (k_new, 8)
            assert new_state.center.shape == (8,)
            assert new_state.center_stale.shape == (8,)
            assert new_state.mean_theta_stale.shape == (8,)
            np.testing.assert_allclose(
                np.asarray(new_state.mean_theta_stale),
                np.asarray(new_params).mean(0),
                atol=1e-5,
            )
            # fresh chains start with zero momentum
            assert float(jnp.abs(new_state.momentum).max()) == 0.0
