"""Scale-adapted SGHMC (Springenberg et al., 2016 — BOHAMIANN; the same
authors' practical variant) and its elastically-coupled composition:
diagonal preconditioning from an online gradient-variance estimate, adapted
during burn-in then FROZEN so the stationary distribution stays valid.

    M⁻¹ = 1 / (√V̂ + ε),   V̂ = EMA[g²]

With a frozen diagonal M the augmented Hamiltonian

    H = Σᵢ [ U(θⁱ) + ½ pⁱᵀ Mᵢ⁻¹ pⁱ ] + (α/2) Σᵢ ‖θⁱ − c‖² + ½ rᵀ M_c⁻¹ r

has the SAME θ-marginal for ANY fixed masses, so preconditioning the
kinetic terms does not perturb the target — provided friction and noise
satisfy fluctuation–dissipation for the chosen convention.  Both samplers
here therefore keep the injected-noise covariance MASS-INDEPENDENT
(2εV for "eq4", 2ε²(V+C) for "eq6" — exactly ``sghmc._noise_scale``),
while friction damps at rate εVM⁻¹.  The coupling force −εα(θⁱ − c̃) is a
potential-gradient force and is deliberately NOT M-scaled: that is the
consistent composition that preserves the Eq. 5 joint target after the
burn-in freeze (DESIGN.md §6).

Post-freeze the recursion is linear on a Gaussian target, so the
frozen-preconditioner oracle (``repro.diagnostics.oracle.preconditioned_*``)
certifies both samplers exactly; the stationary battery
(``tests/test_stationary.py``) is their acceptance gate.

``scale_adapted_ec_sghmc`` preconditions each chain's kinetic term from the
chain's OWN gradient stream (per-chain diagonal Mᵢ⁻¹) and gives the center
the chain-mean mass M_c⁻¹ = meanᵢ Mᵢ⁻¹ — symmetric when the chains agree,
exact in the oracle either way.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .ec_sghmc import p_step
from .preconditioner import PrecondState, rmsprop_preconditioner
from .schedules import as_schedule
from .sghmc import _noise_scale
from .tree_util import count_params, global_norm, tree_mean_axis0, tree_random_normal
from .types import Params, Sampler


class ScaleAdaptedState(NamedTuple):
    momentum: Params
    precond: PrecondState
    step: jnp.ndarray


def scale_adapted_sghmc(
    step_size,
    friction: float = 1.0,
    temperature: float = 1.0,
    burnin: int = 1000,
    decay: float = 0.99,
    precond_eps: float = 1e-8,
    noise_convention: str = "eq4",
    state_dtype=jnp.float32,
) -> Sampler:
    """Preconditioned SGHMC:

        θ' = θ + ε M⁻¹ p
        p' = p − ε g − ε V M⁻¹ p + N(0, 2εV·T)        ["eq4"]

    Noise covariance is mass-independent (fluctuation–dissipation for
    friction C = V given the εVM⁻¹ damping), so with M⁻¹ frozen the chain
    targets exp(−U/T) exactly in the ε → 0 limit and the exact discrete-time
    moments are ``diagnostics.oracle.preconditioned_sghmc_stationary`` —
    per dimension, identical to plain SGHMC with mass 1/M⁻¹."""
    schedule = as_schedule(step_size)
    p_init, p_update = rmsprop_preconditioner(
        decay=decay, eps=precond_eps, burnin=burnin
    )

    def init(params):
        return ScaleAdaptedState(
            momentum=jax.tree.map(lambda p: jnp.zeros_like(p, state_dtype), params),
            precond=p_init(params),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params=None, rng=None):
        del params
        eps = schedule(state.step)
        minv, new_precond = p_update(state.precond, grads)
        updates = jax.tree.map(
            lambda p, m: eps * m * p.astype(jnp.float32), state.momentum, minv
        )
        sigma = temperature**0.5 * _noise_scale(eps, friction, 0.0, noise_convention)
        noise = tree_random_normal(rng, state.momentum, jnp.float32)

        def mom(p, g, m, n):
            p32 = p.astype(jnp.float32)
            out = (
                (1.0 - eps * friction * m) * p32
                - eps * g.astype(jnp.float32)
                + sigma * n  # mass-independent: fluctuation-dissipation
            )
            return out.astype(state_dtype)

        new_mom = jax.tree.map(mom, state.momentum, grads, minv, noise)
        return updates, ScaleAdaptedState(new_mom, new_precond, state.step + 1)

    def stats(state, params):
        del params
        return {"step": state.step, "momentum_norm": global_norm(state.momentum)}

    return Sampler(init, update, stats=stats)


class ScaleAdaptedECState(NamedTuple):
    """EC-SGHMC carry + per-chain preconditioner.  Chain leaves carry the
    leading (K, ...) axis; center leaves do not (same contract as
    ``ECSGHMCState``)."""

    momentum: Params  # pⁱ : (K, ...) per leaf
    precond: PrecondState  # per-chain V̂ : (K, ...) per leaf
    center: Params  # c : (...)
    center_momentum: Params  # r : (...)
    center_stale: Params  # c̃ : worker-side stale snapshot of c
    mean_theta_stale: Params  # server-side stale meanᵢ θⁱ
    step: jnp.ndarray


def scale_adapted_ec_sghmc(
    step_size,
    alpha: float = 1.0,
    friction: float = 1.0,  # V
    center_friction: float = 1.0,  # C
    sync_every: int = 1,  # s
    temperature: float = 1.0,
    burnin: int = 1000,
    decay: float = 0.99,
    precond_eps: float = 1e-8,
    noise_convention: str = "eq6",
    center_noise_in_p: bool = True,
    fused: bool = False,
    state_dtype=jnp.float32,
) -> Sampler:
    """Eq. 6 elastic coupling with per-chain diagonal preconditioning:

        θⁱ' = θⁱ + ε Mᵢ⁻¹ pⁱ
        c'  = c + ε M_c⁻¹ r,        M_c⁻¹ = meanᵢ Mᵢ⁻¹
        pⁱ' = pⁱ − ε g − ε V Mᵢ⁻¹ pⁱ − ε α (θⁱ − c̃) + σ_p N(0, I)
        r'  = r − ε C M_c⁻¹ r − ε α (c − m̃θ) + σ_r N(0, I)

    with the s-periodic stale exchange of ``ec_sghmc`` verbatim and the
    mass-independent noise scales of ``sghmc._noise_scale``.  The momentum
    line reuses ``ec_sghmc.p_step`` with an ARRAY M⁻¹, so with identity
    preconditioning (``decay=1.0, precond_eps=0.0``) the trajectory is
    bit-for-bit plain ``ec_sghmc(mass=1.0)`` — pinned by
    ``tests/test_adaptive_equivalence.py``.

    ``fused=True`` dispatches the θ/p update through the preconditioned
    Pallas kernel (``repro.kernels.ops.fused_precond_ec_update_tree``); the
    preconditioner EMA itself stays in XLA (cheap, and it must see raw
    gradients).  No ``chain_axis`` / shard_map support: the chain-mean
    center mass M_c⁻¹ would be a per-step collective — the adaptive tier is
    single-program for now (DESIGN.md §6)."""
    schedule = as_schedule(step_size)
    s = int(sync_every)
    p_init, p_update = rmsprop_preconditioner(
        decay=decay, eps=precond_eps, burnin=burnin
    )

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, state_dtype)
        center = tree_mean_axis0(jax.tree.map(lambda p: p.astype(state_dtype), params))
        copy = lambda t: jax.tree.map(jnp.copy, t)  # donation-safe buffers
        return ScaleAdaptedECState(
            momentum=jax.tree.map(zeros, params),
            precond=p_init(params),
            center=center,
            center_momentum=jax.tree.map(lambda c: jnp.zeros_like(c), center),
            center_stale=copy(center),
            mean_theta_stale=copy(center),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, rng):
        eps = schedule(state.step)
        minv, new_precond = p_update(state.precond, grads)
        minv_c = tree_mean_axis0(minv)
        sigma_p = temperature**0.5 * _noise_scale(
            eps, friction, center_friction if center_noise_in_p else 0.0, noise_convention
        )
        sigma_r = temperature**0.5 * _noise_scale(eps, center_friction, 0.0, noise_convention)

        # -- position updates (pre-update momenta; Eq. 6 lines 1-2) ---------
        updates = jax.tree.map(
            lambda p, m: eps * m * p.astype(jnp.float32), state.momentum, minv
        )
        new_center = jax.tree.map(
            lambda c, r, mc: (
                c.astype(jnp.float32) + eps * mc * r.astype(jnp.float32)
            ).astype(state_dtype),
            state.center,
            state.center_momentum,
            minv_c,
        )

        # -- momentum updates ----------------------------------------------
        k_p, k_r = jax.random.split(rng)
        noise_r = tree_random_normal(k_r, state.center_momentum, jnp.float32)

        if fused:
            from repro.kernels.ops import fused_precond_ec_update_tree

            new_theta_f, new_momentum = fused_precond_ec_update_tree(
                params, state.momentum, grads, state.center_stale, minv, k_p,
                eps=eps, friction=friction, alpha=alpha,
                sigma_p=sigma_p, stochastic_round=True,
            )
            del new_theta_f  # updates (above) already carry eps*Mᵢ⁻¹*p
        else:
            noise_p = tree_random_normal(k_p, state.momentum, jnp.float32)
            new_momentum = jax.tree.map(
                lambda p, g, th, ct, m, n: p_step(
                    p, g, th, ct, n, eps=eps, friction=friction, minv=m,
                    alpha=alpha, sigma_p=sigma_p, out_dtype=state_dtype,
                ),
                state.momentum, grads, params, state.center_stale, minv, noise_p,
            )

        def r_step(r, c, mth, mc, n):
            r32 = r.astype(jnp.float32)
            out = (
                r32
                - eps * center_friction * mc * r32
                - eps * alpha * (c.astype(jnp.float32) - mth.astype(jnp.float32))
                + sigma_r * n
            )
            return out.astype(state_dtype)

        new_center_momentum = jax.tree.map(
            r_step,
            state.center_momentum,
            state.center,
            state.mean_theta_stale,
            minv_c,
            noise_r,
        )

        # -- s-periodic exchange (identical to ec_sghmc) --------------------
        def do_sync(operand):
            new_c, upd = operand
            new_params = jax.tree.map(
                lambda th, u: th.astype(jnp.float32) + u, params, upd
            )
            mean_theta = jax.tree.map(
                lambda x: x.astype(state_dtype), tree_mean_axis0(new_params)
            )
            return new_c, mean_theta

        def no_sync(operand):
            del operand
            return state.center_stale, state.mean_theta_stale

        is_sync = (state.step + 1) % s == 0
        new_center_stale, new_mean_theta_stale = jax.lax.cond(
            is_sync, do_sync, no_sync, (new_center, updates)
        )

        return updates, ScaleAdaptedECState(
            momentum=new_momentum,
            precond=new_precond,
            center=new_center,
            center_momentum=new_center_momentum,
            center_stale=new_center_stale,
            mean_theta_stale=new_mean_theta_stale,
            step=state.step + 1,
        )

    def stats(state, params):
        diff = jax.tree.map(
            lambda th, c: th.astype(jnp.float32) - c.astype(jnp.float32)[None],
            params,
            state.center,
        )
        n_elem = max(count_params(params), 1)
        rms = global_norm(diff) / jnp.sqrt(jnp.float32(n_elem))
        k = jax.tree.leaves(params)[0].shape[0]
        minv_leaves = jax.tree.leaves(state.precond.v)
        v_mean = sum(jnp.mean(v) for v in minv_leaves) / len(minv_leaves)
        return {
            "step": state.step,
            "momentum_norm": global_norm(state.momentum),
            "center_momentum_norm": global_norm(state.center_momentum),
            "chain_center_rms": rms,
            "coupling_energy": 0.5 * alpha * rms * rms * (n_elem / k),
            "precond_v_mean": v_mean,  # adaptation health: plateaus at freeze
        }

    return Sampler(init, update, stats=stats)
