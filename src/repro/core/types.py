"""Core type definitions for the SG-MCMC sampler library.

Samplers follow an optax-style ``(init, update)`` transform API so they
compose with any model and any distribution strategy:

    sampler = ec_sghmc(step_size=1e-2, alpha=1.0, ...)
    state   = sampler.init(params)
    updates, state = sampler.update(grads, state, params, rng)
    params  = apply_updates(params, updates)

``grads`` are gradients of the potential energy U(θ) (i.e. the *negative*
log posterior), matching the paper's convention: the sampler descends U.
For elastically-coupled samplers, ``params``/``grads`` carry a leading
chain axis of size K on every leaf.

This 4-tuple is also the EXECUTOR protocol: ``repro.run.ChainExecutor``
scans ``grad_targets -> grad_fn -> update`` as one device-resident
``lax.scan`` program, folds ``stats`` into its per-chunk outputs, and is
the only sanctioned way to drive a sampler for more than a handful of
steps (DESIGN.md §3) — per-step Python loops measure host dispatch, not
sampler math.  Everything here must therefore be jit-, vmap- and
scan-safe: no Python side effects, no host syncs, and any step-dependence
routed through ``state`` (the executor may rebuild a sampler inside a
traced program via ``sampler_factory`` with traced hyperparameters).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

Params = Any  # pytree
State = Any  # pytree
Updates = Any  # pytree, same structure as Params


class Sampler(NamedTuple):
    """A stateful parameter-update transform (optax-compatible shape).

    ``grad_targets`` (optional): (state, params) -> pytree at which the
    caller must evaluate gradients before calling ``update``.  ``None``
    means "at params".  Stale-gradient samplers (approach I) point this at
    their worker snapshots.

    ``stats`` (optional): (state, params) -> dict of scalar diagnostics
    (jnp scalars; jit-safe, no host sync).  The lightweight hook the
    convergence-diagnostics subsystem (``repro.diagnostics``) and the
    drivers poll — training/benchmark loops log it, the stationary test
    battery asserts on it.  ``None`` means the sampler exposes nothing.
    """

    init: Callable[[Params], State]
    # update(grads, state, params, rng) -> (updates, new_state)
    update: Callable[..., tuple[Updates, State]]
    grad_targets: Callable[[State, Params], Params] | None = None
    stats: Callable[[State, Params], dict] | None = None


class ScheduleFn:  # pragma: no cover - typing helper only
    def __call__(self, step) -> Any: ...
