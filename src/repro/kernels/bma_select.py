"""Fused BMA mixture + token selection Pallas kernel.

The engine's decode epilogue reads the (K, S, V) member-logit tensor three
times on the unfused path: per-member log-softmax, the K-mixture reduce,
then temperature/top-k selection.  This kernel does all of it in ONE pass
per slot — each grid step pulls one (K, V) logit tile into VMEM and emits
the mixture log-prob row plus the selected token, so the K-member ensemble
pays a single memory pass per decoded token.

Exact-equivalence contract (pinned in tests/test_paged_attention.py):
  * mixture rows match ``serve.engine.bma.mixture_logprobs`` (f32 math,
    both "probs" and "logprobs" modes);
  * greedy tokens match ``jnp.argmax`` (first-occurrence tie-break);
  * sampled tokens match ``jax.random.categorical`` EXACTLY given the same
    key, because categorical is argmax(logits + Gumbel) and the caller
    passes in the identical ``jax.random.gumbel(key, (S, V), f32)`` draw
    (the kernel only fuses the mask/add/argmax);
  * top-k keeps ties at the k-th-largest threshold, like
    ``sampling._top_k_mask``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flash_attention import _CompilerParams, NEG_INF


def _first_argmax(row):
    """(1, V) f32 -> scalar int32 index of the first maximum (jnp.argmax
    tie-break), via an iota-min trick that lowers to TPU reductions."""
    V = row.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, row.shape, 1)
    hit = row == jnp.max(row, axis=-1, keepdims=True)
    return jnp.min(jnp.where(hit, iota, V)).astype(jnp.int32)


def _bma_select_kernel(
    logits_ref, gumbel_ref, logp_ref, tok_ref, *, mode, temperature, top_k
):
    x = logits_ref[:, 0, :].astype(jnp.float32)  # (K, V)
    K = x.shape[0]
    # per-member log-softmax
    m = jnp.max(x, axis=-1, keepdims=True)
    lp = x - (m + jnp.log(jnp.sum(jnp.exp(x - m), axis=-1, keepdims=True)))
    if mode == "probs":  # logsumexp over members - log K
        mk = jnp.max(lp, axis=0, keepdims=True)  # (1, V)
        mix = mk + jnp.log(jnp.sum(jnp.exp(lp - mk), axis=0, keepdims=True))
        mix = mix - jnp.log(jnp.float32(K))
    else:  # "logprobs": renormalized mean log-prob
        a = jnp.mean(lp, axis=0, keepdims=True)  # (1, V)
        ma = jnp.max(a, axis=-1, keepdims=True)
        mix = a - (ma + jnp.log(jnp.sum(jnp.exp(a - ma), axis=-1, keepdims=True)))
    logp_ref[...] = mix  # (1, V)

    if temperature <= 0.0:
        tok_ref[0, 0] = _first_argmax(mix)
        return
    sel = mix / jnp.float32(temperature)
    if top_k:
        V = sel.shape[-1]
        k = min(int(top_k), V)
        iota = jax.lax.broadcasted_iota(jnp.int32, sel.shape, 1)

        def strike(_, masked):
            # remove ONE occurrence of the current max so duplicates count
            # toward k, exactly like lax.top_k's sorted tail
            cur = jnp.max(masked, axis=-1, keepdims=True)
            first = jnp.min(jnp.where(masked == cur, iota, V))
            return jnp.where(iota == first, NEG_INF, masked)

        masked = jax.lax.fori_loop(0, k - 1, strike, sel)
        thresh = jnp.max(masked, axis=-1, keepdims=True)  # k-th largest
        sel = jnp.where(sel < thresh, NEG_INF, sel)  # ties at thresh kept
    sel = sel + gumbel_ref[...].astype(jnp.float32)
    tok_ref[0, 0] = _first_argmax(sel)


def bma_select(
    logits, gumbel, *, mode: str, temperature: float, top_k: int,
    interpret: bool = True,
):
    """logits (K, S, V), gumbel (S, V) f32 (ignored when temperature <= 0)
    -> (tokens (S,) int32, mixture log-probs (S, V) f32)."""
    K, S, V = logits.shape
    kernel = functools.partial(
        _bma_select_kernel,
        mode=mode, temperature=float(temperature), top_k=int(top_k),
    )
    logp, tok = pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((K, 1, V), lambda s: (0, s, 0)),
            pl.BlockSpec((1, V), lambda s: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, V), lambda s: (s, 0)),
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, V), jnp.float32),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(logits, gumbel)
    return tok[:, 0], logp
