"""Token selection shared by every serving path (legacy loop + engine).

One helper, one convention: ``select_tokens(logits, key, sampling)`` maps
``(..., V)`` logits (or mixture log-probs — selection is shift-invariant
per row) to int32 token ids.  ``temperature == 0`` is greedy argmax and
needs no key; any positive temperature is an RNG-keyed categorical draw,
optionally restricted to the top-k logits.  The engine's BMA decode and the
legacy ``make_prefill_step``/``make_decode_step`` both call this, so the
two paths sample identically given the same logits and key.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SamplingParams(NamedTuple):
    """Selection policy.  ``temperature=0`` ⇒ greedy (key unused);
    ``top_k=0`` ⇒ full-vocabulary support.  Both are Python-static: a policy
    change is a (deliberate) recompile, an admission never is."""

    temperature: float = 0.0
    top_k: int = 0


GREEDY = SamplingParams()


def _top_k_mask(logits, k: int):
    """-inf everything below the k-th largest logit per row."""
    k = min(int(k), logits.shape[-1])
    vals = jax.lax.top_k(logits, k)[0]
    thresh = vals[..., -1:]
    return jnp.where(logits < thresh, -jnp.inf, logits)


def select_tokens(logits, key=None, sampling: SamplingParams = GREEDY):
    """``logits (..., V)`` -> int32 tokens ``(...)``.

    Greedy (``temperature == 0``) is exact argmax.  Otherwise logits are
    scaled by ``1/temperature``, optionally top-k masked, and sampled with
    ``jax.random.categorical`` — batched rows draw independent Gumbel noise
    from the single ``key``.
    """
    if sampling.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("temperature > 0 sampling needs an RNG key")
    scaled = logits.astype(jnp.float32) / float(sampling.temperature)
    if sampling.top_k:
        scaled = _top_k_mask(scaled, sampling.top_k)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def gumbel_argmax_select(logits, gumbel, sampling: SamplingParams = GREEDY):
    """Selection with the Gumbel noise drawn OUTSIDE: tokens (...,) int32.

    ``jax.random.categorical(key, x)`` is literally
    ``argmax(x + jax.random.gumbel(key, x.shape, x.dtype))`` — the
    Gumbel-argmax identity, which jax implements verbatim.  Splitting the
    draw from the argmax is what lets the fused Pallas selection kernel
    (``repro.kernels.bma_select``) match :func:`select_tokens` bit-for-bit:
    the caller draws ``gumbel = jax.random.gumbel(key, shape, f32)`` with
    the engine's key and the kernel only does mixture + mask + argmax.
    ``temperature == 0`` ignores ``gumbel`` (greedy)."""
    if sampling.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / float(sampling.temperature)
    if sampling.top_k:
        scaled = _top_k_mask(scaled, sampling.top_k)
    return jnp.argmax(scaled + gumbel, axis=-1).astype(jnp.int32)


def mask_after_eos(tokens, eos_id: int, pad_id: int = 0):
    """Replace every token strictly after the first ``eos_id`` per row with
    ``pad_id`` (the EOS itself is kept).  tokens: (B, T) int."""
    hit = tokens == eos_id
    prior_hits = jnp.cumsum(hit.astype(jnp.int32), axis=-1) - hit.astype(jnp.int32)
    return jnp.where(prior_hits > 0, jnp.asarray(pad_id, tokens.dtype), tokens)
