"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072 — 8 experts top-2, attn/final logit softcaps.
[hf:xai-org/grok-1; unverified]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    vocab_size=131072,
    d_model=6144,
    num_layers=64,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    pattern=(LayerKind("attn", moe=True),),
    act="gelu",
    attn_logit_softcap=30.0,
    final_logit_softcap=30.0,
    moe_num_experts=8,
    moe_top_k=2,
    moe_d_ff=32768,
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale="sqrt_d",
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=3,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    moe_num_experts=4,
    moe_top_k=2,
    moe_d_ff=96,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
