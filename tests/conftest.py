"""Test-suite bootstrap: make ``python -m pytest`` work from the repo root
without the ``PYTHONPATH=src`` incantation (which keeps working unchanged —
duplicate sys.path entries are harmless)."""
from __future__ import annotations

import pathlib
import sys

_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running stationary-battery configs (opt-in via -m slow; "
        "scripts/ci.sh deselects them by default)",
    )
