"""Pytree arithmetic helpers (no optax in this environment — pure JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_zeros_like(a, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype), a)


def tree_dot(a, b):
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    )
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.float32(0.0)


def global_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_random_normal(rng, target, dtype=None):
    """A normal sample per leaf of ``target`` (shape-matched), deterministic
    in (rng, tree-structure)."""
    leaves, treedef = jax.tree.flatten(target)
    keys = jax.random.split(rng, len(leaves)) if leaves else []
    samples = [
        jax.random.normal(k, l.shape, dtype or l.dtype) for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, samples)


def tree_random_normal_per_chain(rng, target, offset=0, dtype=None):
    """One independent :func:`tree_random_normal` draw per leading-axis
    (chain) slice of ``target``: chain ``i`` draws with
    ``fold_in(rng, offset + i)``, so the stream depends only on the GLOBAL
    chain index — invariant to how the chain axis is split over devices.
    Inside ``shard_map`` pass ``offset = axis_index * local_K``; a
    single-program run (offset=0) then produces bit-identical per-chain
    noise to any sharded layout of the same chains (DESIGN.md §7)."""
    k = jax.tree.leaves(target)[0].shape[0]
    keys = jax.vmap(lambda i: jax.random.fold_in(rng, i))(offset + jnp.arange(k))
    return jax.vmap(lambda kk, sl: tree_random_normal(kk, sl, dtype))(keys, target)


def apply_updates(params, updates):
    """params + updates, preserving param dtypes (updates may be f32)."""
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)).astype(p.dtype), params, updates)


def tree_mean_axis0(a, axis_name: str | None = None):
    """Mean over the leading (chain) axis of every leaf.

    ``axis_name``: when the chain axis is additionally sharded over a mesh
    axis (shard_map SPMD — DESIGN.md §2), each shard sees only its local
    chains; pass the mesh axis name and the local mean is pmean-reduced to
    the global chain mean.  Equal per-shard chain counts are assumed (the
    mesh construction in ``repro.launch.mesh`` guarantees this)."""
    m = jax.tree.map(lambda x: jnp.mean(x, axis=0), a)
    if axis_name is not None:
        m = jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), m)
    return m


def tree_broadcast_axis0(a, k: int):
    """Broadcast every leaf to a leading axis of size k."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def count_params(a) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(a))
