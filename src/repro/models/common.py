"""Model substrate: configs + the ParamSpec machinery.

One source of truth per model: ``param_specs(cfg)`` returns a pytree of
:class:`ParamSpec`.  From it we derive
  * ``init_params``      — materialized params (smoke tests / real training)
  * ``abstract_params``  — ShapeDtypeStructs (dry-run: zero allocation)
  * ``param_axes``       — logical-axis names per dim (sharding rules)
so init, shapes, and sharding can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# ParamSpec machinery
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis name (str) or None per dim; len == len(shape)
    init: str = "normal"  # normal | zeros | ones | lru_lambda
    scale: float | str = "fan_in"  # stddev, or "fan_in" => 1/sqrt(fan_in dim)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _materialize(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "lru_lambda":
        # RG-LRU Λ init: a = exp(-softplus(Λ)·c) uniform in [0.9, 0.999]
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        # invert a = exp(-8·softplus(Λ)) -> Λ = softplus_inv(-log(a)/8)
        sp = -jnp.log(u) / 8.0
        lam = jnp.log(jnp.expm1(jnp.maximum(sp, 1e-8)))
        return lam.astype(spec.dtype)
    if spec.scale == "fan_in":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        std = 1.0 / math.sqrt(fan_in)
    else:
        std = float(spec.scale)
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)


def init_params(specs, rng):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(leaves)) if leaves else []
    return jax.tree.unflatten(treedef, [_materialize(s, k) for s, k in zip(leaves, keys)])


def abstract_params(specs, dtype_override=None):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype_override or s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_axes(specs):
    return jax.tree.map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def cast_specs(specs, dtype):
    return jax.tree.map(
        lambda s: dataclasses.replace(s, dtype=dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerKind:
    kind: str  # attn | rglru | mlstm | slstm
    window: Optional[int] = None  # sliding-window size; None => full/global attn
    moe: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | audio | hybrid | ssm | vlm
    vocab_size: int
    d_model: int
    num_layers: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    # repeating block pattern (cycled); remainder handled by truncation
    pattern: tuple = (LayerKind("attn"),)
    norm_eps: float = 1e-6
    norm_scale_offset: float = 0.0  # gemma: weight stored as (w - 1)
    sandwich_norm: bool = False  # gemma2/3: post-norms on both sublayers
    act: str = "silu"
    mlp_gated: bool = True  # False: plain 2-layer MLP (whisper)
    use_rope: bool = True  # False: absolute position embeddings (whisper)
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple] = None  # qwen2-vl (t, h, w) freq split
    query_scale: Optional[float] = None  # None => 1/sqrt(head_dim)
    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # recurrent blocks
    rglru_conv_width: int = 4
    rnn_width: Optional[int] = None  # RG-LRU recurrence width (defaults d_model)
    # embeddings / head
    tie_embeddings: bool = True
    embed_scale: Optional[str] = None  # "sqrt_d" (gemma)
    embed_onehot: bool = False  # one_hot(tokens) @ table: TP-friendly lookup
    # (a gather from a vocab-sharded table forces an all-gather of the whole
    # table under GSPMD; the one-hot contraction partitions cleanly instead)
    # encoder-decoder (whisper): encoder layer count + source length
    enc_layers: int = 0
    enc_seq: int = 0
    # dtypes
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    # loss
    xent_chunk: int = 2048  # seq-chunked cross-entropy (never materialize B,S,V)
    # activation checkpointing: "full" (nothing saved, re-forward in bwd) or
    # "none" (save activations; +25% step speed when memory allows)
    remat: str = "full"
    # dispatch attention through the Pallas flash kernel (interpret-mode on
    # CPU; compiled on TPU). The §Perf lever that removes score
    # materialization; default off = paper-faithful XLA baseline.
    use_flash_kernel: bool = False

    @property
    def layer_kinds(self) -> tuple:
        """Per-layer LayerKind, pattern cycled to num_layers."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def num_params(cfg: ModelConfig) -> int:
    """Total parameter count derived from the spec tree (exact)."""
    from . import registry  # local import to avoid cycle

    specs = registry.get_model(cfg).param_specs(cfg)
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    )


def active_params(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k of num_experts) — for 6·N_active·D."""
    from . import registry

    specs = registry.get_model(cfg).param_specs(cfg)
    total = 0
    # jax.tree.flatten_with_path only exists in newer jax; the tree_util
    # spelling works everywhere (cf. train/checkpoint.py)
    for path, s in jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )[0]:
        n = int(np.prod(s.shape))
        if "expert" in s.axes and cfg.moe_num_experts:
            n = n * cfg.moe_top_k // cfg.moe_num_experts
        total += n
    return total
