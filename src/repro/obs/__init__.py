"""Unified telemetry: structured metrics, host-side event tracing with
Perfetto export, structured logging, and run-manifest sinks.

Quick start::

    from repro import obs

    tracer = obs.enable_tracing()           # off by default — see trace.py
    ... run ...
    tracer.export("trace.json")             # manifest stamped automatically

    reg = obs.default_registry()
    reg.absorb("serve.pool", pool.stats())  # legacy dict -> canonical names
    print(reg.snapshot())

The contract (zero cost when off, host-only recording, namespace scheme)
is DESIGN.md §11.
"""
from repro.obs import trace
from repro.obs.log import get_logger
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    reset_default,
)
from repro.obs.sinks import JsonlSink, run_manifest
from repro.obs.trace import Tracer, disable as disable_tracing, enable as enable_tracing
from repro.obs.validate import validate_manifest, validate_trace

__all__ = [
    "trace",
    "get_logger",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "reset_default",
    "JsonlSink",
    "run_manifest",
    "Tracer",
    "enable_tracing",
    "disable_tracing",
    "validate_manifest",
    "validate_trace",
]


def configure(trace_path=None, capacity: int = 1 << 16):
    """Convenience switch used by launch entry points: enable tracing when
    a ``--trace PATH`` was given, returning (tracer, path) — tracer is the
    disabled singleton when path is None."""
    if trace_path is None:
        return trace.get(), None
    return trace.enable(capacity=capacity), trace_path
