"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    vocab_size=32000,
    d_model=2560,
    num_layers=24,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    pattern=(LayerKind("attn", window=4096),),  # mistral-style SWA everywhere
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    param_dtype=jnp.float32,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=3,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=128,
    pattern=(LayerKind("attn", window=8),),
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
