import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS") or (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
)

# ^ MUST precede any jax import: jax locks the device count on first init.
# Multi-pod dry-run (deliverable e).
#
# For every (architecture x input-shape) cell, lower + compile the step
# function against the production mesh(es) with abstract inputs (zero device
# allocation), record:
#   * memory_analysis()  — proves the cell fits per-device HBM,
#   * cost_analysis()    — HLO FLOPs / bytes for the roofline,
#   * collective bytes   — parsed from the post-SPMD optimized HLO,
# and write one JSON artifact per cell under benchmarks/artifacts/dryrun/.
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --multi-pod
#   python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.launch.specs import build_cell
from repro.obs import get_logger

log = get_logger("dryrun")

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective family, from optimized HLO.

    Operand shapes are parsed from each collective instruction's argument
    list (post-partitioning => per-device shard shapes)."""
    out: dict = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # operand shapes: everything inside the call parens
        call = line.split(m.group(1), 1)[1]
        if "(" not in call:
            continue
        args = call[call.index("(") + 1 :]
        depth = 1
        for i, ch in enumerate(args):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                args = args[:i]
                break
        nbytes = sum(_shape_bytes(d, s) for d, s in SHAPE_RE.findall(args))
        e = out.setdefault(kind, {"count": 0, "bytes": 0})
        e["count"] += 1
        e["bytes"] += nbytes
    return out


def _mesh_for(cell_kind: str, arch: str, multi_pod: bool, num_chains=None):
    if cell_kind == "train":
        k = num_chains if num_chains is not None else configs.EC_CHAINS[arch]
        return mesh_lib.make_train_mesh(k, multi_pod=multi_pod)
    return mesh_lib.make_production_mesh(multi_pod=multi_pod)


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: Path | None = None,
    num_chains=None,
    sync_every: int = 4,
    overrides: dict | None = None,
    tag: str = "",
    **cell_kw,
) -> dict:
    kind = configs.SHAPES[shape_name].kind
    mesh = _mesh_for(kind, arch, multi_pod, num_chains)
    t0 = time.time()
    cell = build_cell(
        arch, shape_name, mesh, num_chains=num_chains, sync_every=sync_every,
        overrides=overrides, **cell_kw,
    )
    with mesh:
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                    "alias_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # CPU backend may not implement it
            mem_rec = {"error": str(e)}
        cost = compiled.cost_analysis() or {}
        cost_rec = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)

    n_dev = mesh.size
    # per-device live bytes at step start: args (params+state+batch+cache)
    arg_bytes = mem_rec.get("argument_size_in_bytes")
    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": kind,
        "mesh": {k: int(v) for k, v in mesh.shape.items()},
        "devices": n_dev,
        "multi_pod": multi_pod,
        "num_chains": cell.num_chains,
        "sync_every": sync_every,
        "tag": tag,
        "model_flops": cell.model_flops,
        "meta": cell.meta,
        "memory_analysis": mem_rec,
        "cost_analysis": cost_rec,
        "collectives": coll,
        "collective_bytes_per_device": sum(v["bytes"] for v in coll.values()),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        mesh_tag = "pod2" if multi_pod else "pod1"
        suffix = f"__{tag}" if tag else ""
        path = out_dir / f"{arch}__{shape_name}__{mesh_tag}{suffix}.json"
        path.write_text(json.dumps(record, indent=1))
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(configs.ARCH_IDS))
    ap.add_argument("--shape", choices=list(configs.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--chains", type=int, default=None)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args(argv)
    out_dir = Path(args.out)

    if args.all:
        pods = [False, True] if args.both_meshes or not args.multi_pod else [True]
        if args.both_meshes:
            pods = [False, True]
        todo = [(a, c.name, mp) for (a, c) in configs.all_cells() for mp in pods]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        todo = [(args.arch, args.shape, args.multi_pod)]

    failures = []
    for arch, shape, mp in todo:
        label = f"{arch} x {shape} x {'2-pod(512)' if mp else '1-pod(256)'}"
        try:
            rec = run_cell(arch, shape, mp, out_dir, args.chains, args.sync_every, tag=args.tag)
            log.info(
                f"[ok] {label}: compile={rec['compile_s']}s "
                f"flops/dev={rec['cost_analysis'].get('flops', float('nan')):.3e} "
                f"coll_B/dev={rec['collective_bytes_per_device']:.3e} "
                f"args/dev={rec['memory_analysis'].get('argument_size_in_bytes', -1)}"
            )
        except Exception as e:
            failures.append((label, repr(e)))
            log.error(f"[FAIL] {label}: {e!r}")
            traceback.print_exc()
    if failures:
        log.error(f"{len(failures)} cell(s) FAILED:")
        for l, e in failures:
            log.error(f"  {l}: {e}")
        sys.exit(1)
    log.info(f"all {len(todo)} cells compiled OK")


if __name__ == "__main__":
    main()
