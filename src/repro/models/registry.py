"""Model registry: family -> ModelDef (the uniform model interface)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from . import encdec, transformer
from .common import ModelConfig


class PagedDef(NamedTuple):
    """Optional paged-KV decode surface (DESIGN.md §8); attn-only models."""

    check_support: Callable  # (cfg) -> None or raises ValueError
    make_pools: Callable  # (cfg, num_pages, block_size, dtype, abstract) -> pools
    prefill_write: Callable  # (cfg, pools, slot_cache, table_row, block_size) -> pools
    decode_step: Callable  # (cfg, params, pools, tokens, tables, ctx, write_block) -> (logits, pools)


class ModelDef(NamedTuple):
    param_specs: Callable  # (cfg) -> spec tree
    train_nll: Callable  # (cfg, params, batch) -> (sum_nll, count)
    prefill: Callable  # (cfg, params, batch, max_seq, cache_dtype) -> (logits, cache)
    decode_step: Callable  # (cfg, params, cache, tokens) -> (logits, cache)
    make_cache: Callable  # (cfg, batch, max_seq, dtype, abstract) -> cache
    cache_axes: Callable  # (cfg) -> logical-axis tree matching make_cache
    paged: PagedDef | None = None  # block-paged decode; None => dense-only


_LM = ModelDef(
    param_specs=transformer.param_specs,
    train_nll=transformer.train_nll,
    prefill=transformer.prefill,
    decode_step=transformer.decode_step,
    make_cache=transformer.make_cache,
    cache_axes=transformer.cache_axes,
    paged=PagedDef(
        check_support=transformer.check_paged_support,
        make_pools=transformer.make_paged_pools,
        prefill_write=transformer.paged_prefill_write,
        decode_step=transformer.paged_decode_step,
    ),
)

_ENCDEC = ModelDef(
    param_specs=encdec.param_specs,
    train_nll=encdec.train_nll,
    prefill=encdec.prefill,
    decode_step=encdec.decode_step,
    make_cache=encdec.make_cache,
    cache_axes=encdec.cache_axes,
)


def get_model(cfg: ModelConfig) -> ModelDef:
    return _ENCDEC if cfg.family == "audio" else _LM
