"""Satellite gate: the fused Pallas EC-SGHMC kernel (interpret mode,
stochastic rounding off, noise bits supplied) must match the pure-jnp
``p_step`` path of ``repro.core.ec_sghmc`` BIT-FOR-BIT in f32.

The two implementations share term grouping by construction (see the
``p_step`` docstring); both sides are jitted so XLA makes the same
contraction decisions.  Runs in a bare environment — no hypothesis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ec_sghmc import p_step
from repro.kernels import ref
from repro.kernels.fused_ecsghmc import fused_ec_update_flat, fused_precond_ec_update_flat

SHAPE = (8, 1024)  # one kernel block


def _operands(seed):
    k = jax.random.PRNGKey(seed)
    kt, kp, kg, kc, k1, k2 = jax.random.split(k, 6)
    return (
        jax.random.normal(kt, SHAPE, jnp.float32),
        0.1 * jax.random.normal(kp, SHAPE, jnp.float32),
        jax.random.normal(kg, SHAPE, jnp.float32),
        jax.random.normal(kc, SHAPE, jnp.float32),
        jax.random.bits(k1, SHAPE, jnp.uint32),
        jax.random.bits(k2, SHAPE, jnp.uint32),
    )


@pytest.mark.parametrize("seed", [0, 42, 1234])
@pytest.mark.parametrize(
    "hyper",
    [
        dict(eps=1e-2, friction=1.0, mass=1.0, alpha=0.7, sigma_p=0.05),
        dict(eps=0.1, friction=1.5, mass=2.0, alpha=1.0, sigma_p=0.2),
        dict(eps=5e-3, friction=0.0, mass=1.0, alpha=0.0, sigma_p=0.0),
    ],
    ids=["paper", "heavy", "degenerate"],
)
def test_fused_matches_p_step_bitwise(seed, hyper):
    theta, p, g, c, bits1, bits2 = _operands(seed)

    @jax.jit
    def fused(theta, p, g, c, bits1, bits2):
        return fused_ec_update_flat(
            theta, p, g, c, bits1, bits2,
            stochastic_round=False, onchip_prng=False, interpret=True, **hyper,
        )

    @jax.jit
    def unfused(theta, p, g, c, bits1, bits2):
        # identical noise law: Box-Muller from the same counter bits
        noise = ref.box_muller(bits1, bits2)
        p_new = p_step(
            p, g, theta, c, noise,
            eps=hyper["eps"], friction=hyper["friction"], minv=1.0 / hyper["mass"],
            alpha=hyper["alpha"], sigma_p=hyper["sigma_p"],
        )
        theta_new = theta + hyper["eps"] * (1.0 / hyper["mass"]) * p
        return theta_new, p_new

    t_f, p_f = fused(theta, p, g, c, bits1, bits2)
    t_u, p_u = unfused(theta, p, g, c, bits1, bits2)
    np.testing.assert_array_equal(np.asarray(t_f), np.asarray(t_u),
                                  err_msg="theta' not bit-identical")
    np.testing.assert_array_equal(np.asarray(p_f), np.asarray(p_u),
                                  err_msg="p' not bit-identical")


@pytest.mark.parametrize("seed", [0, 42])
@pytest.mark.parametrize(
    "hyper",
    [
        dict(eps=1e-2, friction=1.0, alpha=0.7, sigma_p=0.05),
        dict(eps=0.1, friction=1.5, alpha=1.0, sigma_p=0.2),
    ],
    ids=["paper", "heavy"],
)
def test_fused_precond_matches_p_step_bitwise(seed, hyper):
    """Preconditioned variant of the pin above: the M⁻¹-streaming kernel
    must match ``p_step`` with an *array* minv bit-for-bit, including the
    preconditioned drift theta' = theta + ε·M⁻¹·p."""
    theta, p, g, c, bits1, bits2 = _operands(seed)
    km = jax.random.PRNGKey(seed + 1000)
    # strictly positive, well away from 1.0 so the multiply is non-trivial
    minv = jnp.exp(0.5 * jax.random.normal(km, SHAPE, jnp.float32))

    @jax.jit
    def fused(theta, p, g, c, minv, bits1, bits2):
        return fused_precond_ec_update_flat(
            theta, p, g, c, minv, bits1, bits2,
            stochastic_round=False, onchip_prng=False, interpret=True, **hyper,
        )

    @jax.jit
    def unfused(theta, p, g, c, minv, bits1, bits2):
        noise = ref.box_muller(bits1, bits2)
        p_new = p_step(
            p, g, theta, c, noise,
            eps=hyper["eps"], friction=hyper["friction"], minv=minv,
            alpha=hyper["alpha"], sigma_p=hyper["sigma_p"],
        )
        theta_new = theta + hyper["eps"] * minv * p
        return theta_new, p_new

    t_f, p_f = fused(theta, p, g, c, minv, bits1, bits2)
    t_u, p_u = unfused(theta, p, g, c, minv, bits1, bits2)
    np.testing.assert_array_equal(np.asarray(t_f), np.asarray(t_u),
                                  err_msg="theta' not bit-identical")
    np.testing.assert_array_equal(np.asarray(p_f), np.asarray(p_u),
                                  err_msg="p' not bit-identical")


def test_sampler_level_fused_equals_unfused_in_law():
    """End-to-end: one ec_sghmc step, fused vs unfused.  Different PRNG
    streams (counter bits vs jax.random.normal) forbid bitwise equality at
    the sampler level, but with temperature=0 the noise vanishes and the
    two dispatch paths must agree to f32 roundoff on identical dynamics."""
    from repro import core

    kw = dict(step_size=1e-2, alpha=1.0, temperature=0.0)
    params = jax.random.normal(jax.random.PRNGKey(5), (4, 128))
    grads = 1.3 * (params - 0.2)
    rng = jax.random.PRNGKey(7)

    outs = {}
    for fused in (False, True):
        sampler = core.ec_sghmc(fused=fused, **kw)
        st = sampler.init(params)
        # two steps so momentum is non-zero when the kernel runs
        upd, st = sampler.update(grads, st, params=params, rng=rng)
        p1 = core.apply_updates(params, upd)
        upd, st = sampler.update(1.3 * (p1 - 0.2), st, params=p1, rng=rng)
        outs[fused] = (np.asarray(core.apply_updates(p1, upd)), np.asarray(st.momentum))

    np.testing.assert_allclose(outs[True][0], outs[False][0], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(outs[True][1], outs[False][1], rtol=1e-6, atol=1e-6)
