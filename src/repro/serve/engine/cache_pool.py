"""Slot-indexed KV/recurrent cache pool for the serving engine.

One pre-allocated pytree holds every decode slot's cache for every ensemble
member: each leaf of ``model.make_cache(cfg, batch=1, max_seq)`` is pooled
with a leading ``(K, num_slots)`` axis.  The pool is allocated ONCE at
engine construction; admissions and completions recycle slots by index —
no per-request allocation, no shape change, hence no retrace of the decode
program as streams join and leave.

Slots are also the engine's suspension unit: ``park`` lifts one slot's
cache out of the live pool (optionally through the int8 block codec from
``repro.distributed.compression`` — 4x smaller idle footprint, and the same
soundness argument as compressing the EC sync collective: a perturbed
cache/center is what the elastically coupled ensemble is designed to
tolerate), and ``restore`` decodes it back into any free slot.  Float
leaves round-trip through int8; integer leaves (ring-buffer pointers ``t``)
are kept exact.

``PagedCachePool`` is the block-paged alternative (DESIGN.md §8): instead
of one dense ``max_seq`` stripe per slot, KV lives in a flat pool of
fixed-size pages handed out by a host-side ``BlockAllocator`` (freelist +
refcounted prefix sharing + worst-case growth reservations).  Block tables
and context lengths stay host-resident numpy and enter the decode program
as DATA, so slot churn never retraces.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import int8_codec
from repro.obs import trace as obs_trace


class ParkedCache(NamedTuple):
    """A slot's cache lifted out of the live pool (possibly compressed)."""

    leaves: list
    treedef: Any
    compressed: bool


class CachePool:
    """Pre-allocated (K, num_slots, ...) cache pool with free-list recycling.

    The engine owns ``caches`` and is expected to REPLACE it after every
    jitted step (the pooled buffers are donated through the decode/admit
    programs).  The pool itself only tracks slot occupancy and park/restore.
    """

    def __init__(
        self,
        cfg,
        model,
        *,
        num_members: int,
        num_slots: int,
        max_seq: int,
        dtype=None,
        compress_parked: bool = False,
    ):
        if num_members < 1 or num_slots < 1:
            raise ValueError("num_members and num_slots must be >= 1")
        self.num_members = int(num_members)
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.compress_parked = bool(compress_parked)
        self._codec = int8_codec()
        proto = model.make_cache(cfg, 1, max_seq, dtype or cfg.compute_dtype, abstract=True)
        self.slot_shape = jax.tree.map(lambda s: (s.shape, s.dtype), proto)
        self.caches = jax.tree.map(
            lambda s: jnp.zeros((self.num_members, self.num_slots) + s.shape, s.dtype),
            proto,
        )
        self._free = list(range(self.num_slots - 1, -1, -1))  # pop() -> slot 0 first
        self.acquired = 0
        self.released = 0
        self.high_water = 0

    # -- slot bookkeeping ---------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> int:
        return self.num_slots - len(self._free)

    def acquire(self) -> int:
        """Claim a free slot index; raises IndexError when the pool is full
        (the scheduler checks ``free_slots`` before admitting)."""
        slot = self._free.pop()
        self.acquired += 1
        self.high_water = max(self.high_water, self.active_slots)
        return slot

    def release(self, slot: int) -> None:
        if slot in self._free or not (0 <= slot < self.num_slots):
            raise ValueError(f"release of non-acquired slot {slot}")
        self._free.append(slot)
        self.released += 1

    # -- park / restore (idle-slot compression) -----------------------------

    def park(self, slot: int, *, release: bool = True) -> ParkedCache:
        """Lift slot ``slot``'s cache out of the live pool.  With
        ``compress_parked`` float leaves go through the int8 block codec
        (~4x smaller); int leaves stay exact.  ``release`` frees the slot."""
        with obs_trace.get().span("pool.park", cat="pool", slot=slot):
            leaves, treedef = jax.tree.flatten(
                jax.tree.map(lambda a: a[:, slot], self.caches)
            )
            if self.compress_parked:
                leaves = [
                    self._codec.encode(x) if jnp.issubdtype(x.dtype, jnp.floating) else x
                    for x in leaves
                ]
            if release:
                self.release(slot)
            return ParkedCache(leaves, treedef, self.compressed_parking)

    def restore(self, parked: ParkedCache, slot: int | None = None) -> int:
        """Write a parked cache back into ``slot`` (or a newly acquired
        one); returns the slot index."""
        if slot is None:
            slot = self.acquire()
        with obs_trace.get().span("pool.restore", cat="pool", slot=slot):
            leaves = [
                self._codec.decode(x) if isinstance(x, dict) and "q" in x else x
                for x in parked.leaves
            ]
            one = jax.tree.unflatten(parked.treedef, leaves)
            self.caches = jax.tree.map(
                lambda full, x: full.at[:, slot].set(x.astype(full.dtype)), self.caches, one
            )
            return slot

    @property
    def compressed_parking(self) -> bool:
        return self.compress_parked

    def can_admit(self, prompt, max_new: int, version: int = 0) -> bool:
        """Dense slots always fit a request that passed the max_seq guard."""
        del prompt, max_new, version
        return True

    def stats(self) -> dict:
        return {
            "num_slots": self.num_slots,
            "active": self.active_slots,
            "high_water": self.high_water,
            "acquired": self.acquired,
            "released": self.released,
        }


# ---------------------------------------------------------------------------
# Block-paged pool (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _blocks_for(positions: int, block_size: int) -> int:
    return -(-max(int(positions), 0) // block_size)


class BlockAllocator:
    """Host-side page bookkeeping for the paged KV pool.

    Pure python/numpy — no device state — so the allocator invariants are
    property-testable at interleaving granularity (tests/test_paged_cache.py).

    Contract:
      * page 0 is the reserved SINK: never allocated, never freed; free/done
        slots' decode writes are redirected there and nothing reads it.
      * ``tables`` (num_slots, M) int32 rows map a slot's logical blocks to
        pages; allocated entries form a contiguous prefix of the row, the
        rest is sink.  ``ctx`` (num_slots,) is the slot's current position.
      * prefix sharing: the FULL prompt blocks (``plen // bs`` of them) of
        a prompt are registered under (registry_version, prompt bytes); a
        later admit with the same key increfs those pages instead of
        allocating.  Every sharer holds a reference on every shared page,
        so an entry's refcounts move in lockstep and pages are freed
        exactly once, when the last sharer releases.
      * admission is AIRTIGHT: ``can_admit`` charges the request's whole
        worst-case growth (``plen + max_new - 1`` positions) against
        ``free - outstanding reservations``, so a request that admits can
        never hit pool exhaustion mid-decode.
    """

    def __init__(self, *, num_blocks: int, block_size: int, max_seq: int,
                 num_slots: int, prefix_sharing: bool = True):
        if block_size < 1 or num_slots < 1:
            raise ValueError("block_size and num_slots must be >= 1")
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (page 0 is the sink)")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.max_seq = int(max_seq)
        self.num_slots = int(num_slots)
        self.prefix_sharing = bool(prefix_sharing)
        self.blocks_per_slot = _blocks_for(max_seq, block_size)  # M
        self._free = list(range(self.num_blocks - 1, 0, -1))  # pop() -> page 1 first
        self.refcount = np.zeros(self.num_blocks, np.int32)
        self.tables = np.zeros((self.num_slots, self.blocks_per_slot), np.int32)
        self.ctx = np.zeros((self.num_slots,), np.int32)
        self._owned: dict[int, list] = {}
        self._reserved: dict[int, int] = {}
        self._prefix: dict = {}  # key -> list of page ids
        self._block_prefix: dict = {}  # page id -> key (a page is in <= 1 entry)
        self.blocks_high_water = 0
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.shared_block_hits = 0
        self.prefix_invalidated = 0

    # -- internals ----------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    @property
    def reserved_blocks(self) -> int:
        return sum(self._reserved.values())

    def _alloc(self) -> int:
        if not self._free:
            raise RuntimeError("page pool exhausted (admission gate broken?)")
        b = self._free.pop()
        self.refcount[b] = 1
        self.blocks_high_water = max(self.blocks_high_water, self.used_blocks)
        return b

    def _decref(self, b: int) -> None:
        self.refcount[b] -= 1
        if self.refcount[b] < 0:
            raise RuntimeError(f"page {b} refcount underflow")
        if self.refcount[b] == 0:
            key = self._block_prefix.pop(b, None)
            if key is not None:
                self._prefix.pop(key, None)
            self._free.append(b)

    def invalidate_version(self, version: int) -> int:
        """Eagerly drop prefix-sharing entries from superseded registry
        versions.  Entries are keyed on ``(registry_version, prompt bytes)``,
        so after a promotion the old-version entries can never be hit again —
        without this they linger (holding their ``_block_prefix``
        back-pointers) until the last sharer happens to exit.  Current
        sharers are untouched: pages stay refcounted by their slots and are
        freed exactly once, by the existing ``_decref`` path (which tolerates
        the missing back-pointer).  Returns the number of entries dropped."""
        stale = [k for k in self._prefix if k[0] != int(version)]
        for k in stale:
            for b in self._prefix.pop(k):
                self._block_prefix.pop(b, None)
        self.prefix_invalidated += len(stale)
        return len(stale)

    def _prefix_key(self, prompt: np.ndarray, version: int):
        n_full = prompt.size // self.block_size
        if not (self.prefix_sharing and n_full):
            return None, 0
        return (int(version), prompt[: n_full * self.block_size].tobytes()), n_full

    # -- admission ----------------------------------------------------------

    def can_admit(self, prompt, max_new: int, version: int = 0) -> bool:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        total = _blocks_for(prompt.size + max_new - 1, self.block_size)
        if total > self.blocks_per_slot:
            return False
        now = _blocks_for(prompt.size, self.block_size)
        key, n_full = self._prefix_key(prompt, version)
        shared = n_full if (key is not None and key in self._prefix) else 0
        need = (now - shared) + (total - now)
        return need <= len(self._free) - self.reserved_blocks

    def admit(self, slot: int, prompt, max_new: int, version: int = 0) -> np.ndarray:
        """Map ``prompt`` into pages for ``slot``; returns the (M,) int32
        table row.  Callers gate on :meth:`can_admit` first — exhaustion
        here means the reservation accounting is broken."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if slot in self._owned:
            raise ValueError(f"slot {slot} already admitted")
        now = _blocks_for(prompt.size, self.block_size)
        total = _blocks_for(prompt.size + max_new - 1, self.block_size)
        if total > self.blocks_per_slot:
            raise ValueError(
                f"prompt_len + max_new needs {total} blocks > "
                f"blocks_per_slot={self.blocks_per_slot}"
            )
        key, n_full = self._prefix_key(prompt, version)
        row = np.zeros(self.blocks_per_slot, np.int32)
        owned: list = []
        if key is not None:
            self.prefix_queries += 1
            entry = self._prefix.get(key)
            if entry is not None:
                self.prefix_hits += 1
                self.shared_block_hits += n_full
                for j, b in enumerate(entry):
                    self.refcount[b] += 1
                    row[j] = b
                    owned.append(b)
            else:
                entry = [self._alloc() for _ in range(n_full)]
                for j, b in enumerate(entry):
                    row[j] = b
                    owned.append(b)
                    self._block_prefix[b] = key
                self._prefix[key] = entry
            start = n_full
        else:
            start = 0
        for j in range(start, now):
            b = self._alloc()
            row[j] = b
            owned.append(b)
        self.tables[slot] = row
        self.ctx[slot] = prompt.size
        self._owned[slot] = owned
        self._reserved[slot] = total - now
        obs_trace.get().instant(
            "alloc.reserve", cat="alloc", slot=slot, pages=len(owned),
            reserved=total - now, free=len(self._free),
        )
        return row

    # -- decode-time growth --------------------------------------------------

    def ensure_decode_block(self, slot: int) -> None:
        """Guarantee the page holding position ``ctx[slot]`` exists before a
        decode tick writes there (draws down this slot's reservation)."""
        if slot not in self._owned:
            raise ValueError(f"slot {slot} not admitted")
        j = int(self.ctx[slot]) // self.block_size
        if j >= self.blocks_per_slot:
            raise RuntimeError(
                f"slot {slot} position {int(self.ctx[slot])} overflows "
                f"max_seq={self.max_seq} (engine guard breached)"
            )
        if self.tables[slot, j] == 0:
            b = self._alloc()
            self.tables[slot, j] = b
            self._owned[slot].append(b)
            self._reserved[slot] = max(0, self._reserved[slot] - 1)
            obs_trace.get().instant("alloc.grow", cat="alloc", slot=slot, page=b)

    def advance(self, slot: int) -> None:
        self.ctx[slot] += 1

    # -- release -------------------------------------------------------------

    def release(self, slot: int) -> None:
        if slot not in self._owned:
            raise ValueError(f"release of non-admitted slot {slot}")
        owned = self._owned.pop(slot)
        for b in owned:
            self._decref(b)
        self.tables[slot] = 0
        self.ctx[slot] = 0
        self._reserved.pop(slot, None)
        obs_trace.get().instant(
            "alloc.free", cat="alloc", slot=slot, pages=len(owned),
            free=len(self._free),
        )

    # -- invariants (property-test surface) ----------------------------------

    def check(self) -> None:
        """Raise AssertionError on any broken freelist/refcount invariant."""
        free = self._free
        assert len(set(free)) == len(free), "duplicate pages in freelist"
        assert all(0 < b < self.num_blocks for b in free), "sink/oob page freed"
        assert all(self.refcount[b] == 0 for b in free), "freed page still referenced"
        assert self.refcount[0] == 0, "sink page acquired a refcount"
        in_use = {int(b) for bs_ in self._owned.values() for b in bs_}
        assert 0 not in in_use, "sink page owned by a slot"
        assert len(free) + len(in_use) == self.num_blocks - 1, "page leak/double-book"
        counts: dict[int, int] = {}
        for blocks in self._owned.values():
            assert len(set(blocks)) == len(blocks), "slot owns a page twice"
            for b in blocks:
                counts[b] = counts.get(b, 0) + 1
        for b, c in counts.items():
            assert self.refcount[b] == c, f"page {b}: refcount {self.refcount[b]} != owners {c}"
        for slot, blocks in self._owned.items():
            row = self.tables[slot]
            nz = row[row != 0]
            assert list(nz) == [b for b in row[: len(nz)]], "table row not prefix-contiguous"
            assert set(int(b) for b in nz) == set(blocks), "table row != owned pages"
        assert all(v >= 0 for v in self._reserved.values()), "negative reservation"

    def stats(self) -> dict:
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "blocks_used": self.used_blocks,
            "blocks_free": len(self._free),
            "blocks_high_water": self.blocks_high_water,
            "blocks_reserved": self.reserved_blocks,
            "prefix_queries": self.prefix_queries,
            "prefix_hits": self.prefix_hits,
            "shared_block_hits": self.shared_block_hits,
            "prefix_invalidated": self.prefix_invalidated,
        }


class PagedParked(NamedTuple):
    """One slot's pages lifted out of the paged pool (gathered in logical
    block order; possibly int8-compressed)."""

    leaves: list
    treedef: Any
    compressed: bool
    ctx: int
    num_pages: int


def _page_axis(leaf) -> int:
    # member-stacked pool leaves are (K, [n_periods,] num_pages, bs, Hkv, dh):
    # the page axis always sits 4 dims from the end
    return leaf.ndim - 4


class PagedCachePool:
    """Block-paged drop-in for :class:`CachePool` (DESIGN.md §8).

    Device state is one pytree of flat page pools with a leading member
    axis: each leaf of ``model.paged.make_pools`` pooled to
    ``(K, [n_periods,] num_pages, block_size, Hkv, dh)``.  Slot occupancy,
    block tables, context lengths, refcounts and reservations are host-side
    numpy in ``self.alloc`` — the engine ships tables/ctx into the decode
    program as data each tick.
    """

    def __init__(
        self,
        cfg,
        model,
        *,
        num_members: int,
        num_slots: int,
        max_seq: int,
        block_size: int = 16,
        num_blocks: int | None = None,
        dtype=None,
        compress_parked: bool = False,
        prefix_sharing: bool = True,
    ):
        if model.paged is None:
            raise ValueError("model has no paged decode surface (ModelDef.paged is None)")
        if num_members < 1 or num_slots < 1:
            raise ValueError("num_members and num_slots must be >= 1")
        model.paged.check_support(cfg)
        self.cfg, self.model = cfg, model
        self.num_members = int(num_members)
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.block_size = int(block_size)
        M = _blocks_for(max_seq, block_size)
        if num_blocks is None:
            num_blocks = num_slots * M + 1  # worst case concurrency + sink
        self.compress_parked = bool(compress_parked)
        self._codec = int8_codec()
        self.alloc = BlockAllocator(
            num_blocks=num_blocks, block_size=block_size, max_seq=max_seq,
            num_slots=num_slots, prefix_sharing=prefix_sharing,
        )
        proto = model.paged.make_pools(cfg, num_blocks, block_size,
                                       dtype or cfg.compute_dtype, abstract=True)
        self.caches = jax.tree.map(
            lambda s: jnp.zeros((self.num_members,) + s.shape, s.dtype), proto
        )
        self._bytes_per_page = sum(
            leaf.size * leaf.dtype.itemsize // num_blocks
            for leaf in jax.tree.leaves(self.caches)
        )
        self._free = list(range(self.num_slots - 1, -1, -1))
        self.acquired = 0
        self.released = 0
        self.high_water = 0

    # -- slot bookkeeping (CachePool-compatible surface) ---------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> int:
        return self.num_slots - len(self._free)

    @property
    def tables(self) -> np.ndarray:
        return self.alloc.tables

    @property
    def ctx(self) -> np.ndarray:
        return self.alloc.ctx

    def acquire(self) -> int:
        slot = self._free.pop()
        self.acquired += 1
        self.high_water = max(self.high_water, self.active_slots)
        return slot

    def release(self, slot: int) -> None:
        if slot in self._free or not (0 <= slot < self.num_slots):
            raise ValueError(f"release of non-acquired slot {slot}")
        if slot in self.alloc._owned:
            self.alloc.release(slot)
        self._free.append(slot)
        self.released += 1

    # -- admission / growth ---------------------------------------------------

    def can_admit(self, prompt, max_new: int, version: int = 0) -> bool:
        return self.alloc.can_admit(prompt, max_new, version)

    def admit_blocks(self, slot: int, prompt, max_new: int, version: int = 0) -> np.ndarray:
        return self.alloc.admit(slot, prompt, max_new, version)

    def ensure_decode_block(self, slot: int) -> None:
        self.alloc.ensure_decode_block(slot)

    def advance(self, slot: int) -> None:
        self.alloc.advance(slot)

    def invalidate_version(self, version: int) -> int:
        """Drop prefix entries superseded by a registry promotion (the
        engine calls this once per version bump)."""
        return self.alloc.invalidate_version(version)

    # -- park / restore -------------------------------------------------------

    def _slot_pages(self, slot: int) -> list:
        row = self.alloc.tables[slot]
        return [int(b) for b in row[row != 0]]

    def park(self, slot: int, *, release: bool = True) -> PagedParked:
        """Gather (copy) this slot's pages out of the pool in logical block
        order.  Shared prefix pages are COPIED, not moved — other sharers
        keep serving from them."""
        with obs_trace.get().span("pool.park", cat="pool", slot=slot):
            pages = self._slot_pages(slot)
            idx = jnp.asarray(pages, jnp.int32)
            gathered = jax.tree.map(
                lambda leaf: jnp.take(leaf, idx, axis=_page_axis(leaf)), self.caches
            )
            leaves, treedef = jax.tree.flatten(gathered)
            if self.compress_parked:
                leaves = [
                    self._codec.encode(x) if jnp.issubdtype(x.dtype, jnp.floating) else x
                    for x in leaves
                ]
            ctx = int(self.alloc.ctx[slot])
            if release:
                self.release(slot)
            return PagedParked(leaves, treedef, self.compress_parked, ctx, len(pages))

    def restore(self, parked: PagedParked, slot: int | None = None,
                max_new: int = 1) -> int:
        """Allocate fresh pages for a parked cache and scatter it back;
        returns the slot.  ``max_new`` re-reserves the request's remaining
        growth (a restored slot must stay exhaustion-proof too)."""
        if len(self.alloc._free) < parked.num_pages:
            raise RuntimeError("not enough free pages to restore parked cache")
        if slot is None:
            slot = self.acquire()
        restore_span = obs_trace.get().span("pool.restore", cat="pool", slot=slot)
        restore_span.__enter__()
        a = self.alloc
        if slot in a._owned:
            raise ValueError(f"slot {slot} already holds pages")
        pages = [a._alloc() for _ in range(parked.num_pages)]
        row = np.zeros(a.blocks_per_slot, np.int32)
        row[: len(pages)] = pages
        a.tables[slot] = row
        a.ctx[slot] = parked.ctx
        a._owned[slot] = list(pages)
        total = _blocks_for(parked.ctx + max_new - 1, self.block_size)
        a._reserved[slot] = max(0, total - len(pages))
        leaves = [
            self._codec.decode(x) if isinstance(x, dict) and "q" in x else x
            for x in parked.leaves
        ]
        one = jax.tree.unflatten(parked.treedef, leaves)
        idx = jnp.asarray(pages, jnp.int32)

        def scatter(full, vals):
            ax = _page_axis(full)
            moved = jnp.moveaxis(full, ax, 0)
            moved = moved.at[idx].set(jnp.moveaxis(vals.astype(full.dtype), ax, 0))
            return jnp.moveaxis(moved, 0, ax)

        self.caches = jax.tree.map(scatter, self.caches, one)
        restore_span.__exit__(None, None, None)
        return slot

    @property
    def compressed_parking(self) -> bool:
        return self.compress_parked

    # -- stats ----------------------------------------------------------------

    @property
    def bytes_per_page(self) -> int:
        return self._bytes_per_page

    def stats(self) -> dict:
        a = self.alloc.stats()
        return {
            "num_slots": self.num_slots,
            "active": self.active_slots,
            "high_water": self.high_water,
            "acquired": self.acquired,
            "released": self.released,
            "paged": True,
            "bytes_per_page": self._bytes_per_page,
            "bytes_used": a["blocks_used"] * self._bytes_per_page,
            "bytes_high_water": a["blocks_high_water"] * self._bytes_per_page,
            "bytes_total": (a["num_blocks"] - 1) * self._bytes_per_page,
            "prefix_hit_rate": (
                a["prefix_hits"] / a["prefix_queries"] if a["prefix_queries"] else 0.0
            ),
            **a,
        }
