"""Serving steps: prefill (prompt -> cache) and single-token decode.

``decode_step``/``serve_step`` is what the decode_* and long_* dry-run cells
lower: one new token against a KV/recurrent cache of seq_len.  Token
selection goes through the shared ``repro.serve.sampling`` helper
(greedy / temperature / top-k), the same one the continuous-batching
engine (``repro.serve.engine``) uses — legacy and engine paths sample
identically given the same logits and key.

``ensemble_diagnostics`` reports the dispersion of a chain-ensemble before
it serves: a collapsed ensemble (zero spread) silently degrades Bayesian
model averaging to a single model, and the serving tier is where that must
be caught.

``collect_ensemble`` is the device-resident collection path: the sampler
run that produces the K ensemble members compiles as ONE chunked-scan
program (``repro.run.rollout``) with thinned trace collection — members
never round-trip to the host individually.  The interactive ``generate``
loop below is the single per-step Python loop this repo still allows."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.diagnostics import ensemble_spread
from repro.models import ModelDef
from repro.models.common import ModelConfig
from repro.run import rollout
from repro.serve.sampling import GREEDY, SamplingParams, mask_after_eos, select_tokens


def make_prefill_step(
    cfg: ModelConfig,
    model: ModelDef,
    max_seq: int,
    cache_dtype=None,
    sampling: SamplingParams = GREEDY,
):
    def prefill_step(params, batch, key=None):
        logits, cache = model.prefill(cfg, params, batch, max_seq, cache_dtype)
        next_tokens = select_tokens(logits[:, -1], key, sampling)[:, None]
        return next_tokens, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, model: ModelDef, sampling: SamplingParams = GREEDY):
    def serve_step(params, cache, tokens, key=None):
        logits, new_cache = model.decode_step(cfg, params, cache, tokens)
        next_tokens = select_tokens(logits[:, -1], key, sampling)[:, None]
        return next_tokens, new_cache

    return serve_step


def ensemble_diagnostics(params_stack, *, min_rel_spread: float = 1e-6) -> dict:
    """Ensemble-spread health report for a (K, ...)-stacked posterior
    ensemble about to serve.  Returns the shared spread summary plus a
    ``collapsed`` flag — K identical samples waste K× serve compute for a
    single model's predictions."""
    out = ensemble_spread(params_stack)
    out["collapsed"] = bool(out["rel_spread"] < min_rel_spread)
    return out


def collect_ensemble(
    sampler,
    grad_fn,
    params0,
    *,
    num_samples: int,
    key,
    thin: int = 16,
    burn: int | None = None,
):
    """Draw ``num_samples`` ensemble members as thinned posterior samples of
    one device-resident sampler run.

    The whole run — burn-in, thinning, trace collection — is a single
    chunked ``lax.scan`` program; only the (num_samples, ...) member stack
    comes back to the host, stacked on a leading axis ready for
    ``ensemble_decode`` / ``ensemble_diagnostics``.  ``grad_fn(theta)``
    is the gradient of whatever potential the ensemble should target
    (posterior for a trained model, prior bootstrap for a demo).  ``burn``
    defaults to one thinning interval and is rounded up so every kept
    sample is post-burn-in."""
    if num_samples < 1 or thin < 1:
        raise ValueError("num_samples and thin must be >= 1")
    burn = thin if burn is None else thin * -(-burn // thin)  # ceil to a thin multiple
    steps = burn + num_samples * thin
    keys = jax.random.split(key, steps)
    res = rollout(
        sampler, grad_fn, params0,
        num_steps=steps, keys=keys, thin=thin, moments=False,
        chunk_steps=steps,
    )
    members = jax.tree.map(lambda a: jnp.asarray(a[-num_samples:]), res.trace)
    return members, res


def generate(
    cfg: ModelConfig,
    model: ModelDef,
    params,
    batch,
    max_seq: int,
    num_tokens: int,
    *,
    sampling: SamplingParams = GREEDY,
    key=None,
    eos_id: int | None = None,
    pad_id: int = 0,
):
    """Host-side generation loop (examples / integration tests).

    Stops as soon as EVERY sequence has emitted ``eos_id`` (when given)
    instead of always decoding to the full ``num_tokens`` budget, and masks
    everything after each row's first EOS with ``pad_id`` — so the returned
    array may have fewer than ``num_tokens`` columns.  ``sampling``/``key``
    select tokens through the shared helper (greedy by default)."""
    if sampling.temperature > 0 and key is None:
        raise ValueError("temperature > 0 sampling needs key=")
    prompt_len = int(batch["tokens"].shape[-1])
    if prompt_len + num_tokens > max_seq:
        # the cache write clamps at max_seq-1 (dynamic_update_slice
        # semantics), which would silently overwrite the last position
        # instead of failing — same guard as ServeEngine admission
        raise ValueError(
            f"prompt_len + num_tokens = {prompt_len + num_tokens} exceeds "
            f"max_seq={max_seq}"
        )
    prefill = jax.jit(make_prefill_step(cfg, model, max_seq, sampling=sampling))
    step = jax.jit(make_decode_step(cfg, model, sampling=sampling))
    step_key = lambda i: None if key is None else jax.random.fold_in(key, i)
    tok, cache = prefill(params, batch, step_key(0))
    out = [tok]
    done = (tok == eos_id) if eos_id is not None else None
    for i in range(num_tokens - 1):
        if eos_id is not None and bool(done.all()):
            break
        tok, cache = step(params, cache, tok, step_key(i + 1))
        out.append(tok)
        if eos_id is not None:
            done = done | (tok == eos_id)
    seq = jnp.concatenate(out, axis=1)
    if eos_id is not None:
        seq = mask_after_eos(seq, eos_id, pad_id)
    return seq
