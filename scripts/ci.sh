#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh            lint (if ruff is installed) + tier-1 suite with
#                            the slow stationary configs deselected (~10 min
#                            on CPU) + an overhead-bench smoke run that
#                            regenerates BENCH_overhead.json
#   RUN_SLOW=1 scripts/ci.sh ...then the slow stationary battery on top
#   SKIP_BENCH=1 scripts/ci.sh  skip the bench smoke (pure test runs)
#   scripts/ci.sh <args>     extra args forwarded to the fast pytest run
#
# The canonical tier-1 command (ROADMAP.md) remains
#   PYTHONPATH=src python -m pytest -x -q
# which runs EVERYTHING including slow-marked configs; this script is the
# quick gate that still exercises a fast subset of the stationary battery.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if command -v ruff >/dev/null 2>&1; then
  echo "== ruff check =="
  ruff check src benchmarks tests
else
  echo "== ruff not installed — skipping lint (pip install ruff to enable) =="
fi

echo "== tier-1 (fast: -m 'not slow') =="
python -m pytest -x -q -m "not slow" "$@"

echo "== multidevice lane (forced 8-CPU-device child pytest, -m multidevice) =="
REPRO_MULTIDEVICE_CHILD=1 JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" \
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
  python -m pytest -x -q -m multidevice

if [[ "${SKIP_BENCH:-0}" != "1" ]]; then
  echo "== bench smoke: overhead (writes BENCH_overhead.json) =="
  REPRO_BENCH_QUICK=1 python -m benchmarks.run --bench overhead

  echo "== telemetry gate: instrumented-vs-off overhead < 3% + manifest schema =="
  python - <<'PY'
import json
from repro.obs.validate import validate_manifest

d = json.load(open("BENCH_overhead.json"))
ob = d["perf"]["obs_overhead"]
assert ob["overhead_pct"] < 3.0, f"tracer overhead {ob['overhead_pct']:.2f}% >= 3%"
assert ob["implied_pct"] < 3.0, f"implied span cost {ob['implied_pct']:.3f}% >= 3%"
errs = validate_manifest(d["manifest"])
assert not errs, errs
print(f"overhead {ob['overhead_pct']:+.2f}% end-to-end "
      f"(span-cost bound {ob['implied_pct']:.3f}%); BENCH manifest OK")
PY

  echo "== traced serve smoke: live-refresh engine run -> Perfetto trace.json =="
  TRACE_OUT="$(mktemp -t repro_trace_XXXXXX.json)"
  python -m repro.launch.serve --arch qwen3-0.6b --smoke --engine --slots 2 \
    --requests 6 --ensemble 2 --refresh-every 4 --gen 6 --trace "$TRACE_OUT"
  python -m repro.obs "$TRACE_OUT" --require serve
  rm -f "$TRACE_OUT"
  echo "== bench smoke: serve engine incl. refresh-SLO row (overlapped vs frozen p99; writes BENCH_serve.json) =="
  REPRO_BENCH_QUICK=1 python -m benchmarks.run serve
  echo "== bench smoke: adaptive tier (preconditioned vs plain ESS/sec; writes BENCH_adaptive.json) =="
  REPRO_BENCH_QUICK=1 python -m benchmarks.run adaptive
  echo "== bench smoke: shard sweep (forced 1/2/4/8-device children; writes BENCH_shard.json) =="
  REPRO_BENCH_QUICK=1 python -m benchmarks.run shard
fi

if [[ "${RUN_SLOW:-0}" == "1" ]]; then
  echo "== stationary battery (slow configs) =="
  python -m pytest -q -m slow tests/test_stationary.py
fi
