"""Streaming moment accumulation over pytrees (Welford / Chan).

The accumulator is itself a pytree of arrays, so it jits, donates, and rides
as a ``lax.scan`` carry — samplers can accumulate stationary moments for
millions of steps without materializing a trajectory.  All arithmetic is
f32 regardless of the sample dtype (bf16 chains accumulate exactly like
their f32 reference).

Chain-axis convention: leaves may carry a leading chain axis of size K
(the repo-wide SPMD layout).  ``welford_*`` functions are elementwise and
agnostic to it; ``chain_summary`` interprets axis 0 as chains and pools.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class MomentState(NamedTuple):
    """Running (count, mean, M2) per element of the template tree."""

    count: jnp.ndarray  # scalar f32 (shared across leaves)
    mean: Any  # pytree, f32
    m2: Any  # pytree, f32: sum of squared deviations


def welford_init(template) -> MomentState:
    zeros = lambda x: jnp.zeros(jnp.shape(x), jnp.float32)
    return MomentState(
        count=jnp.zeros((), jnp.float32),
        mean=jax.tree.map(zeros, template),
        m2=jax.tree.map(zeros, template),
    )


def welford_add(state: MomentState, sample) -> MomentState:
    """One streaming update; O(1) memory, scan-compatible."""
    n = state.count + 1.0

    def upd(mean, m2, x):
        x = x.astype(jnp.float32)
        delta = x - mean
        mean_new = mean + delta / n
        return mean_new, m2 + delta * (x - mean_new)

    flat_mean, treedef = jax.tree.flatten(state.mean)
    pairs = [
        upd(m, m2, x)
        for m, m2, x in zip(
            flat_mean, jax.tree.leaves(state.m2), treedef.flatten_up_to(sample)
        )
    ]
    return MomentState(
        count=n,
        mean=jax.tree.unflatten(treedef, [p[0] for p in pairs]),
        m2=jax.tree.unflatten(treedef, [p[1] for p in pairs]),
    )


def welford_merge(a: MomentState, b: MomentState) -> MomentState:
    """Chan et al. parallel combine — merge shards accumulated
    independently (map-reduce over devices or scan segments)."""
    n = a.count + b.count
    # guard the empty-accumulator edge without host branching
    wb = b.count / jnp.maximum(n, 1.0)

    def mrg(ma, m2a, mb, m2b):
        delta = mb - ma
        mean = ma + delta * wb
        m2 = m2a + m2b + delta * delta * (a.count * wb)
        return mean, m2

    flat_a, treedef = jax.tree.flatten(a.mean)
    pairs = [
        mrg(ma, m2a, mb, m2b)
        for ma, m2a, mb, m2b in zip(
            flat_a, jax.tree.leaves(a.m2), jax.tree.leaves(b.mean), jax.tree.leaves(b.m2)
        )
    ]
    return MomentState(
        count=n,
        mean=jax.tree.unflatten(treedef, [p[0] for p in pairs]),
        m2=jax.tree.unflatten(treedef, [p[1] for p in pairs]),
    )


def welford_mean(state: MomentState):
    return state.mean


def welford_var(state: MomentState, ddof: int = 0):
    """Per-element variance tree.  Returns zeros until count > ddof."""
    denom = jnp.maximum(state.count - ddof, 1.0)
    valid = (state.count > ddof).astype(jnp.float32)
    return jax.tree.map(lambda m2: valid * m2 / denom, state.m2)


def welford_std(state: MomentState, ddof: int = 0):
    return jax.tree.map(jnp.sqrt, welford_var(state, ddof))


class ChainSummary(NamedTuple):
    """Chain-axis pooling of a MomentState whose leaves carry a leading
    chain axis (time-streamed per chain; pooled across chains here)."""

    pooled_mean: Any  # E over (chains, time), per element
    pooled_var: Any  # Var over (chains, time) — law of total variance
    between_chain_var: Any  # Var_k of the per-chain time-means
    within_chain_var: Any  # E_k of the per-chain time-variances


def chain_summary(state: MomentState, ddof: int = 0) -> ChainSummary:
    var = welford_var(state, ddof)

    def pool(mean, v):
        pm = jnp.mean(mean, axis=0)
        between = jnp.var(mean, axis=0)
        within = jnp.mean(v, axis=0)
        return pm, within + between, between, within

    flat_mean, treedef = jax.tree.flatten(state.mean)
    quads = [pool(m, v) for m, v in zip(flat_mean, jax.tree.leaves(var))]
    unf = lambda i: jax.tree.unflatten(treedef, [q[i] for q in quads])
    return ChainSummary(
        pooled_mean=unf(0), pooled_var=unf(1), between_chain_var=unf(2), within_chain_var=unf(3)
    )
