"""Serving-engine latency/throughput bench (``BENCH_serve.json``).

Drives the continuous-batching posterior-predictive engine
(``repro.serve.engine``) with open-loop synthetic request traces on the
smoke-sized qwen3 config and records, per (slots, K, offered-load)
configuration: p50/p99 request latency, p50/p99 first-token latency, and
aggregate tokens/s — the serving tier's perf trajectory across PRs.  One
configuration additionally runs with live snapshot refresh enabled to price
the refresh cost in-band.

CSV rows keep the historical ``name,us_per_call,derived`` shape:
us_per_call = mean decode-step wall time, derived = tokens/s.
"""
from __future__ import annotations

import jax

from repro import configs
from repro.models import get_model, init_params
from repro.launch.serve import _live_refresher
from repro.serve.engine import ServeEngine, SnapshotRegistry, synthetic_trace

from common import QUICK, emit, record

ARCH = "qwen3-0.6b"
# (slots, K, mean_interarrival decode-steps): two slot widths x two ensemble
# sizes, light and heavy offered load on the wider one
GRID_QUICK = [
    (2, 1, 2.0),
    (4, 2, 2.0),
    (4, 2, 0.5),
]
GRID_FULL = GRID_QUICK + [
    (8, 4, 2.0),
    (8, 4, 0.5),
]


def _members(cfg, model, k: int, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    return jax.vmap(lambda kk: init_params(model.param_specs(cfg), kk))(keys)


PROMPT_LENS = (8, 16)


def _one_config(cfg, model, slots, k, interarrival, *, num_requests, max_new, refresh=False):
    registry = SnapshotRegistry(_members(cfg, model, k))
    refresher = None
    if refresh:
        refresher = _live_refresher(model.param_specs(cfg), jax.random.PRNGKey(7), registry)
    engine = ServeEngine(
        cfg, model, registry,
        num_slots=slots, max_seq=max(PROMPT_LENS) + max_new,
        refresher=refresher, refresh_every=8 if refresh else 0,
    )
    trace = synthetic_trace(
        num_requests,
        vocab_size=cfg.vocab_size,
        prompt_lens=PROMPT_LENS,
        max_new=max_new,
        mean_interarrival=interarrival,
        seed=1,
    )
    report = engine.run(trace)
    assert report.trace_counts.get("decode") == 1, report.trace_counts
    pct = report.latency_percentiles()
    return report, pct


def run():
    cfg = configs.get_config(ARCH, smoke=True)
    model = get_model(cfg)
    grid = GRID_QUICK if QUICK else GRID_FULL
    num_requests = 8 if QUICK else 32
    max_new = 8 if QUICK else 24
    configs_out = []
    for slots, k, inter in grid:
        report, pct = _one_config(
            cfg, model, slots, k, inter, num_requests=num_requests, max_new=max_new
        )
        name = f"serve_s{slots}_k{k}_ia{inter:g}"
        step_us = 1e6 * report.wall_s / max(report.decode_steps, 1)
        emit(name, step_us, f"{report.tokens_per_s:.1f}tok/s")
        configs_out.append(
            {
                "slots": slots,
                "ensemble": k,
                "mean_interarrival": inter,
                "requests": len(report.results),
                "total_tokens": report.total_tokens,
                "decode_steps": report.decode_steps,
                "wall_s": round(report.wall_s, 4),
                "tokens_per_s": round(report.tokens_per_s, 2),
                "decode_traces": report.trace_counts.get("decode"),
                **{kk: round(v, 6) for kk, v in pct.items()},
            }
        )
    # price the live-refresh path on the middle configuration
    slots, k, inter = grid[1]
    report, pct = _one_config(
        cfg, model, slots, k, inter, num_requests=num_requests, max_new=max_new, refresh=True
    )
    emit(
        f"serve_s{slots}_k{k}_refresh",
        1e6 * report.wall_s / max(report.decode_steps, 1),
        f"{report.tokens_per_s:.1f}tok/s",
    )
    configs_out.append(
        {
            "slots": slots,
            "ensemble": k,
            "mean_interarrival": inter,
            "refresh_every": 8,
            "snapshots_promoted": report.registry["promoted"],
            "snapshots_rejected": report.registry["rejected"],
            "refresh_wall_s": report.refresher["refresh_wall_s"],
            "tokens_per_s": round(report.tokens_per_s, 2),
            "wall_s": round(report.wall_s, 4),
            **{kk: round(v, 6) for kk, v in pct.items()},
        }
    )
    record("serve", {"arch": ARCH, "configs": configs_out})
    return {"num_configs": len(configs_out)}
