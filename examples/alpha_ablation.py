"""Ablation: elastic-coupling strength alpha (EXPERIMENTS.md §Findings F2).

Sweeps alpha on the 2-D Gaussian target and reports per-chain marginal
variance (coupling shrinkage) and cross-chain spread (coherence) —
quantifying the exploration/agreement trade-off the paper's Fig. 1 shows
qualitatively.

The ENTIRE alpha ladder runs as one vmapped executor program: alpha is a
traced hyperparameter, so ``ChainExecutor(sampler_factory=...)`` builds
the sampler inside the compiled program and the grid shares a single
compilation (DESIGN.md §3).

    PYTHONPATH=src python examples/alpha_ablation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.run import ChainExecutor

MU = jnp.array([2.0, -1.0])
K, STEPS, BURN = 4, 8000, 2000
ALPHAS = (0.0, 0.01, 0.1, 0.5, 1.0, 2.0, 5.0)


def factory(h):
    return core.ec_sghmc(step_size=5e-2, alpha=h["alpha"], sync_every=4,
                         noise_convention="eq4", center_noise_in_p=False)


def main():
    n = len(ALPHAS)
    hyper = {"alpha": jnp.array(ALPHAS)}
    p0 = jnp.zeros((n, K, 2))
    st0 = jax.vmap(lambda h, p: factory(h).init(p))(hyper, p0)
    keys = jnp.stack([jax.random.split(jax.random.PRNGKey(0), STEPS)] * n)
    ex = ChainExecutor(sampler_factory=factory, grad_fn=lambda p, _b: p - MU,
                       trace_fn=lambda p: p, chunk_steps=4000, key_mode="keys")
    res = ex.run(p0, st0, num_steps=STEPS, keys=keys, hyper=hyper)
    traj = np.asarray(res.trace)[:, BURN:]  # (n, T, K, 2)

    print(f"{'alpha':>8} {'marginal var (→1.0)':>22} {'cross-chain spread':>20}")
    for i, alpha in enumerate(ALPHAS):
        t = traj[i]
        marg_var = float(t.reshape(-1, 2).var(0).mean())  # posterior target: 1.0
        spread = float(t.var(axis=1).mean())  # cross-chain coherence
        print(f"{alpha:8.2f} {marg_var:22.3f} {spread:20.4f}")
    print(f"\n(one compiled program for all {n} alphas — "
          f"{res.steps_per_s * n:.0f} total steps/s)")
    print("\nF2: coupling buys coherence (spread ↓) at the cost of marginal"
          "\nvariance shrinkage (var < 1) — choose alpha per use-case.")


if __name__ == "__main__":
    main()
