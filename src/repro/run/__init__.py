"""Device-resident run executor (scan-fused sampling drivers)."""
from .executor import ChainExecutor, RunResult, rollout

__all__ = ["ChainExecutor", "RunResult", "rollout"]
