"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304 — 64 experts top-8, qk-norm. [arXiv:2409.02060]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    vocab_size=50304,
    d_model=2048,
    num_layers=16,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    pattern=(LayerKind("attn", moe=True),),
    act="silu",
    qk_norm=True,
    moe_num_experts=64,
    moe_top_k=8,
    moe_d_ff=1024,
    rope_theta=10_000.0,
    tie_embeddings=False,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=3,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    moe_num_experts=8,
    moe_top_k=2,
    moe_d_ff=32,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
