"""Posterior-predictive serving engine (DESIGN.md §5, §8).

Continuous batching over a fixed slot axis (one compiled decode program;
admissions/completions are data), a recycled per-slot cache pool with
int8-parked idle caches — dense stripes or a block-paged pool with
refcounted prefix sharing (``paged=True``) — Bayesian model averaging over
K ensemble members (optionally one fused mixture+selection kernel), and
live snapshot refresh from a background coupled-sampler run gated by
ensemble-spread diagnostics — synchronous (``ChainRefresher``) or fully
overlapped with decode (``RefreshScheduler``, DESIGN.md §9).
"""
from .bma import BMA_MODES, fused_mixture_select, mixture_logprobs, reference_bma_decode
from .cache_pool import BlockAllocator, CachePool, PagedCachePool, PagedParked, ParkedCache
from .engine import ServeEngine, ServeReport
from .refresh import RefreshScheduler
from .registry import ChainRefresher, SnapshotRegistry
from .scheduler import FCFSQueue, Request, RequestResult, synthetic_trace

__all__ = [
    "BMA_MODES",
    "BlockAllocator",
    "CachePool",
    "ChainRefresher",
    "FCFSQueue",
    "PagedCachePool",
    "PagedParked",
    "ParkedCache",
    "RefreshScheduler",
    "Request",
    "RequestResult",
    "ServeEngine",
    "ServeReport",
    "SnapshotRegistry",
    "fused_mixture_select",
    "mixture_logprobs",
    "reference_bma_decode",
    "synthetic_trace",
]
