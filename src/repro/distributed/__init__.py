from . import sharding
from .sharding import build_spec, tree_shardings, tree_specs
