"""Scale-adapted SGHMC (Springenberg et al., 2016 — BOHAMIANN; the same
authors' practical variant): diagonal preconditioning from an online
gradient-variance estimate, adapted during burn-in then frozen so the
stationary distribution stays valid.

    M^-1_i ∝ 1 / sqrt(V̂_i),   V̂ = EMA[g²]

Composes with elastic coupling: ``scale_adapted_ec_sghmc`` preconditions
each chain's kinetic term while keeping the Eq. 6 coupling structure.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .preconditioner import PrecondState, rmsprop_preconditioner
from .schedules import as_schedule
from .sghmc import _noise_scale
from .tree_util import tree_random_normal
from .types import Sampler


class ScaleAdaptedState(NamedTuple):
    momentum: any
    precond: PrecondState
    step: jnp.ndarray


def scale_adapted_sghmc(
    step_size,
    friction: float = 1.0,
    temperature: float = 1.0,
    burnin: int = 1000,
    decay: float = 0.99,
    noise_convention: str = "eq4",
    state_dtype=jnp.float32,
) -> Sampler:
    schedule = as_schedule(step_size)
    p_init, p_update = rmsprop_preconditioner(decay=decay, burnin=burnin)

    def init(params):
        return ScaleAdaptedState(
            momentum=jax.tree.map(lambda p: jnp.zeros_like(p, state_dtype), params),
            precond=p_init(params),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params=None, rng=None):
        del params
        eps = schedule(state.step)
        minv, new_precond = p_update(state.precond, grads)
        updates = jax.tree.map(
            lambda p, m: eps * m * p.astype(jnp.float32), state.momentum, minv
        )
        sigma = temperature**0.5 * _noise_scale(eps, friction, 0.0, noise_convention)
        noise = tree_random_normal(rng, state.momentum, jnp.float32)

        def mom(p, g, m, n):
            p32 = p.astype(jnp.float32)
            out = (
                p32
                - eps * g.astype(jnp.float32)
                - eps * friction * m * p32
                + sigma * jnp.sqrt(m) * n  # noise scaled to the preconditioner
            )
            return out.astype(state_dtype)

        new_mom = jax.tree.map(mom, state.momentum, grads, minv, noise)
        return updates, ScaleAdaptedState(new_mom, new_precond, state.step + 1)

    return Sampler(init, update)
