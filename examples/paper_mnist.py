"""The paper's MNIST experiment in miniature (Fig. 2 left): compare
SGHMC / Async-SGHMC / EC-SGHMC on the 2x800 MLP posterior and print the
NLL curves.  Full-size with REPRO_BENCH_QUICK=0.

    PYTHONPATH=src:benchmarks python examples/paper_mnist.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))


def main():
    import fig2_mnist_mlp

    results = fig2_mnist_mlp.run()
    print("\nfinal posterior-predictive NLL:")
    for name, nll in sorted(results.items(), key=lambda kv: kv[1]):
        print(f"  {name:10s} {nll:.4f}")


if __name__ == "__main__":
    main()
