"""Pallas TPU kernels for the perf-critical layers (validated in
interpret mode on CPU; compiled on real TPU):

* fused_ecsghmc — one-pass Eq. 6 sampler update (memory-bound hot spot)
* flash_attention — blocked attention w/ sliding-window block skipping
* paged_attention — single-token decode against a block-paged KV pool
* bma_select — fused BMA mixture + temperature/top-k token selection
* rglru — chunked linear-recurrence scan
"""
from .ops import (
    flash_attention,
    fused_bma_select,
    fused_ec_update,
    fused_ec_update_tree,
    paged_attention,
    rglru_scan,
)
from . import ref
