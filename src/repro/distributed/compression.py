"""Gradient/center-exchange compression for the EC sync collective.

int8 with per-block scales (block = trailing 256 elements).  Soundness
argument specific to this paper: the quantization error of the exchanged
center/mean-theta is mathematically absorbed into the center-noise
covariance C of Eq. 6 — EC-SGHMC is *designed* to tolerate a noisy center,
so compressing its one collective is free robustness the naive approach
does not enjoy (Async-SGHMC's stale gradients enter the dynamics directly).

Two operating modes:

* ``int8_codec().encode/decode`` — the structured round-trip (q, scale)
  used by single-process runs (quantize the already-reduced mean: models
  the wire noise without moving fewer bytes) and by the cache pool's idle
  parking.
* ``encode_packed``/``decode_packed``/``compressed_tree_mean`` — the WIRE
  format for real meshes: the int8 payload and the f32 scales (bitcast to
  int8) ride ONE flat int8 buffer, so the s-periodic exchange under
  ``shard_map`` is a single ``all_gather`` of int8 — the program's only
  collective, at ~4x fewer wire bytes than the raw f32 all-reduce
  (``sync_wire_bytes`` quantifies both).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Int8Codec(NamedTuple):
    encode: callable
    decode: callable
    ratio: float  # wire-bytes ratio vs f32


def int8_codec() -> Int8Codec:
    def encode(x):
        shape = x.shape
        flat = x.astype(jnp.float32).reshape(-1)
        pad = (-flat.size) % BLOCK
        flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
        scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
        q = jnp.round(flat / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
        return {"q": q, "scale": scale, "shape": shape, "n": x.size}

    def decode(enc):
        flat = enc["q"].astype(jnp.float32) * enc["scale"]
        return flat.reshape(-1)[: enc["n"]].reshape(enc["shape"])

    return Int8Codec(encode, decode, ratio=(1 + 4 / BLOCK) / 4)


# ---------------------------------------------------------------------------
# Packed wire format: (q int8 payload | f32 scales bitcast to int8) in one
# flat int8 buffer, so a pytree's exchange is ONE collective operand.
# ---------------------------------------------------------------------------


def _num_blocks(n: int) -> int:
    return max(1, math.ceil(n / BLOCK))


def packed_nbytes(n: int) -> int:
    """Wire bytes of one packed encoding of an ``n``-element f32 array."""
    return _num_blocks(n) * (BLOCK + 4)


def encode_packed(x) -> jnp.ndarray:
    """Encode to a flat int8 wire buffer of ``packed_nbytes(x.size)``."""
    enc = int8_codec().encode(x)
    scale_bytes = jax.lax.bitcast_convert_type(
        enc["scale"].astype(jnp.float32), jnp.int8
    )  # (B, 1, 4)
    return jnp.concatenate([enc["q"].reshape(-1), scale_bytes.reshape(-1)])


def decode_packed(packed, shape, n: int) -> jnp.ndarray:
    """Inverse of :func:`encode_packed` (``shape``/``n`` are static — in a
    traced program they come from the pytree structure, not the wire)."""
    b = _num_blocks(n)
    q = packed[: b * BLOCK].reshape(b, BLOCK)
    scale = jax.lax.bitcast_convert_type(
        packed[b * BLOCK :].reshape(b, 1, 4), jnp.float32
    ).reshape(b, 1)
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


def compressed_tree_mean(tree, axis_name: str):
    """Wire-compressed chain mean for the s-periodic exchange inside
    ``shard_map`` (the ``tree_mean_axis0(tree, axis_name)`` replacement):
    each shard means its LOCAL chains (leading axis), packs every leaf's
    int8 encoding into ONE flat int8 buffer, all-gathers that buffer over
    ``axis_name`` — the program's single collective — then decodes every
    shard's contribution and averages.  Equal per-shard chain counts are
    assumed (mesh construction enforces ``K % axis_size == 0``), so the
    mean of shard means IS the global chain mean, up to the int8
    quantization noise that Eq. 6's center covariance C absorbs."""
    leaves, treedef = jax.tree.flatten(tree)
    local = [jnp.mean(x.astype(jnp.float32), axis=0) for x in leaves]
    packed = jnp.concatenate([encode_packed(m) for m in local])
    gathered = jax.lax.all_gather(packed, axis_name)  # (n_shards, L) int8

    def unpack(row):
        out, off = [], 0
        for m in local:
            nbytes = packed_nbytes(m.size)
            out.append(decode_packed(row[off : off + nbytes], m.shape, m.size))
            off += nbytes
        return out

    means = jax.vmap(unpack)(gathered)  # per-leaf (n_shards, ...) stacks
    return jax.tree.unflatten(treedef, [m.mean(axis=0) for m in means])


def sync_wire_bytes(num_params: int, *, compressed: bool, num_shards: int = 1) -> int:
    """Per-device payload bytes moved by ONE s-periodic center exchange.

    raw: the f32 all-reduce's operand (4 bytes/param); compressed: the
    packed int8 all-gather's operand (``packed_nbytes``).  Both count the
    collective's input payload — the apples-to-apples number
    ``benchmarks/shard_sweep.py`` records (actual link traffic scales it
    by the collective algorithm's (num_shards-1)/num_shards-style factor,
    identically for both)."""
    del num_shards
    return packed_nbytes(num_params) if compressed else 4 * num_params
