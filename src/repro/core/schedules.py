"""Step-size schedules. A schedule is ``step -> epsilon`` (jnp scalar)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def constant(value: float):
    def fn(step):
        return jnp.asarray(value, jnp.float32)

    return fn


def polynomial_decay(a: float, b: float, gamma: float):
    """epsilon_t = a * (b + t)^(-gamma) — the classic SG-MCMC decay
    (Welling & Teh 2011 conditions: gamma in (0.5, 1])."""

    def fn(step):
        return jnp.asarray(a, jnp.float32) * (b + step.astype(jnp.float32)) ** (-gamma)

    return fn


def cosine(peak: float, total_steps: int, floor: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))

    return fn


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def fn(step):
        t = step.astype(jnp.float32)
        warm = peak * t / max(warmup_steps, 1)
        frac = jnp.clip((t - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(t < warmup_steps, warm, cos)

    return fn


class FeedbackESS:
    """Feedback step-size controller driven by measured sampling efficiency
    (pysgmcmc-style stateful schedule: callable like any schedule, plus an
    ``update()`` hook the host calls between compiled chunks).

    Control law (multiplicative integral control on the ESS *rate*):

        err   = clip((target − ess_rate) / target, −1, 1)
        ε  ←  clip(ε · exp(gain · err), lo·ε₀, hi·ε₀)

    ESS per step below target ⇒ the chain mixes too slowly ⇒ GROW ε (more
    distance per step); above target ⇒ ε can shrink back toward the
    small-bias regime.  Updates stop for steps ≥ ``freeze_at`` so the chain
    has a genuinely fixed step size during measurement windows — the same
    freeze-then-measure contract as the preconditioner burn-in
    (DESIGN.md §6); only post-freeze samples enter stationary gates.

    As a *schedule* it returns the CURRENT ε for any step: inside a traced
    program that value is baked at trace time, which is exactly the executor
    contract — ``ChainExecutor`` passes ε through ``hyper`` instead and calls
    ``update()`` at chunk boundaries (``run/executor.py: adapt hook``), so
    the compiled chunk never retraces.
    """

    def __init__(self, init: float, target_ess_rate: float, gain: float = 0.5,
                 bounds: tuple = (0.1, 10.0), freeze_at: int | None = None):
        if not (init > 0.0 and target_ess_rate > 0.0 and gain >= 0.0):
            raise ValueError("init/target must be > 0, gain >= 0")
        self.eps0 = float(init)
        self.value = float(init)
        self.target = float(target_ess_rate)
        self.gain = float(gain)
        self.lo = float(bounds[0]) * self.eps0
        self.hi = float(bounds[1]) * self.eps0
        self.freeze_at = freeze_at
        self.frozen = False

    def __call__(self, step):
        del step  # the current value IS the schedule; host advances it
        return jnp.asarray(self.value, jnp.float32)

    def update(self, ess_rate, step: int | None = None) -> float:
        """Feed one ESS-per-step measurement; returns the (new) ε.  No-op
        once frozen (``step >= freeze_at`` or ``freeze()`` called)."""
        if self.frozen or (
            self.freeze_at is not None and step is not None and step >= self.freeze_at
        ):
            self.frozen = True
            return self.value
        err = min(max((self.target - float(ess_rate)) / self.target, -1.0), 1.0)
        self.value = min(max(self.value * math.exp(self.gain * err), self.lo), self.hi)
        return self.value

    def freeze(self):
        self.frozen = True


def feedback_ess(init: float, target_ess_rate: float, **kw) -> FeedbackESS:
    """Factory mirroring the other schedule constructors."""
    return FeedbackESS(init, target_ess_rate, **kw)


def as_schedule(x):
    if callable(x):
        return x
    # no float() coercion: x may be a traced scalar (vmapped hyperparameter
    # sweeps build samplers inside the program — repro.run.executor)
    return constant(x)
