from .step import make_train_step
