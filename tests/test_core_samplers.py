"""Behaviour tests for the SG-MCMC sampler library (paper Eqs. 4, 6, 9, 10)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from util import gaussian_grad, run_sampler

MU = jnp.array([2.0, -1.0])


class TestSGHMC:
    def test_stationary_gaussian_moments(self):
        """Eq. 4 targets N(mu, I) for U = ||x-mu||^2/2 (V=1, eq4 noise)."""
        s = core.sghmc(step_size=5e-2, friction=1.0)
        traj = run_sampler(s, jnp.zeros(2), gaussian_grad(MU), 8000, collect_from=2000)
        np.testing.assert_allclose(traj.mean(0), np.asarray(MU), atol=0.15)
        np.testing.assert_allclose(traj.var(0), 1.0, atol=0.35)

    def test_temperature_zero_is_deterministic(self):
        s = core.sghmc(step_size=1e-2, temperature=0.0)
        t1 = run_sampler(s, jnp.ones(3), gaussian_grad(jnp.zeros(3)), 100, seed=0)
        t2 = run_sampler(s, jnp.ones(3), gaussian_grad(jnp.zeros(3)), 100, seed=99)
        np.testing.assert_array_equal(t1, t2)

    def test_momentum_descends_potential(self):
        """With temperature 0, SGHMC is momentum gradient descent on U."""
        s = core.sghmc(step_size=1e-2, temperature=0.0)
        traj = run_sampler(s, jnp.full(4, 5.0), gaussian_grad(jnp.zeros(4)), 1500)
        assert np.linalg.norm(traj[-1]) < 0.5

    def test_pytree_params(self):
        params = {"w": jnp.ones((3, 2)), "b": {"x": jnp.zeros(5)}}
        s = core.sghmc(step_size=1e-3)
        st = s.init(params)
        grads = jax.tree.map(jnp.ones_like, params)
        upd, st2 = s.update(grads, st, params=params, rng=jax.random.PRNGKey(0))
        assert jax.tree.structure(upd) == jax.tree.structure(params)
        assert int(st2.step) == 1


class TestECSGHMC:
    def test_alpha0_temp0_equals_independent_sghmc(self):
        """alpha=0 decouples Eq. 5 into K independent SGHMC Hamiltonians."""
        K = 4
        p0 = jax.random.normal(jax.random.PRNGKey(0), (K, 3))
        ec = core.ec_sghmc(step_size=2e-2, alpha=0.0, temperature=0.0)
        sg = core.sghmc(step_size=2e-2, temperature=0.0)
        t_ec = run_sampler(ec, p0, gaussian_grad(jnp.zeros(3)), 200)
        t_sg = run_sampler(sg, p0, gaussian_grad(jnp.zeros(3)), 200)
        np.testing.assert_array_equal(t_ec, t_sg)

    def test_stationary_mean(self):
        ec = core.ec_sghmc(step_size=5e-2, alpha=1.0, sync_every=4)
        p0 = jax.random.normal(jax.random.PRNGKey(1), (4, 2)) * 3
        traj = run_sampler(ec, p0, gaussian_grad(MU), 8000, collect_from=2000)
        np.testing.assert_allclose(traj.reshape(-1, 2).mean(0), np.asarray(MU), atol=0.2)

    def test_eq4_convention_variance(self):
        """With eq4 noise, C excluded from p-noise and weak coupling, each
        chain's marginal variance approaches the posterior's (=1)."""
        ec = core.ec_sghmc(
            step_size=5e-2, alpha=0.05, sync_every=1,
            noise_convention="eq4", center_noise_in_p=False,
        )
        p0 = jnp.zeros((4, 2)) + MU
        traj = run_sampler(ec, p0, gaussian_grad(MU), 10000, collect_from=2000)
        v = traj.reshape(-1, 2).var(0)
        np.testing.assert_allclose(v, 1.0, atol=0.4)

    def test_coupling_contracts_chains(self):
        """The elastic force pulls chains toward the center: chain spread
        with alpha>0 must be far below the uncoupled spread."""
        p0 = jax.random.normal(jax.random.PRNGKey(2), (6, 2)) * 5
        spread = {}
        for alpha in (0.0, 2.0):
            ec = core.ec_sghmc(step_size=5e-2, alpha=alpha, temperature=0.0)
            traj = run_sampler(ec, p0, gaussian_grad(MU, prec=0.0), 300)
            spread[alpha] = float(np.mean(np.var(traj[-1], axis=0)))
        assert spread[2.0] < 0.1 * spread[0.0]

    def test_sync_period_gates_center_exchange(self):
        """c̃ must change only at steps ≡ 0 (mod s)."""
        s = 4
        ec = core.ec_sghmc(step_size=1e-2, alpha=1.0, sync_every=s)
        params = jax.random.normal(jax.random.PRNGKey(3), (3, 2))
        st = ec.init(params)
        grad = gaussian_grad(jnp.zeros(2))
        stales = [np.asarray(st.center_stale)]
        for i in range(9):
            upd, st = ec.update(grad(params), st, params=params, rng=jax.random.PRNGKey(i))
            params = core.apply_updates(params, upd)
            stales.append(np.asarray(st.center_stale))
        for t in range(1, 10):
            changed = not np.array_equal(stales[t], stales[t - 1])
            assert changed == (t % s == 0), f"step {t}: stale-center changed={changed}"

    def test_resample_chain_from_center(self):
        ec = core.ec_sghmc(step_size=1e-2, alpha=2.0)
        params = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
        st = ec.init(params)
        new_params, new_state = core.resample_chain_from_center(
            st, alpha=2.0, rng=jax.random.PRNGKey(1), num_chains=6
        )
        assert new_params.shape == (6, 8)
        assert new_state.momentum.shape == (6, 8)
        # chains scatter around the center with variance K/alpha
        centered = np.asarray(new_params) - np.asarray(st.center)[None]
        assert abs(centered.var() - 6 / 2.0) < 1.5


class TestAsyncSGHMC:
    def test_s1_k1_equals_sghmc(self):
        """One worker syncing every step == plain SGHMC, bit-exact."""
        a = core.async_sghmc(step_size=2e-2, num_workers=1, sync_every=1, temperature=0.0)
        s = core.sghmc(step_size=2e-2, temperature=0.0)
        p0 = jnp.array([3.0, -2.0])

        def grad_k(t):  # async targets have leading worker axis
            return jax.vmap(gaussian_grad(jnp.zeros(2)))(t)

        t_a = run_sampler(a, p0, grad_k, 100)
        t_s = run_sampler(s, p0, gaussian_grad(jnp.zeros(2)), 100)
        np.testing.assert_allclose(t_a, t_s, atol=1e-6)

    def test_staleness_of_snapshots(self):
        """Snapshots refresh only on each worker's phase step."""
        K, s = 4, 2
        a = core.async_sghmc(step_size=1e-2, num_workers=K, sync_every=s)
        params = jnp.ones(3)
        st = a.init(params)
        for t in range(6):
            prev = np.asarray(st.snapshots)
            g = jax.vmap(gaussian_grad(jnp.zeros(3)))(a.grad_targets(st, params))
            upd, st = a.update(g, st, params=params, rng=jax.random.PRNGKey(t))
            params = core.apply_updates(params, upd)
            cur = np.asarray(st.snapshots)
            for k in range(K):
                if t % s == k % s:  # arrived: snapshot == post-update params
                    np.testing.assert_allclose(cur[k], np.asarray(params), atol=1e-7)
                else:  # idle: snapshot untouched
                    np.testing.assert_array_equal(cur[k], prev[k])

    def test_stationary_mean(self):
        a = core.async_sghmc(step_size=5e-2, num_workers=4, sync_every=2)

        def grad_k(t):
            return jax.vmap(gaussian_grad(MU))(t)

        traj = run_sampler(a, jnp.zeros(2), grad_k, 8000, collect_from=2000)
        np.testing.assert_allclose(traj.mean(0), np.asarray(MU), atol=0.25)


class TestSGLD:
    def test_stationary_gaussian_moments(self):
        """Tolerance is ESS-aware (the seed's fixed atol=0.15 was a ~2σ band
        and failed on seeded bad luck; tests/test_stationary.py holds the
        exact-oracle version of this check)."""
        from repro import diagnostics as diag

        s = core.sgld(step_size=1e-2)
        traj = run_sampler(s, jnp.zeros(2), gaussian_grad(MU), 20000, collect_from=4000)
        ess = min(float(diag.effective_sample_size(traj[:, d])) for d in range(2))
        mean_tol = 3.0 * np.sqrt(traj.var() / ess)
        np.testing.assert_allclose(traj.mean(0), np.asarray(MU), atol=max(mean_tol, 0.05))
        np.testing.assert_allclose(traj.var(0), 1.0, atol=0.3)


class TestECSGLD:
    def test_stationary_mean(self):
        ec = core.ec_sgld(step_size=1e-2, alpha=1.0, sync_every=2)
        p0 = jax.random.normal(jax.random.PRNGKey(1), (4, 2))
        traj = run_sampler(ec, p0, gaussian_grad(MU), 12000, collect_from=4000)
        np.testing.assert_allclose(traj.reshape(-1, 2).mean(0), np.asarray(MU), atol=0.2)


class TestSchedules:
    def test_polynomial_decay_conditions(self):
        sch = core.polynomial_decay(a=1.0, b=10.0, gamma=0.55)
        vals = [float(sch(jnp.int32(t))) for t in (0, 10, 100, 1000)]
        assert all(v > 0 for v in vals)
        assert vals == sorted(vals, reverse=True)

    def test_warmup_cosine(self):
        sch = core.warmup_cosine(peak=1.0, warmup_steps=10, total_steps=100)
        assert float(sch(jnp.int32(0))) == 0.0
        assert abs(float(sch(jnp.int32(10))) - 1.0) < 1e-6
        assert float(sch(jnp.int32(100))) < 1e-6
