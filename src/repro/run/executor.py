"""Device-resident chain executor: whole sampling runs as chunked
``lax.scan`` programs.

Every driver in this repo used to advance samplers one jitted step per
Python iteration, so measured "throughput" was host-dispatch latency, not
sampler math — fatal at the paper's Fig. 1/2 scale where a sampler step is
microseconds.  ``ChainExecutor`` compiles the entire step loop onto the
device:

* the inner loop is ``lax.scan`` over ``Sampler.{grad_targets, update}``
  (or a raw ``step_fn``), with the carry DONATED between chunks — params,
  sampler state and accumulators never round-trip to the host;
* streaming diagnostics ride the carry: Welford moments
  (``repro.diagnostics.moments``) and batch-means ESS
  (``repro.diagnostics.streaming``) accumulate with zero host syncs;
* traces are collected THINNED inside the program (nested scan), so a
  million-step run can keep every 100th sample without materializing the
  rest;
* the host regains control only at CHUNK boundaries — that is where
  ``train/loop.py`` checkpoints, logs, and honors preemption, preserving
  its auto-resume semantics exactly (DESIGN.md §3 states the contract);
* a SWEEP axis (``sweep=True`` / ``hyper=``) vmaps whole runs over stacked
  seeds or sampler hyperparameters — a benchmark grid becomes one compiled
  program;
* ``run_sharded`` routes the chain axis through ``shard_map`` over a mesh
  (``repro.distributed.sharding.chain_specs``): the s-periodic center sync
  stays the program's ONLY cross-chain collective, which
  ``tests/test_executor.py`` verifies on the lowered HLO.

Key modes (``key_mode``) reproduce the RNG streams of the drivers this
replaces, bit-for-bit:

* ``"keys"``  — caller pre-splits one key per step (the stationary battery
  and the toy benchmarks);
* ``"fold"``  — per-step key is ``fold_in(base_key, global_step)`` (the
  training loop; resume-safe since the step index is absolute);
* ``"carry"`` — a key rides the carry and is ``split`` once per step (the
  legacy posterior driver sequence).
"""
from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply_updates, tree_broadcast_axis0
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.diagnostics import (
    BatchMeansState,
    MomentState,
    batch_ess_add,
    batch_ess_estimate,
    batch_ess_init,
    welford_add,
    welford_init,
)


def _select_tree(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _is_typed_key(key) -> bool:
    return jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key)


class ChunkSnapshot(NamedTuple):
    """One chunk-boundary observation from ``ChainExecutor.stream``:
    ``step`` is the absolute step index at the boundary; ``params``/``state``
    are defensive copies by default (the live carry is donated into the next
    chunk's program, so holding the raw reference across iterations would be
    a use-after-donate).  ``probe`` is a copied device scalar (the carry's
    step counter) produced BY the chunk computation: ``probe.is_ready()``
    answers "has this chunk retired?" without a host sync — the
    backpressure signal the overlapped refresh scheduler paces dispatch
    with (DESIGN.md §9)."""

    step: int
    params: Any
    state: Any
    outs: Any
    probe: Any = None


class RunResult(NamedTuple):
    """Everything a driver can ask the executor for.  ``trace``/``stats``
    are time-major host arrays (sweep axis first when swept);
    ``moments``/``ess`` are the in-carry accumulators in their final state
    — feed them to ``diagnostics.welford_mean/var`` / ``batch_ess_estimate``."""

    params: Any
    state: Any
    trace: Any  # (T', ...) pytree or None
    stats: Any  # (T', ...) dict of scalars or None
    metrics: Any  # metrics dict of the final executed step ({} if none)
    moments: Optional[MomentState]
    ess: Optional[BatchMeansState]
    steps: int
    wall_s: float

    @property
    def steps_per_s(self) -> float:
        return self.steps / max(self.wall_s, 1e-12)


class ChainExecutor:
    """Compiles sampling runs as chunked, donated ``lax.scan`` programs.

    Exactly one of ``step_fn`` / ``sampler`` / ``sampler_factory`` drives
    the dynamics:

    * ``step_fn(params, state, batch, rng) -> (params, state, metrics)`` —
      arbitrary update (the training loop's model step);
    * ``sampler`` + ``grad_fn(targets, batch) -> grads | (grads, metrics)``
      — the Sampler protocol: gradients are evaluated at
      ``sampler.grad_targets(state, params)`` (stale snapshots for
      approach-I samplers) and fed to ``sampler.update``;
    * ``sampler_factory(hyper) -> Sampler`` — as above, but constructed
      inside the traced program from a (possibly vmapped) hyperparameter
      pytree: an (alpha, step_size, ...) grid runs as ONE compiled program.
      Structural hyperparameters (``sync_every``, chain count, dtypes)
      change the program and must stay Python-static — DESIGN.md §3.

    ``chunk_steps`` bounds how long the device runs between host visits;
    checkpointing/logging/preemption can only happen there.  When tracing
    (``trace_fn``), ``chunk_steps`` and ``num_steps`` must be multiples of
    ``thin``.  Without a ``trace_fn`` the chunk is a single flat scan and
    ``stats``/``metrics`` are reported once per chunk (the final step's).
    """

    def __init__(
        self,
        *,
        step_fn: Callable | None = None,
        sampler=None,
        sampler_factory: Callable | None = None,
        grad_fn: Callable | None = None,
        batch_fn: Callable | None = None,  # host: step -> batch (stacked per chunk)
        device_batch_fn: Callable | None = None,  # traced: step -> batch
        trace_fn: Callable | None = None,  # params -> trace point
        thin: int = 1,
        moments: bool = False,
        moments_of: Callable | None = None,  # params -> tree to accumulate
        moments_from: int = 0,
        ess_probe_fn: Callable | None = None,  # params -> small probe array
        ess_batch_len: int = 64,
        collect_stats: bool = False,
        chunk_steps: int = 256,
        donate: bool = True,
        key_mode: str = "keys",
    ):
        if sum(x is not None for x in (step_fn, sampler, sampler_factory)) != 1:
            raise ValueError("exactly one of step_fn / sampler / sampler_factory")
        if (sampler is not None or sampler_factory is not None) and grad_fn is None:
            raise ValueError("sampler mode needs grad_fn")
        if key_mode not in ("keys", "fold", "carry"):
            raise ValueError(f"unknown key_mode {key_mode!r}")
        if batch_fn is not None and device_batch_fn is not None:
            raise ValueError("pass either batch_fn (host) or device_batch_fn (traced)")
        if thin < 1 or chunk_steps < 1:
            raise ValueError("thin and chunk_steps must be >= 1")
        if trace_fn is not None and chunk_steps % thin != 0:
            raise ValueError("chunk_steps must be a multiple of thin when tracing")
        self.step_fn = step_fn
        self.sampler = sampler
        self.sampler_factory = sampler_factory
        self.grad_fn = grad_fn
        self.batch_fn = batch_fn
        self.device_batch_fn = device_batch_fn
        self.trace_fn = trace_fn
        self.thin = int(thin)
        self.moments = moments
        self.moments_of = moments_of or (lambda p: p)
        self.moments_from = int(moments_from)
        self.ess_probe_fn = ess_probe_fn
        self.ess_batch_len = int(ess_batch_len)
        self.collect_stats = collect_stats
        self.chunk_steps = int(chunk_steps)
        self.donate = donate
        self.key_mode = key_mode
        self._compiled: dict = {}

    # -- step construction --------------------------------------------------

    def _resolve(self, hyper):
        """(step, stats_fn) for a given (possibly traced) hyper pytree."""
        if self.step_fn is not None:
            return self.step_fn, None
        sampler = self.sampler if self.sampler is not None else self.sampler_factory(hyper)
        grad_fn = self.grad_fn

        def step(params, state, batch, rng):
            targets = (
                sampler.grad_targets(state, params) if sampler.grad_targets else params
            )
            out = grad_fn(targets, batch)
            grads, metrics = out if isinstance(out, tuple) else (out, {})
            updates, new_state = sampler.update(grads, state, params, rng)
            return apply_updates(params, updates), new_state, metrics

        return step, sampler.stats

    # -- chunk program ------------------------------------------------------

    def _build_chunk(self, n: int):
        """chunk(hyper, base_key, carry, xs) -> (carry, outs), advancing
        ``n`` steps as (n // thin) outer x thin inner scan iterations."""
        thin = self.thin if self.trace_fn is not None else n
        n_outer = n // thin

        def chunk(hyper, base_key, carry, xs):
            step, stats_fn = self._resolve(hyper)

            def inner(c, x):
                t = c["t"]
                new_key = c["key"]
                if self.key_mode == "keys":
                    rng = x["key"]
                elif self.key_mode == "fold":
                    rng = jax.random.fold_in(base_key, t)
                else:  # carry: key, sub = split(key) — legacy driver sequence
                    ks = jax.random.split(c["key"])
                    new_key, rng = ks[0], ks[1]
                batch = (
                    x["batch"]
                    if self.batch_fn is not None
                    else (self.device_batch_fn(t) if self.device_batch_fn else None)
                )
                params, state, metrics = step(c["params"], c["state"], batch, rng)
                c = dict(c, params=params, state=state, t=t + 1, key=new_key)
                live = t >= self.moments_from
                if self.moments:
                    wf2 = welford_add(c["wf"], self.moments_of(params))
                    c["wf"] = _select_tree(live, wf2, c["wf"])
                if self.ess_probe_fn is not None:
                    es2 = batch_ess_add(c["ess"], self.ess_probe_fn(params))
                    c["ess"] = _select_tree(live, es2, c["ess"])
                return c, metrics

            def outer(c, x):
                c, mseq = jax.lax.scan(inner, c, x, length=thin)
                outs = {"metrics": jax.tree.map(lambda a: a[-1], mseq)}
                if self.trace_fn is not None:
                    outs["trace"] = self.trace_fn(c["params"])
                if self.collect_stats and stats_fn is not None:
                    outs["stats"] = stats_fn(c["state"], c["params"])
                return c, outs

            return jax.lax.scan(outer, carry, xs, length=n_outer)

        return chunk, n_outer, thin

    def _compile(self, n: int, sweep: bool, key_axis):
        sig = (n, sweep, key_axis)
        if sig in self._compiled:
            return self._compiled[sig]
        chunk, n_outer, thin = self._build_chunk(n)
        fn = chunk
        if sweep:
            # hyper / carry / xs map over their leading axis; base_key only
            # when the caller stacked per-member keys (key_axis=0)
            fn = jax.vmap(chunk, in_axes=(0, key_axis, 0, 0))
        fn = jax.jit(fn, donate_argnums=(2,) if self.donate else ())
        self._compiled[sig] = (fn, n_outer, thin)
        return fn, n_outer, thin

    # -- host driver --------------------------------------------------------

    @staticmethod
    def _sweep_size(tree) -> int:
        return jax.tree.leaves(tree)[0].shape[0]

    def _init_carry(self, params, state, start_step, key, sweep):
        p1 = jax.tree.map(lambda x: x[0], params) if sweep else params
        carry = {
            "params": params,
            "state": state,
            "t": jnp.asarray(start_step, jnp.int32),
            "key": None,
            "wf": None,
            "ess": None,
        }
        stack = (lambda tr: tree_broadcast_axis0(tr, self._sweep_size(params))) if sweep else (lambda tr: tr)
        if sweep:
            carry["t"] = stack(carry["t"])
        if self.moments:
            carry["wf"] = stack(welford_init(jax.eval_shape(self.moments_of, p1)))
        if self.ess_probe_fn is not None:
            probe = jax.eval_shape(self.ess_probe_fn, p1)
            carry["ess"] = stack(batch_ess_init(probe, self.ess_batch_len))
        if self.key_mode == "carry":
            carry["key"] = key  # caller stacks it in sweep mode
        return carry

    def _chunk_xs(self, t_run: int, t_abs: int, n: int, thin: int, keys, sweep):
        """Per-chunk xs with (n_outer, thin) step axes (after the sweep
        axis, when present)."""
        n_outer = n // thin
        xs = {}
        if self.key_mode == "keys":
            if sweep:
                sl = keys[:, t_run : t_run + n]
                xs["key"] = sl.reshape(sl.shape[:1] + (n_outer, thin) + sl.shape[2:])
            else:
                sl = keys[t_run : t_run + n]
                xs["key"] = sl.reshape((n_outer, thin) + sl.shape[1:])
        if self.batch_fn is not None:
            if sweep:
                raise NotImplementedError("host batch_fn + sweep is unsupported")
            batches = [self.batch_fn(t_abs + i) for i in range(n)]
            stacked = jax.tree.map(lambda *bs: jnp.stack(bs), *batches)
            xs["batch"] = jax.tree.map(
                lambda a: a.reshape((n_outer, thin) + a.shape[1:]), stacked
            )
        return xs

    def run(
        self,
        params,
        state,
        *,
        num_steps: int,
        key=None,
        keys=None,
        start_step: int = 0,
        hyper=None,
        sweep: bool | None = None,
        on_chunk: Callable | None = None,
        adapt_fn: Callable | None = None,
    ) -> RunResult:
        """Advance ``num_steps`` steps from ``(params, state)``.

        ``keys``: (num_steps, ...) per-step RNG keys for ``key_mode="keys"``
        (``(S, num_steps, ...)`` when swept); ``key``: base key for
        ``"fold"``/``"carry"``.  ``start_step``: absolute index of the first
        step (resume; drives ``fold_in``, ``batch_fn`` and schedules
        through the sampler's own step counter).  ``sweep``: vmap the run
        over the leading axis of params/state/keys/hyper (default: implied
        by ``hyper``; pass ``sweep=False`` to use an UNSWEPT hyper pytree —
        the adaptation configuration).  ``on_chunk(step_end, params, state,
        outs)`` runs on the host at every chunk boundary; return False to
        stop early.

        ``adapt_fn(step_end, carry, hyper) -> hyper | None`` is the
        ADAPTATION HOOK: called on the host at every chunk boundary (before
        the next chunk launches); a non-None return replaces ``hyper`` for
        the remaining chunks.  Because hyper values enter the compiled chunk
        as traced scalars, changing their VALUES never retraces — the hook
        must preserve their avals (keep jnp.float32 scalars jnp.float32).
        This is how ``schedules.FeedbackESS`` closes the diagnostics →
        dynamics loop: read the in-carry streaming ESS, call
        ``controller.update()``, and hand the new step size to the next
        chunk (see ``ess_feedback_adapter``).

        The carry is DONATED between chunks: buffers passed in are consumed
        (pass copies if you need them after).
        """
        sweep = (hyper is not None) if sweep is None else bool(sweep)
        if self.sampler_factory is not None and hyper is None:
            raise ValueError("sampler_factory mode needs hyper=")
        if self.key_mode == "keys" and keys is None:
            raise ValueError("key_mode='keys' needs keys=")
        if self.key_mode in ("fold", "carry") and key is None:
            raise ValueError(f"key_mode={self.key_mode!r} needs key=")
        if self.trace_fn is not None and num_steps % self.thin != 0:
            raise ValueError("num_steps must be a multiple of thin when tracing")
        key_axis = None
        if sweep and self.key_mode == "fold":
            stacked = key.ndim >= 1 if _is_typed_key(key) else key.ndim >= 2
            key_axis = 0 if stacked else None

        carry = self._init_carry(params, state, start_step, key, sweep)
        traces, stats, metrics = [], [], {}
        t_run, t_abs = 0, int(start_step)
        t0 = time.perf_counter()
        stopped = False
        chunks = 0
        while t_run < num_steps and not stopped:
            n = min(self.chunk_steps, num_steps - t_run)
            fn, n_outer, thin = self._compile(n, sweep, key_axis)
            xs = self._chunk_xs(t_run, t_abs, n, thin, keys, sweep)
            # the span measures host-side DISPATCH (async enqueue), not
            # device compute — executor.settle below is where compute lands
            with obs_trace.get().span("executor.chunk", cat="executor",
                                      step=t_abs, n=n):
                carry, outs = fn(hyper, key, carry, xs)
            chunks += 1
            t_run += n
            t_abs += n
            if self.trace_fn is not None:
                traces.append(outs["trace"])
            if "stats" in outs:
                stats.append(outs["stats"])
            metrics = jax.tree.map(
                (lambda a: a[:, -1]) if sweep else (lambda a: a[-1]), outs["metrics"]
            )
            if on_chunk is not None:
                if on_chunk(t_abs, carry["params"], carry["state"], outs) is False:
                    stopped = True
            if adapt_fn is not None and t_run < num_steps and not stopped:
                new_hyper = adapt_fn(t_abs, carry, hyper)
                if new_hyper is not None:
                    hyper = new_hyper
        # dispatch is async: settle the final carry (same executable as the
        # chunk outputs) so wall_s measures compute, not enqueue latency
        with obs_trace.get().span("executor.settle", cat="executor", step=t_abs):
            jax.block_until_ready(carry["params"])
        wall = time.perf_counter() - t0
        reg = obs_metrics.default_registry()
        reg.counter("executor.chunks_total").inc(chunks)
        reg.counter("executor.steps_total").inc(t_run)
        reg.histogram("executor.run_wall_s").observe(wall)

        axis = 1 if sweep else 0
        cat = lambda ts: jax.tree.map(lambda *xs_: np.concatenate(xs_, axis=axis), *ts)
        return RunResult(
            params=carry["params"],
            state=carry["state"],
            trace=cat(traces) if traces else None,
            stats=cat(stats) if stats else None,
            metrics=metrics,
            moments=carry["wf"],
            ess=carry["ess"],
            steps=t_run,
            wall_s=wall,
        )

    def stream(
        self,
        params,
        state,
        *,
        num_steps: int,
        key=None,
        keys=None,
        start_step: int = 0,
        copy_snapshots: bool = True,
        snapshot_every: int = 1,
    ):
        """Chunk-boundary snapshot hook: a generator that advances the run
        one chunk at a time and yields a :class:`ChunkSnapshot` at every
        boundary — the host-side surface the serving tier's snapshot
        registry refreshes ensemble members from (`repro.serve.engine`).

        Unlike ``run`` nothing is accumulated across chunks: the caller owns
        each boundary.  With ``copy_snapshots`` (default) the yielded
        params/state are copies and stay valid after the generator advances;
        pass False only if each snapshot is fully consumed before ``next()``
        is called again — the live carry is donated into the next chunk.
        The generator can be abandoned at any boundary (the carry's device
        buffers are garbage-collected with it).

        ``snapshot_every=k`` is the MICRO-CHUNK hook (DESIGN.md §9): every
        boundary still yields (so a caller can pace dispatch one chunk at a
        time against another workload's clock), but params/state are copied
        only on every k-th boundary and on the final one — intermediate
        yields carry ``params=state=None``.  Chunking is invisible to the
        dynamics (§3), so splitting a chunk into k micro-chunks with
        ``key_mode='fold'`` is bit-identical to the unsplit run.  Nothing
        in this generator forces a host sync: every chunk dispatch, copy and
        yield rides JAX's async dispatch."""
        if self.key_mode == "keys" and keys is None:
            raise ValueError("key_mode='keys' needs keys=")
        if self.key_mode in ("fold", "carry") and key is None:
            raise ValueError(f"key_mode={self.key_mode!r} needs key=")
        if self.trace_fn is not None and num_steps % self.thin != 0:
            raise ValueError("num_steps must be a multiple of thin when tracing")
        if self.sampler_factory is not None:
            raise ValueError("stream does not support sampler_factory mode")
        if snapshot_every < 1:
            raise ValueError("snapshot_every must be >= 1")
        copy = (lambda tr: jax.tree.map(lambda x: x.copy(), tr)) if copy_snapshots else (lambda tr: tr)
        carry = self._init_carry(params, state, start_step, key, sweep=False)
        t_run, t_abs, boundary = 0, int(start_step), 0
        while t_run < num_steps:
            n = min(self.chunk_steps, num_steps - t_run)
            fn, n_outer, thin = self._compile(n, False, None)
            xs = self._chunk_xs(t_run, t_abs, n, thin, keys, False)
            with obs_trace.get().span("executor.chunk", cat="executor",
                                      step=t_abs, n=n, stream=True):
                carry, outs = fn(None, key, carry, xs)
            t_run += n
            t_abs += n
            boundary += 1
            # the copy makes the probe safe to hold across the next chunk
            # when that chunk donates (and deletes) the carry; a non-donated
            # stream can hand out the scalar itself — one less dispatch on
            # the caller's (possibly latency-critical) thread
            probe = carry["t"].copy() if self.donate else carry["t"]
            if boundary % snapshot_every == 0 or t_run >= num_steps:
                yield ChunkSnapshot(t_abs, copy(carry["params"]), copy(carry["state"]), outs, probe)
            else:
                yield ChunkSnapshot(t_abs, None, None, outs, probe)

    # -- shard_map chain routing -------------------------------------------

    def _build_sharded(self, n, mesh, chain_axis, carry, num_chains, specs=None):
        """Jitted shard_map chunk: the carry shards on the chain axis via
        the ``chain_specs`` shape contract.  The per-step key is
        SHARD-INVARIANT: the sampler must have been built with
        ``chain_axis=<name>``, which makes it (a) reduce its sync mean over
        that axis (pmean, or one packed-int8 all_gather when built with
        ``compression=`` — the wire-compressed center exchange) and
        (b) key its per-chain noise by the GLOBAL chain index — per-chain
        noise decorrelates across shards and is invariant to the mesh
        layout, while replicated center state sees identical noise
        everywhere (DESIGN.md §2/§7).  No per-step outputs — the production
        configuration keeps moments in the carry and nothing else leaves
        the device."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import chain_specs

        if specs is None:
            specs = chain_specs(carry, num_chains, chain_axis)

        def chunk(base_key, carry):
            step, _ = self._resolve(None)

            def body(c, _):
                t = c["t"]
                # shard-invariant by design: the chain_axis sampler folds the
                # shard index into its per-chain noise keys itself, keeping
                # center-noise draws replicated (DESIGN.md §2)
                rng = jax.random.fold_in(base_key, t)
                batch = self.device_batch_fn(t) if self.device_batch_fn else None
                params, state, _m = step(c["params"], c["state"], batch, rng)
                c = dict(c, params=params, state=state, t=t + 1)
                if self.moments:
                    wf2 = welford_add(c["wf"], self.moments_of(params))
                    c["wf"] = _select_tree(t >= self.moments_from, wf2, c["wf"])
                return c, None

            c, _ = jax.lax.scan(body, carry, None, length=n)
            return c

        sm = shard_map(
            chunk, mesh=mesh, in_specs=(P(), specs), out_specs=specs, check_rep=False
        )
        return jax.jit(sm, donate_argnums=(1,) if self.donate else ())

    def _sharded_carry(self, params, state, start_step):
        carry = self._init_carry(params, state, start_step, None, sweep=False)
        carry.pop("key")
        carry.pop("ess")  # probe shapes are global; keep the sharded carry minimal
        return carry

    @staticmethod
    def _check_mesh(mesh, chain_axis: str, num_chains: int) -> None:
        """Multi-device contract (DESIGN.md §7): the chain axis must exist
        on the mesh and divide K evenly — equal per-shard chain counts are
        what make the hierarchical (local mean, cross-shard mean) exchange
        equal the global chain mean."""
        if chain_axis not in mesh.shape:
            raise ValueError(
                f"mesh has axes {tuple(mesh.shape)}; no {chain_axis!r} axis"
            )
        axis_size = mesh.shape[chain_axis]
        if num_chains % axis_size != 0:
            raise ValueError(
                f"num_chains={num_chains} must be divisible by the "
                f"{chain_axis!r} mesh axis (size {axis_size})"
            )

    def run_sharded(
        self,
        params,
        state,
        *,
        num_steps: int,
        key,
        mesh,
        chain_axis: str = "chain",
        num_chains: int | None = None,
        start_step: int = 0,
        specs=None,
    ) -> RunResult:
        """Device-resident run with the chain axis sharded over ``mesh``
        (chunked like ``run``; no traces/stats — moments stay in carry).

        ``mesh`` may carry a ``chain_axis`` of ANY size that divides the
        chain count — 1 (the SPMD emulation) through one device per chain.
        The compiled program is layout-invariant for samplers built with
        ``chain_axis=``: per-chain trajectories are bit-identical across
        mesh sizes wherever reduction order allows (DESIGN.md §7, gated by
        tests/test_sharding.py).

        ``specs``: explicit carry PartitionSpec pytree, overriding the
        ``chain_specs`` shape heuristic — REQUIRED when replicated state has
        a leading dim that coincidentally equals ``num_chains`` (the
        heuristic would shard it; see ``chain_specs``' docstring)."""
        num_chains = num_chains or self._sweep_size(params)
        self._check_mesh(mesh, chain_axis, num_chains)
        carry = self._sharded_carry(params, state, start_step)
        t0 = time.perf_counter()
        done = 0
        while done < num_steps:
            n = min(self.chunk_steps, num_steps - done)
            sig = ("sharded", n, chain_axis, id(mesh))
            if sig not in self._compiled:
                self._compiled[sig] = self._build_sharded(
                    n, mesh, chain_axis, carry, num_chains, specs
                )
            with obs_trace.get().span("executor.chunk", cat="executor",
                                      step=done, n=n, sharded=True):
                carry = self._compiled[sig](key, carry)
            done += n
        with obs_trace.get().span("executor.settle", cat="executor", step=done):
            jax.block_until_ready(carry["params"])
        wall = time.perf_counter() - t0
        return RunResult(
            params=carry["params"], state=carry["state"], trace=None, stats=None,
            metrics={}, moments=carry["wf"], ess=None, steps=done, wall_s=wall,
        )

    def lower_sharded(self, params, state, *, num_steps, key, mesh,
                      chain_axis: str = "chain", num_chains: int | None = None,
                      specs=None):
        """Lowered (pre-compile) sharded chunk for HLO inspection — the
        one-collective-per-sync-period acceptance check reads its text
        (raw center exchange: one all-reduce; compressed: one all-gather)."""
        num_chains = num_chains or self._sweep_size(params)
        self._check_mesh(mesh, chain_axis, num_chains)
        carry = self._sharded_carry(params, state, 0)
        fn = self._build_sharded(num_steps, mesh, chain_axis, carry, num_chains, specs)
        return fn.lower(key, carry)


def ess_feedback_adapter(controller, hyper_key: str = "step_size"):
    """Bridge a ``schedules.FeedbackESS`` controller to the executor's
    ``adapt_fn`` hook: at each chunk boundary, turn the in-carry batch-means
    ESS into an ESS-per-step rate, feed it to ``controller.update``, and
    hand the controller's new value back through ``hyper[hyper_key]``.

    Requires the executor to be built with ``ess_probe_fn`` (the streaming
    ESS accumulator must ride the carry) and the sampler to be built via
    ``sampler_factory`` reading ``hyper[hyper_key]``.  The replacement value
    is always a jnp.float32 scalar — same aval every chunk, so the compiled
    scan NEVER retraces (pinned by tests/test_executor.py)."""

    def adapt(step_end, carry, hyper):
        es = carry.get("ess")
        if es is None:
            raise ValueError("ess_feedback_adapter requires an executor with ess_probe_fn")
        count = float(np.asarray(es.count))
        if count < 2.0 * float(np.asarray(es.batch_len)):
            return None  # need >= 2 complete batches for a defensible estimate
        ess = np.asarray(batch_ess_estimate(es))
        controller.update(float(np.mean(ess)) / max(count, 1.0), step=step_end)
        new_hyper = dict(hyper or {})
        new_hyper[hyper_key] = jnp.asarray(controller.value, jnp.float32)
        return new_hyper

    return adapt


def rollout(
    sampler,
    grad_fn,
    params,
    *,
    num_steps: int,
    keys=None,
    key=None,
    state=None,
    trace: bool = True,
    thin: int = 1,
    moments: bool = True,
    moments_from: int = 0,
    chunk_steps: int = 4096,
    key_mode: str = "keys",
    sweep: bool = False,
    **kw,
) -> RunResult:
    """One-call executor run for sampler-over-potential workloads (the test
    battery, toy benchmarks, ensemble collection).  ``grad_fn(theta)`` takes
    only the gradient targets — batch plumbing belongs to the training
    stack."""
    if chunk_steps % thin != 0:
        chunk_steps = thin * max(chunk_steps // thin, 1)
    ex = ChainExecutor(
        sampler=sampler,
        grad_fn=lambda targets, _batch: grad_fn(targets),
        trace_fn=(lambda p: p) if trace else None,
        thin=thin,
        moments=moments,
        moments_from=moments_from,
        chunk_steps=chunk_steps,
        key_mode=key_mode,
        **kw,
    )
    if state is None:
        init = jax.vmap(sampler.init) if sweep else sampler.init
        state = init(params)
    return ex.run(params, state, num_steps=num_steps, keys=keys, key=key, sweep=sweep)
