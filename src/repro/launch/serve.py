"""Serving launcher.

Two paths:

* legacy single-stream decoding (+ ``ensemble_decode``, the vmapped
  whole-batch Bayesian-model-averaging loop — kept as the simple reference
  implementation);
* ``--engine``: the continuous-batching posterior-predictive engine
  (``repro.serve.engine``) — request-level scheduling over a fixed slot
  axis, cache pooling, BMA over K ensemble members, and (``--refresh-every``)
  live snapshot refresh from a background coupled-sampler run.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 16 --gen 8 --ensemble 2
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --engine --slots 4 --requests 12 --ensemble 2 --refresh-every 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro import core
from repro import obs
from repro.models import get_model, init_params
from repro.serve.engine import (
    ChainRefresher,
    RefreshScheduler,
    ServeEngine,
    SnapshotRegistry,
    synthetic_trace,
)
from repro.serve.loop import (
    collect_ensemble,
    ensemble_diagnostics,
    make_decode_step,
    make_prefill_step,
)
from repro.serve.sampling import SamplingParams

log = obs.get_logger("serve")

# prior-bootstrap ensemble: members are thinned SGLD draws from
# N(params_init, PRIOR_SCALE^2 I) — a posterior stand-in when no sampled
# checkpoint is supplied; the spread matches the init scale so BMA is
# exercised with realistic dispersion.
PRIOR_SCALE = 0.02
_PREC = 1.0 / PRIOR_SCALE**2
_EPS = 0.2 / _PREC  # eps*lam = 0.2: stable, mixes in ~5 steps


def _prior_grad(center):
    """grad of the bootstrap prior N(center, PRIOR_SCALE^2 I); leaf
    broadcasting makes it work for unstacked and (K,...)-stacked params."""
    return lambda p: jax.tree.map(lambda x, x0: _PREC * (x - x0), p, center)


def _bootstrap_ensemble(specs, key, num: int):
    center = init_params(specs, key)
    start = jax.tree.map(lambda x: x + 0.0, center)  # rollout donates its input
    members, res = collect_ensemble(
        core.sgld(step_size=_EPS), _prior_grad(center), start,
        num_samples=num, key=jax.random.fold_in(key, 1), thin=16,
    )
    return members, res


def _live_refresher(specs, key, registry: SnapshotRegistry, chunk_steps: int = 16,
                    mode: str = "overlapped"):
    """Background chain-stacked SGLD over the same bootstrap prior — the
    live run whose chunk-boundary chain stack refreshes the registry.
    ``mode='overlapped'`` (default) builds the async ``RefreshScheduler``
    (DESIGN.md §9); ``'sync'`` keeps the legacy inline ``ChainRefresher``."""
    center = init_params(specs, key)
    start = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (registry.num_members,) + x.shape) + 0.0, center
    )
    cls = RefreshScheduler if mode == "overlapped" else ChainRefresher
    return cls(
        registry,
        core.sgld(step_size=_EPS),
        _prior_grad(center),
        start,
        key=jax.random.fold_in(key, 2),
        chunk_steps=chunk_steps,
    )


def ensemble_decode(cfg, model, params_stack, batch, max_seq: int, num_tokens: int):
    """Average predictive probs over the chain/ensemble axis of params."""
    k = jax.tree.leaves(params_stack)[0].shape[0]

    def prefill_one(p):
        return model.prefill(cfg, p, batch, max_seq)

    logits, caches = jax.vmap(prefill_one)(params_stack)
    probs = jnp.mean(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=0)
    tok = jnp.argmax(probs[:, -1], -1).astype(jnp.int32)[:, None]
    out = [tok]

    def step_one(p, c, t):
        return model.decode_step(cfg, p, c, t)

    vstep = jax.jit(jax.vmap(step_one, in_axes=(0, 0, None)))
    for _ in range(num_tokens - 1):
        logits, caches = vstep(params_stack, caches, tok)
        probs = jnp.mean(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=0)
        tok = jnp.argmax(probs[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def _run_engine(args, cfg, model):
    specs = model.param_specs(cfg)
    key = jax.random.PRNGKey(args.seed)
    k = max(args.ensemble, 1)
    if k > 1:
        members, res = _bootstrap_ensemble(specs, key, k)
        log.info(f"ensemble: K={k} collected at {res.steps_per_s:.0f} steps/s")
    else:
        members = jax.tree.map(lambda x: x[None], init_params(specs, key))
    registry = SnapshotRegistry(members)
    refresher = None
    if args.refresh_every and k > 1:
        refresher = _live_refresher(specs, key, registry, mode=args.refresh_mode)
    max_seq = args.prompt_len + args.gen + 1
    engine = ServeEngine(
        cfg, model, registry,
        num_slots=args.slots, max_seq=max_seq,
        sampling=SamplingParams(args.temperature, args.top_k),
        bma=args.bma, eos_id=args.eos, seed=args.seed,
        refresher=refresher, refresh_every=args.refresh_every,
    )
    trace = synthetic_trace(
        args.requests,
        vocab_size=cfg.vocab_size,
        prompt_lens=(max(args.prompt_len // 2, 1), args.prompt_len),
        max_new=args.gen,
        mean_interarrival=args.interarrival,
        seed=args.seed,
    )
    report = engine.run(trace)
    pct = report.latency_percentiles()
    log.info(
        f"served {len(report.results)} requests / {report.total_tokens} tokens "
        f"in {report.wall_s:.2f}s ({report.tokens_per_s:.1f} tok/s, "
        f"slots={args.slots}, K={k}, decode_traces={report.trace_counts.get('decode')})"
    )
    log.info(
        f"latency p50={pct['latency_p50_s'] * 1e3:.1f}ms p99={pct['latency_p99_s'] * 1e3:.1f}ms  "
        f"first-token p50={pct['first_token_p50_s'] * 1e3:.1f}ms "
        f"p99={pct['first_token_p99_s'] * 1e3:.1f}ms"
    )
    if refresher is not None:
        rf = report.refresher
        log.info(f"snapshots: {report.registry['version']} promoted, {report.registry['rejected']} rejected, "
              f"{rf['steps_done']} sampler steps")
        if "pump_wall_s" in rf:  # overlapped scheduler observability
            log.info(
                f"overlap: {rf['micro_chunks']} micro-chunks of {rf['micro_steps']} steps "
                f"on {rf['device'] or 'default device'}, pump {rf['pump_wall_s']:.3f}s, "
                f"per-refresh {rf['per_refresh_wall_s'] * 1e3:.1f}ms, "
                f"stalled {rf['decode_steps_stalled']} ticks ({rf['stall_wall_s']:.3f}s), "
                f"deferred {rf['flips_deferred']} flips"
            )
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--ensemble", type=int, default=1, help="posterior samples to average")
    ap.add_argument("--seed", type=int, default=0)
    # engine path
    ap.add_argument("--engine", action="store_true", help="continuous-batching engine")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--interarrival", type=float, default=2.0, help="mean decode-steps between arrivals")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--bma", choices=("probs", "logprobs"), default="probs")
    ap.add_argument("--eos", type=int, default=None)
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="decode-step cadence of live snapshot refresh (0 = frozen members)")
    ap.add_argument("--refresh-mode", choices=("overlapped", "sync"), default="overlapped",
                    help="overlapped: async micro-chunk scheduler (decode never stalls); "
                         "sync: legacy inline ChainRefresher")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Perfetto trace.json of the run to PATH")
    args = ap.parse_args(argv)

    tracer, trace_path = obs.configure(args.trace)
    cfg = configs.get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    if args.engine:
        report = _run_engine(args, cfg, model)
        if trace_path:
            tracer.export(trace_path)
            log.info(f"trace written to {trace_path} ({len(tracer)} events)")
        return report
    max_seq = args.prompt_len + args.gen + 1
    key = jax.random.PRNGKey(args.seed)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.02 * jax.random.normal(key, (args.batch, cfg.enc_seq, cfg.d_model))

    t0 = time.time()
    if args.ensemble > 1:
        # device-resident collection: one compiled sampler run, thinned
        # trace = the ensemble (repro.serve.loop.collect_ensemble)
        params, res = _bootstrap_ensemble(
            model.param_specs(cfg), jax.random.PRNGKey(args.seed), args.ensemble
        )
        health = ensemble_diagnostics(params)
        log.info(
            f"ensemble: K={health['num_chains']} spread={health['chain_spread']:.3e} "
            f"rel={health['rel_spread']:.3e} "
            f"(collected at {res.steps_per_s:.0f} steps/s)"
            + (" [COLLAPSED — BMA is a no-op]" if health["collapsed"] else "")
        )
        toks = ensemble_decode(cfg, model, params, batch, max_seq, args.gen)
    else:
        params = init_params(model.param_specs(cfg), key)
        prefill = jax.jit(make_prefill_step(cfg, model, max_seq))
        step = jax.jit(make_decode_step(cfg, model))
        tok, cache = prefill(params, batch)
        out = [tok]
        for _ in range(args.gen - 1):
            tok, cache = step(params, cache, tok)
            out.append(tok)
        toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    log.info(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s, ensemble={args.ensemble})")
    log.info(str(toks))
    if trace_path:
        tracer.export(trace_path)
        log.info(f"trace written to {trace_path} ({len(tracer)} events)")
    return toks


if __name__ == "__main__":
    main()
