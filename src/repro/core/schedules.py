"""Step-size schedules. A schedule is ``step -> epsilon`` (jnp scalar)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def fn(step):
        return jnp.asarray(value, jnp.float32)

    return fn


def polynomial_decay(a: float, b: float, gamma: float):
    """epsilon_t = a * (b + t)^(-gamma) — the classic SG-MCMC decay
    (Welling & Teh 2011 conditions: gamma in (0.5, 1])."""

    def fn(step):
        return jnp.asarray(a, jnp.float32) * (b + step.astype(jnp.float32)) ** (-gamma)

    return fn


def cosine(peak: float, total_steps: int, floor: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))

    return fn


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def fn(step):
        t = step.astype(jnp.float32)
        warm = peak * t / max(warmup_steps, 1)
        frac = jnp.clip((t - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(t < warmup_steps, warm, cos)

    return fn


def as_schedule(x):
    if callable(x):
        return x
    # no float() coercion: x may be a traced scalar (vmapped hyperparameter
    # sweeps build samplers inside the program — repro.run.executor)
    return constant(x)
