"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch, run one forward (train NLL), one prefill and one decode step
on CPU; assert shapes and finiteness.  The FULL configs are exercised only
via the dry-run (abstract shapes, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import abstract_params, get_model, init_params

ARCHS = list(configs.ARCH_IDS)
B, S = 2, 32


def _batch(cfg, rng):
    kt, kl = jax.random.split(rng)
    n_text = S
    batch = {}
    if cfg.family == "vlm":
        n_patch = 8
        n_text = S - n_patch
        batch["patch_embeds"] = 0.02 * jax.random.normal(rng, (B, n_patch, cfg.d_model))
        # M-RoPE positions: patches get (t=0, h, w); text continues temporally
        t = jnp.concatenate([jnp.zeros(n_patch, jnp.int32), jnp.arange(n_text, dtype=jnp.int32) + 1])
        h = jnp.concatenate([jnp.arange(n_patch, dtype=jnp.int32) // 4, jnp.arange(n_text, dtype=jnp.int32) + 1])
        w = jnp.concatenate([jnp.arange(n_patch, dtype=jnp.int32) % 4, jnp.arange(n_text, dtype=jnp.int32) + 1])
        pos = jnp.stack([t, h, w])  # (3, S)
        batch["positions"] = jnp.broadcast_to(pos[:, None], (3, B, S))
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.02 * jax.random.normal(rng, (B, cfg.enc_seq, cfg.d_model))
    batch["tokens"] = jax.random.randint(kt, (B, n_text), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(kl, (B, n_text), 0, cfg.vocab_size)
    return batch


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_config(arch, smoke=True)
            model = get_model(cfg)
            params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_forward(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    sum_nll, count = jax.jit(lambda p, b: model.train_nll(cfg, p, b))(params, batch)
    assert np.isfinite(float(sum_nll)), f"{arch}: non-finite NLL"
    n_text = batch["labels"].shape[1]
    assert int(count) == B * n_text
    # untrained model ≈ uniform: NLL/token near log(vocab)
    per_tok = float(sum_nll) / float(count)
    assert 0.5 * np.log(cfg.vocab_size) < per_tok < 2.0 * np.log(cfg.vocab_size), per_tok


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grads_finite(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    batch = _batch(cfg, jax.random.PRNGKey(2))

    def loss(p):
        s, c = model.train_nll(cfg, p, batch)
        return s / c

    grads = jax.jit(jax.grad(loss))(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.all(np.isfinite(np.asarray(g))), f"{arch}: non-finite grad at {path}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    batch = _batch(cfg, jax.random.PRNGKey(3))
    max_seq = S + 8
    logits, cache = jax.jit(
        lambda p, b: model.prefill(cfg, p, b, max_seq=max_seq)
    )(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(cache["t"]) == (S if cfg.family != "vlm" else S)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    step = jax.jit(lambda p, c, t: model.decode_step(cfg, p, c, t))
    for _ in range(3):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_abstract_params_match_init(arch, arch_setup):
    """abstract_params (dry-run path) must agree with materialized params."""
    cfg, model, params = arch_setup(arch)
    abstract = abstract_params(model.param_specs(cfg))
    concrete = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    assert jax.tree.all(
        jax.tree.map(lambda a, b: a.shape == b.shape and a.dtype == b.dtype, abstract, concrete)
    )


def test_decode_matches_prefill_incremental():
    """Decode-with-cache must agree with re-running the full sequence
    (teacher forcing) — checks cache correctness end-to-end. Dense arch."""
    cfg = configs.get_config("qwen3-0.6b", smoke=True)
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 12), 0, cfg.vocab_size)

    # full forward logits at the last position via prefill on t tokens
    def last_logits(n):
        batch = {"tokens": toks[:, :n], "labels": toks[:, :n]}
        lg, _ = model.prefill(cfg, params, batch, max_seq=16)
        return np.asarray(lg[0, 0], np.float32)

    # incremental: prefill 8, then decode tokens 8..11
    batch = {"tokens": toks[:, :8], "labels": toks[:, :8]}
    lg, cache = model.prefill(cfg, params, batch, max_seq=16)
    np.testing.assert_allclose(np.asarray(lg[0, 0]), last_logits(8), rtol=2e-4, atol=2e-4)
    for t in range(8, 12):
        lg, cache = model.decode_step(cfg, params, cache, toks[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(lg[0, 0], np.float32), last_logits(t + 1), rtol=2e-4, atol=2e-4,
            err_msg=f"decode step at t={t}",
        )


def test_decode_matches_prefill_windowed():
    """Same check for a sliding-window arch (ring-buffer cache path)."""
    cfg = configs.get_config("h2o-danube-1.8b", smoke=True)
    model = get_model(cfg)
    params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(8), (1, 14), 0, cfg.vocab_size)

    def last_logits(n):
        lg, _ = model.prefill(cfg, params, {"tokens": toks[:, :n], "labels": toks[:, :n]}, max_seq=16)
        return np.asarray(lg[0, 0], np.float32)

    lg, cache = model.prefill(cfg, params, {"tokens": toks[:, :10], "labels": toks[:, :10]}, max_seq=16)
    np.testing.assert_allclose(np.asarray(lg[0, 0]), last_logits(10), rtol=2e-4, atol=2e-4)
    for t in range(10, 14):
        lg, cache = model.decode_step(cfg, params, cache, toks[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(lg[0, 0], np.float32), last_logits(t + 1), rtol=2e-4, atol=2e-4,
            err_msg=f"windowed decode at t={t}",
        )


def test_recurrent_decode_matches_prefill():
    """RG-LRU / xLSTM state handoff from prefill to decode."""
    for arch in ("recurrentgemma-2b", "xlstm-350m"):
        cfg = configs.get_config(arch, smoke=True)
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(9), (1, 12), 0, cfg.vocab_size)

        def last_logits(n):
            lg, _ = model.prefill(cfg, params, {"tokens": toks[:, :n], "labels": toks[:, :n]}, max_seq=16)
            return np.asarray(lg[0, 0], np.float32)

        lg, cache = model.prefill(cfg, params, {"tokens": toks[:, :8], "labels": toks[:, :8]}, max_seq=16)
        for t in range(8, 12):
            lg, cache = model.decode_step(cfg, params, cache, toks[:, t : t + 1])
            np.testing.assert_allclose(
                np.asarray(lg[0, 0], np.float32), last_logits(t + 1), rtol=5e-4, atol=5e-4,
                err_msg=f"{arch} decode at t={t}",
            )
