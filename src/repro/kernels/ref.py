"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# --- fused EC-SGHMC update -------------------------------------------------


def _bits_to_unit(bits):
    return (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24)) + (0.5 / (1 << 24))


def box_muller(bits1, bits2):
    u1 = _bits_to_unit(bits1)
    u2 = _bits_to_unit(bits2)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos((2.0 * jnp.pi) * u2)


def fused_ec_update(
    theta, p, g, c_tilde, bits1, bits2, *, eps, friction, mass, alpha, sigma_p
):
    """Reference Eq. 6 chain update with Box-Muller noise from given bits.
    Returns (theta_new_f32, p_new_f32) — round-to-nearest casting is applied
    by callers; stochastic rounding is validated distributionally."""
    minv = 1.0 / mass
    t32, p32 = theta.astype(jnp.float32), p.astype(jnp.float32)
    noise = box_muller(bits1, bits2)
    theta_new = t32 + eps * minv * p32
    p_new = (
        (1.0 - eps * friction * minv) * p32
        - eps * g.astype(jnp.float32)
        - eps * alpha * (t32 - c_tilde.astype(jnp.float32))
        + sigma_p * noise
    )
    return theta_new, p_new


# --- flash attention ---------------------------------------------------------


def attention(q, k, v, *, causal=True, window=None, softcap=None, scale=None):
    """q: (B, Hq, S, d); k/v: (B, Hkv, S, d); GQA by head broadcast.
    Full-materialization reference."""
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qr = q.reshape(B, Hkv, G, S, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qr * scale, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, S, d)


# --- paged attention (single-token decode) -----------------------------------


def gather_pages(pages, block_tables):
    """(num_pages, bs, Hkv, d) pool + (B, M) int32 tables -> the dense
    per-sequence cache (B, M*bs, Hkv, d) a slot-resident engine would hold."""
    B, M = block_tables.shape
    _, bs, Hkv, d = pages.shape
    return pages[block_tables].reshape(B, M * bs, Hkv, d)


def paged_attention(
    q, k_pages, v_pages, block_tables, context_lens,
    *, scale=None, window=None, softcap=None,
):
    """Dense full-materialization reference for the paged decode kernel:
    gather every page into a contiguous cache, then masked softmax in f32.
    q: (B, Hkv, G, d); context_lens (B,) is the INCLUSIVE current position
    (the query's own kpos).  Returns (B, Hkv, G, d)."""
    B, Hkv, G, d = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = gather_pages(k_pages, block_tables).astype(jnp.float32)  # (B, T, Hkv, d)
    v = gather_pages(v_pages, block_tables).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32) * scale, k)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(k.shape[1])[None, :]  # (1, T)
    ctx = context_lens[:, None]
    mask = kpos <= ctx
    if window is not None:
        mask &= (ctx - kpos) < window
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgt,bthd->bhgd", w, v).astype(q.dtype)


# --- fused BMA mixture + selection -------------------------------------------


def bma_select(logits, gumbel, *, mode, temperature, top_k):
    """Unfused oracle for kernels.bma_select: mixture via the serving-tier
    helper, selection via argmax over (scaled, top-k-masked) + Gumbel —
    exactly what jax.random.categorical computes given the same draw."""
    from repro.serve.engine.bma import mixture_logprobs
    from repro.serve.sampling import _top_k_mask

    logp = mixture_logprobs(logits, mode)  # (S, V) f32
    if temperature <= 0.0:
        return jnp.argmax(logp, axis=-1).astype(jnp.int32), logp
    sel = logp / jnp.float32(temperature)
    if top_k:
        sel = _top_k_mask(sel, top_k)
    tok = jnp.argmax(sel + gumbel.astype(jnp.float32), axis=-1).astype(jnp.int32)
    return tok, logp


# --- RG-LRU scan -------------------------------------------------------------


def rglru_scan(a, x, h0=None):
    """h_t = a_t * h_{t-1} + x_t over axis 1.  a, x: (B, S, R) f32."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a = a.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if h0 is not None:
        x = x.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h
