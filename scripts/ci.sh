#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh            tier-1 suite with the slow stationary configs
#                            deselected (~10 min on CPU — dominated by the
#                            pre-existing arch/dryrun smoke suites, not the
#                            stationary battery)
#   RUN_SLOW=1 scripts/ci.sh ...then the slow stationary battery on top
#   scripts/ci.sh <args>     extra args forwarded to the fast pytest run
#
# The canonical tier-1 command (ROADMAP.md) remains
#   PYTHONPATH=src python -m pytest -x -q
# which runs EVERYTHING including slow-marked configs; this script is the
# quick gate that still exercises a fast subset of the stationary battery.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 (fast: -m 'not slow') =="
python -m pytest -x -q -m "not slow" "$@"

if [[ "${RUN_SLOW:-0}" == "1" ]]; then
  echo "== stationary battery (slow configs) =="
  python -m pytest -q -m slow tests/test_stationary.py
fi
