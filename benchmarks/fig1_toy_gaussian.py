"""Paper Fig. 1: first sampling steps on a 2-D Gaussian (alpha=1, eps=1e-2,
C=V=I, K=4, all samplers from the same initial guess).

What the figure actually shows (and what we quantify):
  (1) independent SGHMC runs take erratic initial paths — "depending on the
      noise it can happen that SGHMC only explores low-density regions in
      its first steps (cf. purple curve)".  Metric: WORST-case mean NLL
      across independent runs.
  (2) the elastically coupled chains "quickly sample from high density
      regions and show coherent behaviour".  Metrics: worst-case mean NLL
      across chains, and cross-chain spread (coherence).

Execution: each sampler's independent runs are ONE vmapped
``ChainExecutor`` program (the sweep axis carries the seeds) — the grid
that used to be a Python loop of per-seed scans compiles once and runs
device-resident.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro import diagnostics as diag
from repro.run import ChainExecutor

from common import QUICK, emit, record

MU = jnp.array([2.0, -1.0])
STEPS = 600
K = 4
N_RUNS = 8  # independent SGHMC seeds (the paper's two, statistically robust)


def grad_U(theta):
    return theta - MU


def nll(x):
    return 0.5 * np.sum((np.asarray(x) - np.asarray(MU)) ** 2, axis=-1)


def _run_swept(sampler, params0, seeds):
    """All ``seeds`` as one vmapped executor program; (R, STEPS, ...) traj.
    One executor, two runs: the jit cache persists, so the second (reported)
    run's wall time is pure compute."""
    keys = jnp.stack([jax.random.split(jax.random.PRNGKey(s), STEPS) for s in seeds])
    ex = ChainExecutor(sampler=sampler, grad_fn=lambda t, _b: grad_U(t),
                       trace_fn=lambda p: p, chunk_steps=STEPS, key_mode="keys")

    def go():
        stacked = jnp.broadcast_to(params0[None], (len(seeds),) + params0.shape) + 0.0
        state = jax.vmap(sampler.init)(stacked)
        return ex.run(stacked, state, num_steps=STEPS, keys=keys, sweep=True)

    go()  # compile
    res = go()
    return np.asarray(res.trace), res


def run():
    start = jnp.array([-2.0, 3.0])
    sg = core.sghmc(step_size=1e-2, friction=1.0)
    t_sg, res_sg = _run_swept(sg, start, range(N_RUNS))  # (R, S, 2)

    ec = core.ec_sghmc(step_size=1e-2, alpha=1.0, friction=1.0, center_friction=1.0,
                       sync_every=1, noise_convention="eq6")
    t_ec, res_ec = _run_swept(
        ec, jnp.broadcast_to(start[None], (K, 2)), [100, 101]
    )  # (2, S, K, 2)

    # wall-clock per *sampler step* of the compiled sweep (R runs advance in
    # lockstep, so the whole grid costs one program's wall time)
    us = 1e6 * res_ec.wall_s / STEPS

    # (1) worst-case exploration over the first 150 steps
    sg_worst = float(max(nll(t_sg[r, :150]).mean() for r in range(N_RUNS)))
    ec_worst = float(
        max(nll(t_ec[g, :150, i]).mean() for g in range(2) for i in range(K))
    )
    # (2) coherence: late-phase cross-chain spread vs cross-run spread
    # (shared estimator — leading axis = runs resp. chains)
    sg_spread = float(diag.cross_chain_spread(t_sg[:, 400:, :]))
    ec_spread = float(diag.cross_chain_spread(np.moveaxis(t_ec[0, 400:, :, :], 1, 0)))
    # (3) both reach the mode: final NLL of the pooled posterior mean
    sg_final = float(nll(diag.pooled_moments(t_sg[:, 500:])[0]))
    ec_final = float(nll(diag.pooled_moments(t_ec[:, 500:])[0].mean(axis=0)))
    # (4) exploration speed: effective sample size per position dim.
    # Pool BOTH EC groups (2 x K = 8 chains) so the raw sample budget
    # matches the N_RUNS=8 SGHMC side.  The pooled estimator assumes
    # independent chains — exact for the SGHMC runs, an UPPER bound for the
    # coupled chains — so the conservative chain-mean (coupled) ESS is
    # emitted alongside; the truth for EC lies between the two.
    ec_chains = np.concatenate(
        [np.moveaxis(t_ec[g, 150:, :, :], 1, 0) for g in range(t_ec.shape[0])], axis=0
    )  # (2K, S', 2)
    sg_ess = float(np.sum(diag.effective_sample_size_nd(t_sg[:, 150:, :])))
    ec_ess = float(np.sum(diag.effective_sample_size_nd(ec_chains)))
    sg_cess = float(np.sum(diag.coupled_ess_nd(t_sg[:, 150:, :])))
    ec_cess = float(np.sum(diag.coupled_ess_nd(ec_chains)))
    sg_rhat = float(np.max(diag.split_rhat_nd(t_sg[:, 150:, :])))
    ec_rhat = float(np.max(diag.split_rhat_nd(ec_chains)))

    emit("fig1_toy/sghmc_worst_run_nll_first100", us, f"{sg_worst:.3f}")
    emit("fig1_toy/ecsghmc_worst_chain_nll_first100", us, f"{ec_worst:.3f}")
    emit("fig1_toy/sghmc_cross_run_spread", us, f"{sg_spread:.4f}")
    emit("fig1_toy/ecsghmc_cross_chain_spread", us, f"{ec_spread:.4f}")
    emit("fig1_toy/sghmc_final_mean_nll", us, f"{sg_final:.4f}")
    emit("fig1_toy/ecsghmc_final_mean_nll", us, f"{ec_final:.4f}")
    emit("fig1_toy/sghmc_pooled_ess", us, f"{sg_ess:.0f}")
    emit("fig1_toy/ecsghmc_pooled_ess", us, f"{ec_ess:.0f}")
    emit("fig1_toy/sghmc_chain_mean_ess", us, f"{sg_cess:.0f}")
    emit("fig1_toy/ecsghmc_chain_mean_ess", us, f"{ec_cess:.0f}")
    emit("fig1_toy/sghmc_split_rhat", us, f"{sg_rhat:.3f}")
    emit("fig1_toy/ecsghmc_split_rhat", us, f"{ec_rhat:.3f}")
    ok = ec_worst < sg_worst and ec_spread < sg_spread and ec_final < 0.5
    emit("fig1_toy/claim_ec_coherent_fast_exploration", us, "CONFIRMED" if ok else "REFUTED")

    record("perf", {
        "sghmc": {
            "us_per_step": 1e6 * res_sg.wall_s / STEPS,
            "steps_per_s": res_sg.steps_per_s,
            "sweep_runs": N_RUNS,
            "ess_per_s": sg_ess / max(res_sg.wall_s, 1e-9),
        },
        "ec_sghmc": {
            "us_per_step": 1e6 * res_ec.wall_s / STEPS,
            "steps_per_s": res_ec.steps_per_s,
            "sweep_runs": 2,
            "ess_per_s": ec_ess / max(res_ec.wall_s, 1e-9),
        },
        "config": {"steps": STEPS, "chains": K, "alpha": 1.0, "step_size": 1e-2,
                   "sync_every": 1, "quick": QUICK},
    })
    return {
        "sg_worst": sg_worst, "ec_worst": ec_worst,
        "sg_spread": sg_spread, "ec_spread": ec_spread,
        "sg_ess": sg_ess, "ec_ess": ec_ess,
    }


if __name__ == "__main__":
    run()
