"""Test-suite bootstrap: make ``python -m pytest`` work from the repo root
without the ``PYTHONPATH=src`` incantation (which keeps working unchanged —
duplicate sys.path entries are harmless).

Multi-device harness (DESIGN.md §7): tests marked ``multidevice`` assume a
forced 8-CPU-device backend (``XLA_FLAGS=--xla_force_host_platform_device_
count=8``), which must be set BEFORE jax initializes — impossible to do
in-process once the suite has touched a device.  They therefore only run in
a child pytest launched with :func:`tests.util.multidevice_env` (the CI lane
does this, and ``tests/test_sharding.py`` carries a slow-marked relaunch
proxy so ``-m slow`` covers the suite from a plain session).  In a parent
session they auto-skip."""
from __future__ import annotations

import os
import pathlib
import sys

import pytest

_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

MULTIDEVICE_CHILD_ENV = "REPRO_MULTIDEVICE_CHILD"


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running stationary-battery configs (opt-in via -m slow; "
        "scripts/ci.sh deselects them by default)",
    )
    config.addinivalue_line(
        "markers",
        "multidevice: needs a forced multi-CPU-device jax backend; runs only "
        "in a child pytest launched via tests.util.run_multidevice_suite "
        f"(which sets {MULTIDEVICE_CHILD_ENV}=1), auto-skips otherwise",
    )


def pytest_collection_modifyitems(config, items):
    if os.environ.get(MULTIDEVICE_CHILD_ENV) == "1":
        return
    skip = pytest.mark.skip(
        reason="multidevice suite runs in a forced-device child pytest "
        "(scripts/ci.sh multidevice lane, or the slow relaunch proxy in "
        "tests/test_sharding.py)"
    )
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)
