"""Elastically-Coupled SGHMC — the paper's contribution (Eq. 5/6).

K chains (theta^i, p^i) are coupled through a center variable c with its own
momentum r via the augmented Hamiltonian

    H(z) = sum_i [ U(theta^i) + p^iT M^-1 p^i ]
         + (1/K) sum_i (alpha/2) ||theta^i - c||^2  +  rT M^-1 r .

Discretized dynamics (Eq. 6), with the distributed-staleness model made
explicit (communication period ``s``):

    theta^i_{t+1} = theta^i_t + eps M^-1 p^i_t
    c_{t+1}       = c_t       + eps M^-1 r_t
    p^i_{t+1} = p^i_t - eps grad Ũ(theta^i_t) - eps V M^-1 p^i_t
                      - eps alpha (theta^i_t - c̃_t) + N(0, 2 eps^2 (V+C))
    r_{t+1}   = r_t   - eps C M^-1 r_t
                      - eps alpha (c_t - mean_thetã_t) + N(0, 2 eps^2 C)

where c̃ is the *stale* center snapshot each worker last received and
mean_thetã is the *stale* chain average the server last received — both
refreshed every ``s`` steps.  s=1 recovers the fully-synchronous coupled
system; alpha=0 recovers K independent SGHMC chains.

SPMD realization (see DESIGN.md §2): every leaf of params/grads carries a
leading chain axis of size K.  Chain states (momentum) carry the same axis;
center states do not.  When the chain axis is sharded over a mesh axis, the
``mean over axis 0`` executed inside the s-periodic ``lax.cond`` branch is
the ONLY cross-chain collective the compiled program contains — this is the
paper's communication pattern, verbatim.

The momentum update is dispatched through the fused Pallas kernel
(`repro.kernels.fused_ecsghmc`) when ``fused=True`` and shapes allow;
otherwise pure-jnp (identical math, unit-tested against each other).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .schedules import as_schedule
from .sghmc import _noise_scale
from .tree_util import (
    count_params,
    global_norm,
    tree_mean_axis0,
    tree_random_normal,
    tree_random_normal_per_chain,
)
from .types import Sampler


def p_step(p, g, theta, c_tilde, noise, *, eps, friction, minv, alpha, sigma_p,
           out_dtype=jnp.float32):
    """Eq. 6 momentum line, one leaf:  p' = (1 - eps V M^-1) p - eps g
    - eps alpha (theta - c̃) + sigma_p n.  The coupling force enters through
    the momentum — the paper's physics-respecting placement (vs. EAMSGD's
    position placement).

    Term grouping deliberately mirrors the fused Pallas kernel
    (`repro.kernels.fused_ecsghmc._kernel`) so that, given the same noise,
    the unfused and fused paths agree BIT-FOR-BIT in f32 — asserted by
    tests/test_fused_equivalence.py."""
    p32 = p.astype(jnp.float32)
    out = (
        (1.0 - eps * friction * minv) * p32
        - eps * g.astype(jnp.float32)
        - eps * alpha * (theta.astype(jnp.float32) - c_tilde.astype(jnp.float32))
        + sigma_p * noise
    )
    return out.astype(out_dtype)


class ECSGHMCState(NamedTuple):
    momentum: any  # p^i : (K, ...) per leaf
    center: any  # c : (...) per leaf
    center_momentum: any  # r : (...)
    center_stale: any  # c̃ : worker-side stale snapshot of c
    mean_theta_stale: any  # server-side stale mean_i theta^i
    step: jnp.ndarray


def ec_sghmc(
    step_size,
    alpha: float = 1.0,
    friction: float = 1.0,  # V
    center_friction: float = 1.0,  # C
    mass: float = 1.0,
    sync_every: int = 1,  # s
    temperature: float = 1.0,
    noise_convention: str = "eq6",
    center_noise_in_p: bool = True,
    compression=None,  # optional repro.distributed.compression codec for the sync
    fused: bool = False,
    state_dtype=jnp.float32,
    chain_axis: str | None = None,
    per_chain_noise: bool | None = None,
) -> Sampler:
    """``center_noise_in_p``: Eq. 6 as printed injects N(0, 2eps^2 (V+C))
    into p — the C part being the paper's *model* of center-staleness noise.
    When the center is genuinely stale (s > 1 in a real deployment) that
    noise already exists physically and injecting it again double-counts;
    set False to inject only the V part (total noise then matches 2 eps D
    when the staleness noise is real).  Faithful-to-paper default: True.

    ``chain_axis``: mesh axis name the leading chain axis is sharded over
    when the update runs inside ``shard_map`` (DESIGN.md §2/§7).  The
    s-periodic chain mean then reduces over that axis — still the program's
    only cross-chain collective: a pmean, or, with ``compression``, a
    single packed-int8 ``all_gather`` (~4x fewer wire bytes;
    ``distributed.compression.compressed_tree_mean``).  None (default)
    keeps the single-program SPMD emulation where the mean is a plain
    axis-0 reduction (``compression`` then quantizes the reduced mean —
    same noise model, no wire savings).

    ``per_chain_noise``: draw each chain's momentum noise from
    ``fold_in(step_key, global_chain_index)`` instead of one block draw
    per shard.  The stream then depends only on the global chain index, so
    any mesh layout of the same K chains — including the unsharded
    single-device program — sees bit-identical per-chain noise
    (the equivalence contract of DESIGN.md §7, gated by
    tests/test_sharding.py).  Defaults to True under ``chain_axis`` for
    the unfused path; the fused Pallas kernel generates block noise from
    counter bits and keeps the legacy per-shard stream."""
    schedule = as_schedule(step_size)
    minv = 1.0 / mass
    s = int(sync_every)
    if per_chain_noise is None:
        per_chain_noise = chain_axis is not None and not fused
    if per_chain_noise and fused:
        raise ValueError("per_chain_noise requires the unfused update "
                         "(the fused kernel draws block noise from counter bits)")

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, state_dtype)
        center = tree_mean_axis0(jax.tree.map(lambda p: p.astype(state_dtype), params))
        # distinct buffers (aliasing would break XLA donation)
        copy = lambda t: jax.tree.map(jnp.copy, t)
        return ECSGHMCState(
            momentum=jax.tree.map(zeros, params),
            center=center,
            center_momentum=jax.tree.map(lambda c: jnp.zeros_like(c), center),
            center_stale=copy(center),
            mean_theta_stale=copy(center),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, rng):
        eps = schedule(state.step)
        sigma_p = temperature**0.5 * _noise_scale(
            eps, friction, center_friction if center_noise_in_p else 0.0, noise_convention
        )
        sigma_r = temperature**0.5 * _noise_scale(eps, center_friction, 0.0, noise_convention)

        # -- position updates (use pre-update momenta; Eq. 6 lines 1-2) -----
        updates = jax.tree.map(lambda p: eps * minv * p.astype(jnp.float32), state.momentum)
        new_center = jax.tree.map(
            lambda c, r: (c.astype(jnp.float32) + eps * minv * r.astype(jnp.float32)).astype(
                state_dtype
            ),
            state.center,
            state.center_momentum,
        )

        # -- momentum updates ----------------------------------------------
        # shard_map: the caller passes a SHARD-INVARIANT key (DESIGN.md §2).
        # Per-chain noise must differ across shards — per_chain_noise folds
        # the GLOBAL chain index, the legacy block path folds the shard
        # index — while the center noise k_r stays identical everywhere, or
        # the replicated center state would silently random-walk apart.
        k_p, k_r = jax.random.split(rng)
        if chain_axis is not None and not per_chain_noise:
            k_p = jax.random.fold_in(k_p, jax.lax.axis_index(chain_axis))
        noise_r = tree_random_normal(k_r, state.center_momentum, jnp.float32)

        if fused:
            # one-pass Pallas kernel: theta'+p' fused, Box-Muller noise from
            # counter bits (on-chip PRNG on TPU), stochastic-rounded stores
            # for sub-f32 state dtypes. Same dynamics, same noise law.
            from repro.kernels.ops import fused_ec_update_tree

            new_theta_f, new_momentum = fused_ec_update_tree(
                params, state.momentum, grads, state.center_stale, k_p,
                eps=eps, friction=friction, mass=mass, alpha=alpha,
                sigma_p=sigma_p, stochastic_round=True,
            )
            del new_theta_f  # updates (above) already carry eps*M^-1*p
        else:
            if per_chain_noise:
                local_k = jax.tree.leaves(state.momentum)[0].shape[0]
                offset = (
                    jax.lax.axis_index(chain_axis) * local_k
                    if chain_axis is not None
                    else 0
                )
                noise_p = tree_random_normal_per_chain(
                    k_p, state.momentum, offset, jnp.float32
                )
            else:
                noise_p = tree_random_normal(k_p, state.momentum, jnp.float32)
            new_momentum = jax.tree.map(
                lambda p, g, th, ct, n: p_step(
                    p, g, th, ct, n, eps=eps, friction=friction, minv=minv,
                    alpha=alpha, sigma_p=sigma_p, out_dtype=state_dtype,
                ),
                state.momentum, grads, params, state.center_stale, noise_p,
            )

        def r_step(r, c, mth, n):
            r32 = r.astype(jnp.float32)
            out = (
                r32
                - eps * center_friction * minv * r32
                - eps * alpha * (c.astype(jnp.float32) - mth.astype(jnp.float32))
                + sigma_r * n
            )
            return out.astype(state_dtype)

        new_center_momentum = jax.tree.map(
            r_step, state.center_momentum, state.center, state.mean_theta_stale, noise_r
        )

        # -- s-periodic exchange (the ONLY cross-chain collective) ----------
        def do_sync(operand):
            new_c, upd = operand
            # workers push theta^i (post-update), server replies with c.
            new_params = jax.tree.map(
                lambda th, u: th.astype(jnp.float32) + u, params, upd
            )
            if compression is not None and chain_axis is not None:
                # real wire compression: local mean -> packed int8 ->
                # ONE all_gather over the chain axis -> decode + average
                # (the program's only collective; ~4x fewer wire bytes)
                from repro.distributed.compression import compressed_tree_mean

                mean_theta = compressed_tree_mean(new_params, chain_axis)
            else:
                mean_theta = tree_mean_axis0(new_params, chain_axis)
                if compression is not None:
                    # single-program path: quantize the reduced mean —
                    # models the wire noise without moving fewer bytes
                    mean_theta = jax.tree.map(
                        lambda x: compression.decode(compression.encode(x)), mean_theta
                    )
            mean_theta = jax.tree.map(lambda x: x.astype(state_dtype), mean_theta)
            return new_c, mean_theta

        def no_sync(operand):
            del operand
            return state.center_stale, state.mean_theta_stale

        is_sync = (state.step + 1) % s == 0
        new_center_stale, new_mean_theta_stale = jax.lax.cond(
            is_sync, do_sync, no_sync, (new_center, updates)
        )

        new_state = ECSGHMCState(
            momentum=new_momentum,
            center=new_center,
            center_momentum=new_center_momentum,
            center_stale=new_center_stale,
            mean_theta_stale=new_mean_theta_stale,
            step=state.step + 1,
        )
        return updates, new_state

    def stats(state, params):
        """Jit-safe scalar diagnostics: the numbers repro.diagnostics and
        the drivers poll to watch coupling health without a host sync."""
        diff = jax.tree.map(
            lambda th, c: th.astype(jnp.float32) - c.astype(jnp.float32)[None],
            params,
            state.center,
        )
        n_elem = max(count_params(params), 1)
        rms = global_norm(diff) / jnp.sqrt(jnp.float32(n_elem))
        k = jax.tree.leaves(params)[0].shape[0]
        return {
            "step": state.step,
            "momentum_norm": global_norm(state.momentum),
            "center_momentum_norm": global_norm(state.center_momentum),
            "chain_center_rms": rms,
            # the Eq. 5 coupling energy (1/K) sum_i (alpha/2)||theta^i - c||^2
            "coupling_energy": 0.5 * alpha * rms * rms * (n_elem / k),
        }

    return Sampler(init, update, stats=stats)


def resample_chain_from_center(state: ECSGHMCState, alpha: float, rng, num_chains: int):
    """Elastic-K scaling / chain recovery: draw fresh chains from the
    stationary conditional  theta^i | c  ~  N(c, (alpha/K)^-1 I)  implied by
    the coupling term of Eq. 5, with zero momentum.  Returns (params, state)
    for the new chain count."""
    k = num_chains
    scale = (k / max(alpha, 1e-8)) ** 0.5

    def draw(c, key):
        return c[None] + scale * jax.random.normal(key, (k,) + c.shape, c.dtype)

    leaves, treedef = jax.tree.flatten(state.center)
    keys = jax.random.split(rng, len(leaves))
    params = jax.tree.unflatten(treedef, [draw(c, kk) for c, kk in zip(leaves, keys)])
    new_state = ECSGHMCState(
        momentum=jax.tree.map(lambda p: jnp.zeros_like(p), params),
        center=state.center,
        center_momentum=state.center_momentum,
        center_stale=state.center,
        mean_theta_stale=tree_mean_axis0(params),
        step=state.step,
    )
    return params, new_state
