"""Bayesian model averaging across the ensemble-member axis.

The paper's deliverable is K elastically coupled chains whose *product* is
a posterior-predictive: p(y|x) = (1/K) Σ_k p(y|x, θ_k).  ``mixture_logprobs``
reduces per-member logits (K, ..., V) to the mixture's log-probs in f32:

* ``"probs"``     — log((1/K) Σ_k softmax(logits_k)): the exact BMA
  arithmetic mixture (what ``launch.serve.ensemble_decode`` always did);
* ``"logprobs"``  — log-prob averaging, softmax((1/K) Σ_k log softmax):
  the re-normalized geometric mixture (product-of-experts), sharper than
  the arithmetic one and cheaper to fuse — offered because temperature
  sampling composes naturally with it.

``reference_bma_decode`` is the sequential per-member oracle the engine is
verified against (tests/test_serve_engine.py): a plain Python loop over
members, each with its own cache, combined step-by-step with the same
mixture + selection helpers.  Slow by construction, trusted by inspection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.serve.sampling import GREEDY, SamplingParams, mask_after_eos, select_tokens

BMA_MODES = ("probs", "logprobs")


def mixture_logprobs(logits, mode: str = "probs"):
    """(K, ..., V) per-member logits -> (..., V) mixture log-probs (f32)."""
    if mode not in BMA_MODES:
        raise ValueError(f"mode must be one of {BMA_MODES}, got {mode!r}")
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    if mode == "probs":
        return jax.nn.logsumexp(lp, axis=0) - jnp.log(jnp.float32(lp.shape[0]))
    return jax.nn.log_softmax(jnp.mean(lp, axis=0), axis=-1)


def fused_mixture_select(logits, key, *, mode: str = "probs",
                         sampling: SamplingParams = GREEDY):
    """One-kernel mixture + selection: (K, S, V) per-member logits ->
    (tokens (S,), mixture logprobs (S, V)).  Delegates to the Pallas
    ``bma_select`` kernel, which reproduces ``mixture_logprobs`` +
    ``select_tokens`` exactly — sampled selection rides the Gumbel-argmax
    identity (see ``repro.serve.sampling.gumbel_argmax_select``) so the
    token draw is bit-identical to ``jax.random.categorical`` with the
    same key."""
    from repro.kernels import fused_bma_select

    if mode not in BMA_MODES:
        raise ValueError(f"mode must be one of {BMA_MODES}, got {mode!r}")
    return fused_bma_select(
        logits, key, mode=mode,
        temperature=float(sampling.temperature), top_k=int(sampling.top_k),
    )


def reference_bma_decode(
    cfg,
    model,
    member_list,
    batch,
    max_seq: int,
    num_tokens: int,
    *,
    mode: str = "probs",
    sampling: SamplingParams = GREEDY,
    key=None,
    eos_id: int | None = None,
    pad_id: int = 0,
):
    """Sequential per-member reference: K separate prefill/decode streams,
    mixed per step.  Returns (tokens (B, num_tokens), logprob trace
    (num_tokens, B, V)) — tokens post-EOS masked like the engine's."""
    step_key = lambda i: None if key is None else jax.random.fold_in(key, i)
    logits_k, caches = [], []
    for p in member_list:
        logits, cache = model.prefill(cfg, p, batch, max_seq)
        logits_k.append(logits[:, -1])
        caches.append(cache)
    logp = mixture_logprobs(jnp.stack(logits_k), mode)  # (B, V)
    tok = select_tokens(logp, step_key(0), sampling)[:, None]
    out, trace = [tok], [logp]
    for i in range(num_tokens - 1):
        logits_k = []
        for j, p in enumerate(member_list):
            logits, caches[j] = model.decode_step(cfg, p, caches[j], tok)
            logits_k.append(logits[:, -1])
        logp = mixture_logprobs(jnp.stack(logits_k), mode)
        tok = select_tokens(logp, step_key(i + 1), sampling)[:, None]
        out.append(tok)
        trace.append(logp)
    seq = jnp.concatenate(out, axis=1)
    if eos_id is not None:
        seq = mask_after_eos(seq, eos_id, pad_id)
    return seq, jnp.stack(trace)
