"""Production meshes.

``make_production_mesh`` is the contract mesh for the dry-run: a 16x16
single-pod (256 chips, TPU v5e) or 2x16x16 multi-pod (512 chips) device
grid.  ``make_train_mesh`` derives the EC-SGHMC training mesh from the same
device set by carving a ``chain`` axis out of the data axis (single-pod) —
multi-pod keeps the ``pod`` axis, and chains map onto (pod, chain): the
cross-pod link only carries the s-periodic elastic-coupling exchange, which
is the paper's deployment story.

Everything here is a FUNCTION (no module-level jax device state) so imports
never lock the device count before dryrun.py sets XLA_FLAGS.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, size: int = 16):
    shape = (2, size, size) if multi_pod else (size, size)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_train_mesh(num_chains: int = 1, *, multi_pod: bool = False, size: int = 16,
                    tp: int | None = None):
    """Same devices as the production mesh, with a chain axis of size
    ``num_chains`` factored out of the per-pod data axis.

    ``tp`` re-balances the TP:DP ratio within the fixed chip count (the
    §Perf lever for activation-allreduce-bound cells): the per-pod grid is
    (chain, (size*size)/(chain*tp), tp) instead of (chain, size/chain, size).
    """
    chips = size * size
    tp = size if tp is None else tp
    assert chips % (num_chains * tp) == 0, (num_chains, tp)
    data = chips // (num_chains * tp)
    if multi_pod:
        return jax.make_mesh((2, num_chains, data, tp), ("pod", "chain", "data", "model"))
    return jax.make_mesh((num_chains, data, tp), ("chain", "data", "model"))


def make_serve_mesh(*, multi_pod: bool = False, size: int = 16, tp: int | None = None):
    """Production-mesh devices with a re-balanced (data, model) split for
    serving hillclimbs; tp=None returns the contract production mesh."""
    if tp is None:
        return make_production_mesh(multi_pod=multi_pod, size=size)
    chips = size * size
    assert chips % tp == 0
    shape = (2, chips // tp, tp) if multi_pod else (chips // tp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def total_chains(mesh, num_chains: int) -> int:
    """Total K across pods (multi-pod meshes double the chain count)."""
    return num_chains * mesh.shape.get("pod", 1)


HARDWARE = {
    # TPU v5e per-chip constants used by the roofline analysis
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link
}
