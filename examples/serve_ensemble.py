"""Serving example: batched greedy decoding with a chain-ensemble —
averaging the predictive distribution over K posterior samples (the reason
one runs EC-SGHMC in the first place: Bayesian model averaging at serve
time).

    PYTHONPATH=src python examples/serve_ensemble.py
"""
from repro.launch.serve import main as serve_main


def main():
    print("== single model ==")
    serve_main(["--arch", "qwen3-0.6b", "--smoke", "--batch", "4",
                "--prompt-len", "16", "--gen", "8"])
    print("== 3-sample posterior ensemble ==")
    serve_main(["--arch", "qwen3-0.6b", "--smoke", "--batch", "4",
                "--prompt-len", "16", "--gen", "8", "--ensemble", "3"])


if __name__ == "__main__":
    main()
