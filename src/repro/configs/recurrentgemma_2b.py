"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    vocab_size=256000,
    d_model=2560,
    num_layers=26,  # 8 full (rec,rec,attn) periods + 2 remainder rec blocks
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    pattern=(LayerKind("rglru"), LayerKind("rglru"), LayerKind("attn", window=2048)),
    norm_scale_offset=1.0,
    act="gelu",
    rnn_width=2560,
    rglru_conv_width=4,
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale="sqrt_d",
    param_dtype=jnp.float32,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=5,  # 1 period + 2 remainder
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    pattern=(LayerKind("rglru"), LayerKind("rglru"), LayerKind("attn", window=8)),
    rnn_width=64,
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
