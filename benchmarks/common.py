"""Shared benchmark utilities."""
from __future__ import annotations

import os
import time

import jax
import numpy as np

QUICK = os.environ.get("REPRO_BENCH_QUICK", "1") == "1"


def time_fn(fn, *args, iters: int = 20, warmup: int = 3):
    """Median wall time per call in microseconds (blocking)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return 1e6 * float(np.median(ts))


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
