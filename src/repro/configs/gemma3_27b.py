"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt scaled per pool; unverified]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

_LOCAL = LayerKind("attn", window=1024)
_GLOBAL = LayerKind("attn", window=None)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    vocab_size=262144,
    d_model=5376,
    num_layers=62,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),  # 5:1
    norm_scale_offset=1.0,
    sandwich_norm=True,
    act="gelu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    embed_scale="sqrt_d",
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=6,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    pattern=(LayerKind("attn", window=8),) * 5 + (LayerKind("attn"),),
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
