"""Exact-distribution stationary battery: every sampler's empirical
moments on a Gaussian target are gated against the CLOSED-FORM oracle for
the discrete-time recursion (repro.diagnostics.oracle) — not against the
continuum limit, so there is no discretization slack to hide behind.

Tolerances are pure Monte-Carlo: 3σ bands sized from the empirical ESS,
computed CONSERVATIVELY on the chain-mean series (treating the K coupled
chains as fully correlated), plus a safety floor.  Every config uses a
fixed seed, so failures are deterministic, and a failure means the sampler
does not draw from the distribution the math says it draws from.

This file is the acceptance gate future perf/sharding PRs run against:
change the update rule, and the oracle will notice.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro import diagnostics as diag
from repro.run import rollout

MU = 1.5  # per-dimension target mean (non-zero to catch mean bugs)
LAM = 1.0  # target precision: U = (lam/2)||theta - mu||^2
D = 2  # parameter dimensions (iid under the isotropic target)


def run_chains(sampler, shape, steps, burn, seed=0):
    """Drive a sampler with exact gradients through the device-resident
    executor (``repro.run.rollout`` — the same chunked-scan program every
    production driver uses); return (K, T, D) trajectory (K=1 axis inserted
    for unstacked samplers).  Moments are ALSO streamed through the Welford
    accumulator riding the scan carry and cross-checked, so the battery
    exercises the in-carry diagnostics path every run.  Gradients are
    evaluated at ``Sampler.grad_targets`` (stale worker snapshots for the
    approach-I baseline), which the battery's old hand-rolled scan got
    wrong — it could not have gated ``async_sghmc`` at all."""
    params0 = jnp.full(shape, MU + 1.0, jnp.float32)  # off-target start
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    res = rollout(
        sampler, lambda th: LAM * (th - MU), params0,
        num_steps=steps, keys=keys, moments=True, chunk_steps=8192,
    )
    wf = res.moments
    traj = np.asarray(res.trace)  # (steps, *shape)

    # Welford over the full run must equal the trajectory moments exactly
    # (the scan-streaming path is what big runs use instead of a trajectory).
    np.testing.assert_allclose(
        np.asarray(diag.welford_mean(wf)), traj.mean(axis=0), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(diag.welford_var(wf)), traj.var(axis=0), rtol=2e-3, atol=2e-4
    )

    traj = traj[burn:]
    if traj.ndim == 2:  # (T, D) -> (1, T, D)
        return traj[None]
    return np.moveaxis(traj, 1, 0)  # (T, K, D) -> (K, T, D)


def conservative_ess(traj):
    """Conservative coupled-chain ESS (chain-mean series), summed over
    dims — treats the K chains as fully correlated, which lower-bounds the
    information and therefore widens the tolerance bands."""
    return float(np.sum(diag.coupled_ess_nd(traj)))


def assert_matches_oracle(traj, oracle, *, check_cross=False, label=""):
    emp_mean, emp_var = diag.pooled_moments(traj)  # (D,), (D,)
    ess = conservative_ess(traj)

    mean_tol = 3.0 * np.sqrt(oracle.theta_var / ess) + 1e-4
    assert abs(emp_mean.mean() - oracle.theta_mean) < mean_tol, (
        f"{label}: mean {emp_mean.mean():.5f} vs oracle {oracle.theta_mean} "
        f"(tol {mean_tol:.5f}, ess {ess:.0f})"
    )

    var_tol = diag.monte_carlo_tolerance(oracle.theta_var, ess) + 1e-6
    assert abs(emp_var.mean() - oracle.theta_var) < var_tol, (
        f"{label}: var {emp_var.mean():.6f} vs oracle {oracle.theta_var:.6f} "
        f"(tol {var_tol:.6f}, ess {ess:.0f})"
    )

    if check_cross and traj.shape[0] > 1:
        k = traj.shape[0]
        pairs = [
            np.mean((traj[i] - emp_mean) * (traj[j] - emp_mean))
            for i in range(k)
            for j in range(i + 1, k)
        ]
        emp_cross = float(np.mean(pairs))
        cross_tol = 3.0 * np.sqrt(
            (oracle.theta_var**2 + oracle.theta_cross_cov**2) / max(ess, 4.0)
        ) + 1e-6
        assert abs(emp_cross - oracle.theta_cross_cov) < cross_tol, (
            f"{label}: cross-cov {emp_cross:.6f} vs oracle {oracle.theta_cross_cov:.6f} "
            f"(tol {cross_tol:.6f})"
        )

    # convergence hygiene: the battery's own split-R̂ must be clean
    rhat = float(np.max(diag.split_rhat_nd(traj)))
    assert rhat < 1.05, f"{label}: split-Rhat {rhat:.3f} — trajectory not stationary"


class TestSGHMCStationary:
    def test_eq4(self):
        s = core.sghmc(step_size=0.1, friction=1.0)
        traj = run_chains(s, (4, D), steps=30_000, burn=2_000)
        oracle = diag.sghmc_stationary(
            step_size=0.1, friction=1.0, noise_convention="eq4", precision=LAM, mu=MU
        )
        assert_matches_oracle(traj, oracle, label="sghmc-eq4")

    def test_eq6(self):
        s = core.sghmc(step_size=0.1, friction=1.5, noise_convention="eq6")
        traj = run_chains(s, (4, D), steps=30_000, burn=2_000, seed=1)
        oracle = diag.sghmc_stationary(
            step_size=0.1, friction=1.5, noise_convention="eq6", precision=LAM, mu=MU
        )
        assert_matches_oracle(traj, oracle, label="sghmc-eq6")

    @pytest.mark.slow
    def test_cold_temperature(self):
        s = core.sghmc(step_size=0.1, friction=1.0, temperature=0.25)
        traj = run_chains(s, (4, D), steps=40_000, burn=2_000, seed=2)
        oracle = diag.sghmc_stationary(
            step_size=0.1, friction=1.0, temperature=0.25, precision=LAM, mu=MU
        )
        assert_matches_oracle(traj, oracle, label="sghmc-cold")


class TestSGLDStationary:
    def test_default(self):
        s = core.sgld(step_size=0.1)
        traj = run_chains(s, (4, D), steps=30_000, burn=2_000)
        oracle = diag.sgld_stationary(step_size=0.1, precision=LAM, mu=MU)
        assert_matches_oracle(traj, oracle, label="sgld")


class TestAsyncSGHMCStationary:
    """The paper's naive approach-I baseline, gated against the exact
    delay-augmented oracle: a worker arriving at step t pushes the gradient
    of the snapshot it pulled s steps earlier, so the server recursion has
    a pure feedback lag whose stationary variance the oracle solves in
    closed form.  s=1 is synchronous-parallel SGHMC; larger s inflates the
    variance — the degradation EC-SGHMC is designed to avoid."""

    @pytest.mark.parametrize("s", [1, 4])
    def test_oracle(self, s):
        sampler = core.async_sghmc(
            step_size=0.1, num_workers=4, friction=1.0, sync_every=s
        )
        traj = run_chains(sampler, (D,), steps=40_000, burn=4_000, seed=3 + s)
        oracle = diag.async_sghmc_stationary(
            step_size=0.1, friction=1.0, sync_every=s, precision=LAM, mu=MU
        )
        assert_matches_oracle(traj, oracle, label=f"async-s{s}")

    def test_s1_is_synchronous_sghmc(self):
        """With s=1 every worker reports every step at the current params:
        the oracle must coincide with plain SGHMC exactly."""
        o_async = diag.async_sghmc_stationary(step_size=0.1, friction=1.0,
                                              sync_every=1, precision=LAM, mu=MU)
        o_sg = diag.sghmc_stationary(step_size=0.1, friction=1.0,
                                     noise_convention="eq4", precision=LAM, mu=MU)
        assert o_async.theta_var == pytest.approx(o_sg.theta_var, rel=1e-12)

    def test_staleness_inflates_variance(self):
        """§2 of the paper, quantified: the oracle's θ-variance must grow
        monotonically with the staleness period."""
        vs = [
            diag.async_sghmc_stationary(step_size=0.1, friction=1.0, sync_every=s,
                                        precision=LAM, mu=MU).theta_var
            for s in (1, 2, 4, 8)
        ]
        assert vs == sorted(vs) and vs[-1] > 1.2 * vs[0], vs


# the acceptance grid: alpha in {0, 1} x sync_every in {1, 8}; eq6 noise,
# center staleness noise excluded so alpha=0 is EXACTLY independent SGHMC
EC_KW = dict(friction=1.0, center_friction=1.0, noise_convention="eq6",
             center_noise_in_p=False)
K = 4


def _ec_case(alpha, s, *, fused=False, steps=40_000, seed=None):
    eps = 0.1
    sampler = core.ec_sghmc(step_size=eps, alpha=alpha, sync_every=s, fused=fused, **EC_KW)
    seed = seed if seed is not None else int(17 * alpha + s + 100 * fused)
    traj = run_chains(sampler, (K, D), steps=steps, burn=4_000, seed=seed)
    oracle = diag.ec_sghmc_stationary(
        step_size=eps, alpha=alpha, num_chains=K, sync_every=s, precision=LAM, mu=MU,
        **EC_KW,
    )
    return traj, oracle


class TestECSGHMCStationary:
    @pytest.mark.parametrize("s", [1, 8])
    def test_alpha0_recovers_independent_sghmc(self, s):
        """Acceptance criterion: alpha=0 must reproduce independent-SGHMC
        moments — both in the oracle (exact identity) and empirically."""
        traj, oracle = _ec_case(0.0, s)
        sg = diag.sghmc_stationary(
            step_size=0.1, friction=1.0, noise_convention="eq6", precision=LAM, mu=MU
        )
        assert oracle.theta_var == pytest.approx(sg.theta_var, rel=1e-12)
        assert_matches_oracle(traj, oracle, label=f"ec-a0-s{s}")

    @pytest.mark.parametrize("s", [1, 8])
    def test_alpha1(self, s):
        traj, oracle = _ec_case(1.0, s)
        assert_matches_oracle(traj, oracle, check_cross=True, label=f"ec-a1-s{s}")

    @pytest.mark.slow
    def test_alpha1_s4(self):
        traj, oracle = _ec_case(1.0, 4)
        assert_matches_oracle(traj, oracle, check_cross=True, label="ec-a1-s4")

    @pytest.mark.slow
    def test_eq4_convention(self):
        """The staleness-sweep configuration (eq4 noise, weaker coupling)."""
        kw = dict(friction=1.0, center_friction=1.0, noise_convention="eq4",
                  center_noise_in_p=False)
        sampler = core.ec_sghmc(step_size=0.1, alpha=0.5, sync_every=4, **kw)
        traj = run_chains(sampler, (K, D), steps=40_000, burn=4_000, seed=7)
        oracle = diag.ec_sghmc_stationary(
            step_size=0.1, alpha=0.5, num_chains=K, sync_every=4, precision=LAM, mu=MU, **kw
        )
        assert_matches_oracle(traj, oracle, check_cross=True, label="ec-eq4")

    @pytest.mark.slow
    def test_phase_resolved_variance(self):
        """The cyclostationary fingerprint: variance ramps between syncs and
        snaps back at the exchange — phase-resolved match against the
        oracle's per-phase solution."""
        s = 8
        traj, oracle = _ec_case(1.0, s, steps=80_000, seed=11)
        t = traj.shape[1]
        t = t - t % s
        ess_phase = conservative_ess(traj) / s
        # trajectory index i holds theta_{burn+i+1}; phase = (burn+i+1) % s
        burn = 4_000
        for phase in range(s):
            offset = (phase - burn - 1) % s
            sel = traj[:, offset:t:s, :]
            emp = float(sel.var())
            want = float(oracle.phase_theta_vars[phase])
            tol = diag.monte_carlo_tolerance(want, ess_phase) + 1e-6
            assert abs(emp - want) < tol, (
                f"phase {phase}: var {emp:.6f} vs oracle {want:.6f} (tol {tol:.6f})"
            )
        assert np.ptp(oracle.phase_theta_vars) > 3 * 1e-4  # the ramp is resolvable


class TestFusedECSGHMCStationary:
    """Same dynamics through the Pallas kernel (interpret mode on CPU):
    Box-Muller counter noise + fused update must hit the same oracle."""

    def test_alpha1_s1_fused(self):
        traj, oracle = _ec_case(1.0, 1, fused=True, steps=30_000)
        assert_matches_oracle(traj, oracle, check_cross=True, label="ec-fused-a1-s1")

    @pytest.mark.slow
    def test_alpha1_s8_fused(self):
        traj, oracle = _ec_case(1.0, 8, fused=True, steps=30_000)
        assert_matches_oracle(traj, oracle, check_cross=True, label="ec-fused-a1-s8")

    @pytest.mark.slow
    def test_alpha0_s1_fused_matches_sghmc_oracle(self):
        traj, oracle = _ec_case(0.0, 1, fused=True, steps=30_000)
        assert_matches_oracle(traj, oracle, label="ec-fused-a0-s1")


class TestResampleChainFromCenter:
    """Satellite: the elastic-K chain-recovery path draws from the
    stationary conditional theta^i | c ~ N(c, (K/alpha) I)."""

    def test_moments_and_shapes(self):
        alpha, k_new = 2.0, 6
        ec = core.ec_sghmc(step_size=1e-2, alpha=alpha)
        params = jax.random.normal(jax.random.PRNGKey(0), (4, 2000))
        st = ec.init(params)
        new_params, new_state = core.resample_chain_from_center(
            st, alpha=alpha, rng=jax.random.PRNGKey(1), num_chains=k_new
        )
        draws = np.asarray(new_params)  # (k_new, 2000)
        center = np.asarray(st.center)

        assert draws.shape == (k_new, 2000)
        var_target = k_new / alpha
        n = draws.size
        # per-coordinate mean of the k_new draws: E|err| = sqrt(2 var / (pi k))
        mean_err = np.abs(draws.mean(axis=0) - center).mean()
        assert mean_err < 2.0 * np.sqrt(var_target / k_new)
        centered = draws - center[None]
        assert abs(centered.mean()) < 4 * np.sqrt(var_target / n)
        # variance K/alpha: 3σ band for a chi^2 with n dof
        assert abs(centered.var() - var_target) < 3 * var_target * np.sqrt(2 / n)

    def test_state_shape_consistency(self):
        """Returned state must be consistent with the NEW chain count while
        keeping center buffers at their (chain-free) shapes."""
        ec = core.ec_sghmc(step_size=1e-2, alpha=1.0)
        params = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
        st = ec.init(params)
        for k_new in (4, 6, 2):
            new_params, new_state = core.resample_chain_from_center(
                st, alpha=1.0, rng=jax.random.PRNGKey(3), num_chains=k_new
            )
            assert new_params.shape == (k_new, 8)
            assert new_state.momentum.shape == (k_new, 8)
            assert new_state.center.shape == (8,)
            assert new_state.center_stale.shape == (8,)
            assert new_state.mean_theta_stale.shape == (8,)
            np.testing.assert_allclose(
                np.asarray(new_state.mean_theta_stale),
                np.asarray(new_params).mean(0),
                atol=1e-5,
            )
            # fresh chains start with zero momentum
            assert float(jnp.abs(new_state.momentum).max()) == 0.0
