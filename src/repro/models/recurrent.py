"""Recurrent temporal-mixing blocks: RG-LRU (RecurrentGemma/Griffin) and
mLSTM / sLSTM (xLSTM).

Training uses parallel forms where they exist (associative scan for RG-LRU,
stabilized quadratic parallel form for mLSTM) and lax.scan for sLSTM (true
memory-mixing recurrence).  Decode is O(1)/token via explicit recurrent
state — which is why these families run the long_500k cell.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec

# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block: conv + gated linear recurrence)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_specs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    R = cfg.rnn_width or cfg.d_model
    W = cfg.rglru_conv_width
    pd = cfg.param_dtype
    return {
        "w_x": ParamSpec((D, R), ("embed", "rnn"), dtype=pd),  # recurrent branch in
        "w_gate_branch": ParamSpec((D, R), ("embed", "rnn"), dtype=pd),
        "conv_w": ParamSpec((W, R), (None, "rnn"), scale=0.1, dtype=pd),
        "conv_b": ParamSpec((R,), ("rnn",), init="zeros", dtype=pd),
        "w_a": ParamSpec((R, R), ("rnn", None), dtype=pd),  # recurrence gate
        "b_a": ParamSpec((R,), ("rnn",), init="zeros", dtype=pd),
        "w_i": ParamSpec((R, R), ("rnn", None), dtype=pd),  # input gate
        "b_i": ParamSpec((R,), ("rnn",), init="zeros", dtype=pd),
        "lam": ParamSpec((R,), ("rnn",), init="lru_lambda", dtype=jnp.float32),
        "w_out": ParamSpec((R, D), ("rnn", "embed"), dtype=pd),
    }


def _rglru_gates(p, u):
    """u: (..., R) conv output. Returns (a, gated_input) in f32."""
    r_gate = jax.nn.sigmoid(u @ p["w_a"].astype(u.dtype) + p["b_a"].astype(u.dtype))
    i_gate = jax.nn.sigmoid(u @ p["w_i"].astype(u.dtype) + p["b_i"].astype(u.dtype))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r_gate.astype(jnp.float32)
    a = jnp.exp(log_a)
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    x_in = scale * (i_gate.astype(jnp.float32) * u.astype(jnp.float32))
    return a, x_in


def rglru_block(cfg: ModelConfig, p, x):
    """Training/prefill: x (B,S,D) -> (B,S,D) via associative scan."""
    cd = cfg.compute_dtype
    B, S, D = x.shape
    gate = jax.nn.gelu(x.astype(cd) @ p["w_gate_branch"].astype(cd))
    u = x.astype(cd) @ p["w_x"].astype(cd)  # (B,S,R)
    W = p["conv_w"].shape[0]  # causal depthwise conv, width W
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    u = sum(
        pad[:, i : i + S, :] * p["conv_w"][i].astype(cd) for i in range(W)
    ) + p["conv_b"].astype(cd)
    a, x_in = _rglru_gates(p, u)  # f32 (B,S,R)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    out = (gate * h.astype(cd)) @ p["w_out"].astype(cd)
    return out


def rglru_init_state(cfg: ModelConfig, batch: int, dtype):
    R = cfg.rnn_width or cfg.d_model
    W = cfg.rglru_conv_width
    return {
        "h": jnp.zeros((batch, R), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, R), dtype),
    }


def rglru_state_specs(cfg: ModelConfig, batch: int, dtype):
    R = cfg.rnn_width or cfg.d_model
    W = cfg.rglru_conv_width
    return {
        "h": jax.ShapeDtypeStruct((batch, R), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, W - 1, R), dtype),
    }


def rglru_decode(cfg: ModelConfig, p, x, state):
    """x: (B,1,D); O(1) recurrent step."""
    cd = cfg.compute_dtype
    xt = x[:, 0].astype(cd)
    gate = jax.nn.gelu(xt @ p["w_gate_branch"].astype(cd))
    u = xt @ p["w_x"].astype(cd)  # (B,R)
    hist = jnp.concatenate([state["conv"].astype(cd), u[:, None]], axis=1)  # (B,W,R)
    u = jnp.einsum("bwr,wr->br", hist, p["conv_w"].astype(cd)) + p["conv_b"].astype(cd)
    a, x_in = _rglru_gates(p, u)
    h = a * state["h"] + x_in  # f32
    out = (gate * h.astype(cd)) @ p["w_out"].astype(cd)
    return out[:, None], {"h": h, "conv": hist[:, 1:].astype(state["conv"].dtype)}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block, parallel stabilized form)
#
# The block operates in the up-projected space: up = 2*d_model split into
# cfg.num_heads heads of dh_in = up // num_heads each.
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: ModelConfig):
    up = 2 * cfg.d_model
    NH = cfg.num_heads
    return up, NH, up // NH


def mlstm_specs(cfg: ModelConfig) -> dict:
    D, pd = cfg.d_model, cfg.param_dtype
    up, NH, dh = _mlstm_dims(cfg)
    return {
        "w_up1": ParamSpec((D, up), ("embed", "mlp"), dtype=pd),  # mixer path
        "w_up2": ParamSpec((D, up), ("embed", "mlp"), dtype=pd),  # gate path
        "conv_w": ParamSpec((4, up), (None, "mlp"), scale=0.1, dtype=pd),
        "conv_b": ParamSpec((up,), ("mlp",), init="zeros", dtype=pd),
        "wq": ParamSpec((up, NH, dh), ("mlp", "heads", None), dtype=pd),
        "wk": ParamSpec((up, NH, dh), ("mlp", "heads", None), dtype=pd),
        "wv": ParamSpec((up, NH, dh), ("mlp", "heads", None), dtype=pd),
        "w_igate": ParamSpec((up, NH), ("mlp", "heads"), scale=0.01, dtype=pd),
        "b_igate": ParamSpec((NH,), ("heads",), init="zeros", dtype=pd),
        "w_fgate": ParamSpec((up, NH), ("mlp", "heads"), scale=0.01, dtype=pd),
        "b_fgate": ParamSpec((NH,), ("heads",), init="ones", dtype=pd),
        "w_down": ParamSpec((up, D), ("mlp", "embed"), dtype=pd),
    }


def mlstm_block(cfg: ModelConfig, p, x):
    """Parallel stabilized mLSTM: O(S^2) train form (decode is O(1))."""
    cd = cfg.compute_dtype
    B, S, D = x.shape
    up, NH, dh = _mlstm_dims(cfg)
    u1 = x.astype(cd) @ p["w_up1"].astype(cd)  # (B,S,up) mixer path
    u2 = jax.nn.silu(x.astype(cd) @ p["w_up2"].astype(cd))  # gate path
    W = p["conv_w"].shape[0]
    pad = jnp.pad(u1, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(pad[:, i : i + S, :] * p["conv_w"][i].astype(cd) for i in range(W))
    conv = jax.nn.silu(conv + p["conv_b"].astype(cd))
    q = jnp.einsum("bsu,uhk->bshk", conv, p["wq"].astype(cd))
    k = jnp.einsum("bsu,uhk->bshk", conv, p["wk"].astype(cd))
    v = jnp.einsum("bsu,uhk->bshk", u1, p["wv"].astype(cd))
    f32 = jnp.float32
    igate = jnp.einsum("bsu,uh->bsh", conv.astype(f32), p["w_igate"].astype(f32)) + p["b_igate"]
    fgate = jnp.einsum("bsu,uh->bsh", conv.astype(f32), p["w_fgate"].astype(f32)) + p["b_fgate"]

    logf = jax.nn.log_sigmoid(fgate)  # (B,S,NH)
    F = jnp.cumsum(logf, axis=1)
    # D_ts = F_t - F_s + i_s for s <= t
    dmat = F[:, :, None, :] - F[:, None, :, :] + igate[:, None, :, :]  # (B,t,s,NH)
    causal = jnp.tril(jnp.ones((S, S), bool))
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)  # (B,t,1,NH) stabilizer
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bthk,bshk->btsh", q.astype(f32), k.astype(f32))
    scores = scores / math.sqrt(dh) * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2)), jnp.exp(-m[:, :, 0]))  # (B,t,NH)
    h = jnp.einsum("btsh,bshk->bthk", scores, v.astype(f32)) / norm[..., None]
    h = h.reshape(B, S, up).astype(cd)
    return (h * u2) @ p["w_down"].astype(cd)


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype):
    up, NH, dh = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, NH, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, NH, dh), jnp.float32),
        "m": jnp.full((batch, NH), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, up), dtype),
    }


def mlstm_state_specs(cfg: ModelConfig, batch: int, dtype):
    up, NH, dh = _mlstm_dims(cfg)
    return {
        "C": jax.ShapeDtypeStruct((batch, NH, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, NH, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, NH), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, 3, up), dtype),
    }


def mlstm_decode(cfg: ModelConfig, p, x, state):
    cd = cfg.compute_dtype
    B = x.shape[0]
    up, NH, dh = _mlstm_dims(cfg)
    f32 = jnp.float32
    xt = x[:, 0].astype(cd)
    u1 = xt @ p["w_up1"].astype(cd)
    u2 = jax.nn.silu(xt @ p["w_up2"].astype(cd))
    hist = jnp.concatenate([state["conv"].astype(cd), u1[:, None]], axis=1)  # (B,4,up)
    conv = jax.nn.silu(
        jnp.einsum("bwu,wu->bu", hist, p["conv_w"].astype(cd)) + p["conv_b"].astype(cd)
    )
    q = jnp.einsum("bu,uhk->bhk", conv, p["wq"].astype(cd)).astype(f32)
    k = jnp.einsum("bu,uhk->bhk", conv, p["wk"].astype(cd)).astype(f32)
    v = jnp.einsum("bu,uhk->bhk", u1, p["wv"].astype(cd)).astype(f32)
    ig = jnp.einsum("bu,uh->bh", conv.astype(f32), p["w_igate"].astype(f32)) + p["b_igate"]
    fg = jnp.einsum("bu,uh->bh", conv.astype(f32), p["w_fgate"].astype(f32)) + p["b_fgate"]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + state["m"], ig)  # (B,NH)
    f_p = jnp.exp(logf + state["m"] - m_new)
    i_p = jnp.exp(ig - m_new)
    k_s = k / math.sqrt(dh)
    C = f_p[..., None, None] * state["C"] + i_p[..., None, None] * (
        v[..., :, None] * k_s[..., None, :]
    )
    n = f_p[..., None] * state["n"] + i_p[..., None] * k_s
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, up).astype(cd)
    out = (h * u2) @ p["w_down"].astype(cd)
    new_state = {"C": C, "n": n, "m": m_new, "conv": hist[:, 1:].astype(state["conv"].dtype)}
    return out[:, None], new_state


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, exponential gating, head-wise memory mixing)
#
# Heads operate on d_model (NH * head_dim == d_model); the block appends a
# gated FFN (pf = 4/3) as in the official xLSTM block.
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ModelConfig) -> dict:
    D, NH, dh, pd = cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.param_dtype
    assert NH * dh == D, "sLSTM requires num_heads * head_dim == d_model"
    ff = int(D * 4 / 3)
    return {
        "w_in": ParamSpec((4, D, NH, dh), (None, "embed", "heads", None), dtype=pd),
        "r": ParamSpec((4, NH, dh, dh), (None, "heads", None, None), scale=0.01, dtype=pd),
        "b": ParamSpec((4, NH, dh), (None, "heads", None), init="zeros", dtype=pd),
        "w_group_norm": ParamSpec((D,), ("embed",), init="ones", dtype=pd),
        "ff_gate": ParamSpec((D, ff), ("embed", "mlp"), dtype=pd),
        "ff_up": ParamSpec((D, ff), ("embed", "mlp"), dtype=pd),
        "ff_down": ParamSpec((ff, D), ("mlp", "embed"), dtype=pd),
    }


def _slstm_cell(p, xt, state):
    """xt: (B, D) f32; state: dict(h, c, n, m) each (B, NH, dh)."""
    h_prev, c_prev, n_prev, m_prev = state["h"], state["c"], state["n"], state["m"]
    wx = jnp.einsum("bd,gdhk->gbhk", xt, p["w_in"].astype(jnp.float32))
    rh = jnp.einsum("bhk,ghkl->gbhl", h_prev, p["r"].astype(jnp.float32))
    z, i, f, o = [wx[g] + rh[g] + p["b"][g].astype(jnp.float32) for g in range(4)]
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    logf = jax.nn.log_sigmoid(f)
    m = jnp.maximum(logf + m_prev, i)
    i_p = jnp.exp(i - m)
    f_p = jnp.exp(logf + m_prev - m)
    c = f_p * c_prev + i_p * z
    n = f_p * n_prev + i_p
    h = o * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m}


def _slstm_out(cfg: ModelConfig, p, hs):
    """Group-norm + gated FFN applied to the mixed sequence output."""
    from .layers import rms_norm  # local import avoids cycle

    cd = cfg.compute_dtype
    hs = rms_norm(hs.astype(cd), p["w_group_norm"], cfg.norm_eps)
    f = jax.nn.gelu(hs @ p["ff_gate"].astype(cd)) * (hs @ p["ff_up"].astype(cd))
    return f @ p["ff_down"].astype(cd)


def slstm_block(cfg: ModelConfig, p, x):
    """x: (B,S,D). lax.scan over time (memory mixing is inherently serial)."""
    B, S, D = x.shape
    NH, dh = cfg.num_heads, cfg.head_dim
    state0 = slstm_init_state(cfg, B, x.dtype)

    def step(state, xt):
        new = _slstm_cell(p, xt, state)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(x.astype(jnp.float32), 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, D)
    return _slstm_out(cfg, p, hs)


def slstm_init_state(cfg: ModelConfig, batch: int, dtype):
    NH, dh = cfg.num_heads, cfg.head_dim
    z = lambda: jnp.zeros((batch, NH, dh), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": jnp.full((batch, NH, dh), -1e30, jnp.float32)}


def slstm_state_specs(cfg: ModelConfig, batch: int, dtype):
    NH, dh = cfg.num_heads, cfg.head_dim
    sds = lambda: jax.ShapeDtypeStruct((batch, NH, dh), jnp.float32)
    return {"h": sds(), "c": sds(), "n": sds(), "m": sds()}


def slstm_decode(cfg: ModelConfig, p, x, state):
    B = x.shape[0]
    new = _slstm_cell(p, x[:, 0].astype(jnp.float32), state)
    hs = new["h"].reshape(B, 1, cfg.d_model)
    return _slstm_out(cfg, p, hs), new
