"""The Ma et al. (2015) "complete recipe" for SG-MCMC — the theory layer.

Any diffusion of the form

    dz = f(z) dt + sqrt(2 D(z)) dW_t,
    f(z) = -(D(z) + Q(z)) ∇H(z) + Γ(z),     Γ_i = Σ_j ∂/∂z_j (D_ij + Q_ij)

with D ⪰ 0 and Q skew-symmetric has exp(-H(z)) as its stationary
distribution.  This module provides a dense-matrix simulator for
low-dimensional z used (a) by the toy experiments and (b) by tests that
verify SGHMC (Eq. 4) and EC-SGHMC (Eq. 6) are instances of the recipe with
the D/Q matrices claimed in the paper (§1.1.1 and Prop. 3.1).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Recipe(NamedTuple):
    grad_H: Callable  # (z) -> ∇H(z), shape (m,)
    D: jnp.ndarray  # (m, m) PSD
    Q: jnp.ndarray  # (m, m) skew-symmetric


def validate(recipe: Recipe, atol: float = 1e-6) -> None:
    D, Q = recipe.D, recipe.Q
    if not bool(jnp.allclose(Q, -Q.T, atol=atol)):
        raise ValueError("Q must be skew-symmetric")
    eig = jnp.linalg.eigvalsh(0.5 * (D + D.T))
    if not bool(jnp.all(eig >= -atol)):
        raise ValueError("D must be PSD")


def step(recipe: Recipe, z, eps, rng):
    """One Euler–Maruyama step of Eq. (3) (constant D, Q ⇒ Γ = 0)."""
    drift = -(recipe.D + recipe.Q) @ recipe.grad_H(z)
    noise = jax.random.normal(rng, z.shape, jnp.float32)
    # N(0, 2 eps D): D PSD; use matrix sqrt via cholesky of (D + jitter)
    m = recipe.D.shape[0]
    chol = jnp.linalg.cholesky(recipe.D + 1e-12 * jnp.eye(m))
    return z + eps * drift + jnp.sqrt(2.0 * eps) * (chol @ noise)


def simulate(recipe: Recipe, z0, eps, num_steps: int, rng):
    """Full trajectory, scan-compiled. Returns (num_steps, m)."""

    def body(z, key):
        z1 = step(recipe, z, eps, key)
        return z1, z1

    keys = jax.random.split(rng, num_steps)
    _, traj = jax.lax.scan(body, z0, keys)
    return traj


def sghmc_recipe(grad_U: Callable, dim: int, friction: float = 1.0, mass: float = 1.0) -> Recipe:
    """Eq. (4) as a recipe instance: z = [θ, p],
    H = U(θ) + pᵀM⁻¹p/2·2 (paper's g = pᵀM⁻¹p), D = diag([0, V]),
    Q = [[0, I], [-I, 0]] (the paper prints a V in Q's corner; the dynamics
    it derives correspond to this canonical symplectic Q)."""
    I = jnp.eye(dim)
    Z = jnp.zeros((dim, dim))
    D = jnp.block([[Z, Z], [Z, friction * I]])
    Q = jnp.block([[Z, -I], [I, Z]])

    def grad_H(z):
        theta, p = z[:dim], z[dim:]
        return jnp.concatenate([grad_U(theta), p / mass])

    return Recipe(grad_H, D, Q)


def ec_sghmc_recipe(
    grad_U: Callable,
    dim: int,
    num_chains: int,
    alpha: float = 1.0,
    friction: float = 1.0,
    center_friction: float = 1.0,
    mass: float = 1.0,
) -> Recipe:
    """Prop. 3.1: z = [θ¹..θᴷ, c, p¹..pᴷ, r] with
    H(z) = Σ U(θⁱ) + Σ pⁱᵀM⁻¹pⁱ + (1/K)Σ (α/2)‖θⁱ−c‖² + rᵀM⁻¹r,
    D = diag([0, V·I_K, 0, C]), Q = canonical symplectic block."""
    K, d = num_chains, dim
    m = (K + 1) * d  # positions; same count of momenta
    Zm = jnp.zeros((m, m))
    Dpos = Zm
    Dmom = jnp.block(
        [
            [friction * jnp.eye(K * d), jnp.zeros((K * d, d))],
            [jnp.zeros((d, K * d)), center_friction * jnp.eye(d)],
        ]
    )
    D = jnp.block([[Dpos, Zm], [Zm, Dmom]])
    Q = jnp.block([[Zm, -jnp.eye(m)], [jnp.eye(m), Zm]])

    def grad_H(z):
        pos, mom = z[:m], z[m:]
        thetas = pos[: K * d].reshape(K, d)
        c = pos[K * d :]
        dU = jax.vmap(grad_U)(thetas)  # (K, d)
        d_theta = dU + (alpha / K) * (thetas - c[None])
        d_c = (alpha / K) * jnp.sum(c[None] - thetas, axis=0)
        return jnp.concatenate([d_theta.reshape(-1), d_c, mom / mass])

    return Recipe(grad_H, D, Q)
