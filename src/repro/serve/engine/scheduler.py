"""Request model + admission bookkeeping for the continuous-batching engine.

The scheduler's clock is the DECODE STEP: one tick = one execution of the
engine's single compiled decode program over the fixed slot axis.  Requests
carry an ``arrival_step`` on that clock (synthetic traces; a network server
would map wall-clock arrivals onto ticks the same way).  Admission policy is
plain FCFS: at every tick, pending requests whose arrival has passed are
prefilled into free slots, newest slots join the in-flight batch mid-decode,
and finished slots are recycled — all without changing any traced shape.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class Request:
    """One generation request: ``prompt`` (1-D int32 token ids), up to
    ``max_new`` generated tokens (EOS may end it earlier), visible to the
    scheduler from ``arrival_step`` onward."""

    rid: int
    prompt: np.ndarray
    max_new: int
    arrival_step: int = 0

    def __post_init__(self):
        object.__setattr__(self, "prompt", np.asarray(self.prompt, np.int32).reshape(-1))
        if self.prompt.size < 1:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")


@dataclass
class RequestResult:
    """Per-request outcome + latency breakdown (seconds are wall-clock from
    the moment the request became schedulable, i.e. queueing included)."""

    rid: int
    prompt_len: int
    tokens: np.ndarray = field(default_factory=lambda: np.zeros((0,), np.int32))
    admitted_step: int = -1
    finished_step: int = -1
    first_token_s: float = float("nan")
    latency_s: float = float("nan")
    hit_eos: bool = False
    truncated: bool = False  # run() hit max_steps with this request in flight
    logprobs: np.ndarray | None = None  # (num_tokens, V), engine opt-in

    @property
    def num_tokens(self) -> int:
        return int(self.tokens.size)


class FCFSQueue:
    """Arrival-ordered pending queue; ``admissible(step)`` pops the next
    request visible at ``step`` (or None)."""

    def __init__(self, requests):
        self._q = deque(sorted(requests, key=lambda r: (r.arrival_step, r.rid)))

    def __len__(self) -> int:
        return len(self._q)

    def next_arrival(self) -> int | None:
        return self._q[0].arrival_step if self._q else None

    def visible(self, step: int):
        """Requests already schedulable at ``step`` (arrival passed), in
        admission order — still queued, possibly waiting for a slot."""
        return [r for r in self._q if r.arrival_step <= step]

    def admissible(self, step: int):
        if self._q and self._q[0].arrival_step <= step:
            return self._q.popleft()
        return None

    def peek(self, step: int):
        """Head-of-line request visible at ``step`` WITHOUT popping — the
        paged engine inspects it against the block pool's ``can_admit``
        before committing (FCFS means a head that does not fit blocks the
        line; it is admitted once completions free enough pages)."""
        if self._q and self._q[0].arrival_step <= step:
            return self._q[0]
        return None

    def pop(self):
        """Pop the head unconditionally (pairs with a prior ``peek``)."""
        return self._q.popleft()


def synthetic_trace(
    num_requests: int,
    *,
    vocab_size: int,
    prompt_lens=(8, 16),
    max_new: int = 16,
    mean_interarrival: float = 2.0,
    seed: int = 0,
    prompt_pool: int = 0,
) -> list:
    """Poisson open-loop request trace: exponential inter-arrival times
    (mean ``mean_interarrival`` decode steps — the offered-load knob)
    accumulated in continuous time and floored onto the tick clock, so
    sub-tick means (< 1) genuinely produce multiple arrivals per tick.
    Prompt lengths cycle through ``prompt_lens``; token ids are random.

    ``prompt_pool > 0`` draws prompts from a fixed pool of that many
    distinct prompts instead of fresh ones per request — the knob that
    exercises (and benchmarks) paged prefix sharing: a pool of P prompts
    gives an expected steady-state prefix hit rate of 1 - P/num_requests."""
    if mean_interarrival <= 0:
        raise ValueError("mean_interarrival must be > 0")
    rng = np.random.default_rng(seed)
    pool = [
        rng.integers(
            0, vocab_size, size=int(prompt_lens[i % len(prompt_lens)])
        ).astype(np.int32)
        for i in range(prompt_pool)
    ]
    reqs, t = [], 0.0
    for rid in range(num_requests):
        if pool:
            prompt = pool[rid % len(pool)]
        else:
            L = int(prompt_lens[rid % len(prompt_lens)])
            prompt = rng.integers(0, vocab_size, size=L).astype(np.int32)
        reqs.append(Request(rid=rid, prompt=prompt, max_new=max_new, arrival_step=int(t)))
        t += rng.exponential(mean_interarrival)
    return reqs
