"""The paper's MNIST experiment model: 2-layer fully-connected network,
800 units per layer, ReLU activations (Fig. 2 left)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec


def param_specs(in_dim: int = 784, hidden: int = 800, out_dim: int = 10):
    return {
        "w1": ParamSpec((in_dim, hidden), ("embed", "mlp")),
        "b1": ParamSpec((hidden,), ("mlp",), init="zeros"),
        "w2": ParamSpec((hidden, hidden), ("mlp", "mlp2")),
        "b2": ParamSpec((hidden,), ("mlp2",), init="zeros"),
        "w3": ParamSpec((hidden, out_dim), ("mlp2", None)),
        "b3": ParamSpec((out_dim,), (None,), init="zeros"),
    }


def apply(params, x):
    """x: (B, in_dim) -> logits (B, out_dim)."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


def nll_fn(params, batch):
    """(sum_nll, batch_size) for the classification posterior (Eq. 7/8)."""
    logits = apply(params, batch["x"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    return -jnp.sum(gold), jnp.asarray(batch["y"].shape[0], jnp.float32)
