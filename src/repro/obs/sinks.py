"""Output sinks: the shared run manifest and a JSONL metrics stream.

The manifest is the provenance block stamped into every artifact a run
emits — ``trace.json`` (``otherData.manifest``), each ``BENCH_*.json``
(``manifest`` key, via ``benchmarks/common.py``), and the JSONL metrics
stream header — so any two artifacts can be matched to the same code +
backend + device state after the fact.
"""
from __future__ import annotations

import json
import platform
import subprocess
import sys
import time

MANIFEST_KEYS = (
    "git_sha",
    "jax_version",
    "backend",
    "device_kind",
    "device_count",
    "python",
    "platform",
    "timestamp",
)


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=5,
        )
        return out.stdout.strip() if out.returncode == 0 else "unknown"
    except (OSError, subprocess.TimeoutExpired):
        return "unknown"


def run_manifest() -> dict:
    """Provenance of the current run.  Importing jax here is fine — every
    caller already has it resident; failures degrade to "unknown" rather
    than taking the run down."""
    try:
        import jax

        backend = jax.default_backend()
        devices = jax.devices()
        device_kind = devices[0].device_kind if devices else "unknown"
        device_count = len(devices)
        jax_version = jax.__version__
    except Exception:  # manifest must never be the thing that crashes a run
        backend = device_kind = jax_version = "unknown"
        device_count = 0
    return {
        "git_sha": _git_sha(),
        "jax_version": jax_version,
        "backend": backend,
        "device_kind": device_kind,
        "device_count": device_count,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


class JsonlSink:
    """Append-one-JSON-object-per-line stream.  First line is the run
    manifest; ``metrics()`` lines carry periodic registry snapshots and
    ``summary()`` closes the run."""

    def __init__(self, path):
        self.path = path
        self._wrote_header = False

    def _write(self, obj: dict) -> None:
        with open(self.path, "a") as f:
            f.write(json.dumps(obj) + "\n")

    def header(self, manifest: dict | None = None) -> None:
        self._write({"kind": "manifest", **(manifest or run_manifest())})
        self._wrote_header = True

    def metrics(self, snapshot: dict, step: int | None = None) -> None:
        if not self._wrote_header:
            self.header()
        rec = {"kind": "metrics"}
        if step is not None:
            rec["step"] = step
        rec.update(snapshot)
        self._write(rec)

    def summary(self, snapshot: dict, **extra) -> None:
        if not self._wrote_header:
            self.header()
        self._write({"kind": "summary", **extra, **snapshot})
