"""Sharding tests.

Two tiers:

* pure-logic rule-table tests (``build_spec`` / ``leading_axes_specs``) —
  run everywhere, no devices needed;
* the ``multidevice`` suite — the DESIGN.md §7 acceptance gate, running on
  a FORCED 8-CPU-device backend: mesh-size equivalence of
  ``ChainExecutor.run_sharded`` (per-chain trajectories bit-identical
  across 1/2/4/8-device meshes and vs the unsharded executor where
  reduction order allows; center within float tolerance), the compressed
  int8 center exchange against its quantization bound, mesh validation
  errors, sharded in-carry moments, and the mesh-sharded ``ServeEngine``
  (token-identical to unsharded, one compiled decode program).

The multidevice tests auto-skip in a plain session (see tests/conftest.py)
and run via ``tests.util.run_multidevice_suite`` — the CI lane calls it
directly; ``TestMultideviceRelaunch`` is the slow-marked proxy that gives
``-m slow`` coverage from a single-device parent.
"""
import os
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import util

from repro.distributed import sharding as shd

MULTI_N = util.MULTIDEVICE_DEVICES


class TestBuildSpecSingleDevice:
    """Pure-logic tests via a fabricated mesh shape (no real devices)."""

    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_divisibility_fallback(self):
        mesh = self._mesh()
        # model axis size 1 always divides — spec granted
        spec = shd.build_spec((16, 8), ("embed", "heads"), {"embed": "data", "heads": "model"}, mesh)
        assert spec == P("data", "model")

    def test_axis_reuse_blocked(self):
        mesh = self._mesh()
        spec = shd.build_spec(
            (16, 8), ("embed", "mlp"), {"embed": "model", "mlp": "model"}, mesh
        )
        # mlp has priority over embed; embed must NOT reuse "model"
        assert spec == P(None, "model")

    def test_priority_kv_heads_over_seq(self):
        mesh = self._mesh()
        spec = shd.build_spec(
            (4, 128, 8, 64),
            ("batch", "kvseq", "kv_heads", None),
            {"batch": "data", "kvseq": "model", "kv_heads": "model"},
            mesh,
        )
        # kv_heads claims "model" first (priority), kvseq falls back
        assert spec == P("data", None, "model", None)

    def test_tuple_rules(self):
        mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
        spec = shd.build_spec((32,), ("embed",), {"embed": ("pod", "data")}, mesh)
        assert spec == P(("pod", "data"))

    def test_indivisible_dim_replicates(self):
        mesh = jax.make_mesh((1, 2) if len(jax.devices()) >= 2 else (1, 1), ("data", "model"))
        if mesh.shape["model"] == 1:
            pytest.skip("single device")
        spec = shd.build_spec((7,), ("heads",), {"heads": "model"}, mesh)
        assert spec == P(None)


class TestLeadingAxesSpecs:
    """Serving-engine layout rule: leading dims take the named mesh axes
    when divisible, else replicate.  ``leading_axes_specs`` only consults
    ``mesh.shape``, so a fabricated shape exercises real axis sizes on a
    single-device box."""

    MESH = SimpleNamespace(shape={"member": 2, "slot": 4})

    def _spec(self, shape, axes):
        x = jax.ShapeDtypeStruct(shape, jnp.float32)
        return shd.leading_axes_specs(x, axes, self.MESH)

    def test_cache_leaf_both_axes(self):
        assert self._spec((4, 8, 16), ("member", "slot")) == P("member", "slot")

    def test_indivisible_leading_dim_replicates(self):
        assert self._spec((3, 8, 16), ("member", "slot")) == P(None, "slot")
        assert self._spec((4, 7, 16), ("member", "slot")) == P("member", None)

    def test_missing_mesh_axis_replicates(self):
        assert self._spec((4, 8), ("member", "nope")) == P("member", None)

    def test_short_leaf_truncates(self):
        # scalar / 1-D leaves take only the axes their rank allows
        assert self._spec((8,), ("slot", "member")) == P("slot")
        assert self._spec((), ("slot",)) == P()

    def test_tree_mapped(self):
        tree = {
            "t": jax.ShapeDtypeStruct((4, 8), jnp.int32),
            "kv": jax.ShapeDtypeStruct((4, 8, 32, 2), jnp.float32),
        }
        specs = shd.leading_axes_specs(tree, ("member", "slot"), self.MESH)
        assert specs == {"t": P("member", "slot"), "kv": P("member", "slot")}


# ---------------------------------------------------------------------------
# forced-8-device suite (DESIGN.md §7)
# ---------------------------------------------------------------------------

MU = np.array([2.0, -1.0, 0.5, -0.25], np.float32)
K, SYNC, STEPS, D = 8, 4, 96, 4


def _sampler(alpha, compression=None, chain_axis="chain"):
    from repro import core

    return core.ec_sghmc(
        step_size=1e-2,
        alpha=alpha,
        sync_every=SYNC,
        noise_convention="eq6",
        chain_axis=chain_axis,
        per_chain_noise=True,
        compression=compression,
    )


def _executor(sampler):
    from repro.run import ChainExecutor

    mu = jnp.asarray(MU)
    return ChainExecutor(
        sampler=sampler,
        grad_fn=lambda t, _b: t - mu,
        moments=True,
        chunk_steps=STEPS,
        key_mode="fold",
    )


def _init():
    return jnp.broadcast_to(jnp.linspace(-2.0, 2.0, D, dtype=jnp.float32), (K, D)) + 0.0


def _run_on_mesh(alpha, n_dev, compression=None):
    """run_sharded on an n_dev-device (chain,) mesh; returns (params, state,
    moments)."""
    util.require_devices(n_dev)
    sampler = _sampler(alpha, compression)
    ex = _executor(sampler)
    params = _init()
    state = sampler.init(params)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:n_dev]), ("chain",))
    res = ex.run_sharded(params, state, num_steps=STEPS, key=jax.random.key(7), mesh=mesh)
    return np.asarray(res.params), res.state, res.moments


@pytest.mark.multidevice
class TestMeshSizeEquivalence:
    """The layout-invariance contract: the SAME sampler program on meshes
    of every size dividing K.  Per-chain noise keys by GLOBAL chain index
    and the step key is shard-invariant, so per-chain trajectories are
    bit-identical wherever reduction order allows (alpha=0: no cross-chain
    reduction feeds back — exact); the center's hierarchical
    (local-mean, cross-shard-mean) exchange is float-tolerance equal to
    the flat mean (alpha>0)."""

    def test_alpha0_bit_identical_across_meshes(self):
        util.require_devices(MULTI_N)
        runs = {n: _run_on_mesh(0.0, n) for n in (1, 2, 4, 8)}
        base = runs[1][0]
        for n in (2, 4, 8):
            np.testing.assert_array_equal(runs[n][0], base, err_msg=f"mesh size {n}")

    def test_alpha0_matches_unsharded_run(self):
        util.require_devices(MULTI_N)
        sharded = _run_on_mesh(0.0, 8)[0]
        # unsharded executor: same fold-in key stream, chain_axis=None
        # sampler with per_chain_noise draws the identical global-index
        # noise (offset 0 covers all K chains on the one "shard")
        sampler = _sampler(0.0, chain_axis=None)
        ex = _executor(sampler)
        params = _init()
        res = ex.run(params, sampler.init(params), num_steps=STEPS, key=jax.random.key(7))
        np.testing.assert_array_equal(np.asarray(res.params), sharded)

    def test_alpha1_trajectories_within_tolerance(self):
        util.require_devices(MULTI_N)
        runs = {n: _run_on_mesh(1.0, n) for n in (1, 2, 4, 8)}
        base = runs[1]
        for n in (2, 4, 8):
            # center feedback reenters chain updates, so reduction-order
            # float drift can compound — but stays at float tolerance
            np.testing.assert_allclose(
                runs[n][0], base[0], rtol=1e-5, atol=1e-5, err_msg=f"mesh size {n}"
            )
            np.testing.assert_allclose(
                np.asarray(runs[n][1].center),
                np.asarray(base[1].center),
                rtol=1e-5,
                atol=1e-5,
            )

    def test_sharded_moments_match_across_meshes(self):
        util.require_devices(MULTI_N)
        from repro.diagnostics import welford_mean

        m1 = np.asarray(welford_mean(_run_on_mesh(1.0, 1)[2]))
        m8 = np.asarray(welford_mean(_run_on_mesh(1.0, 8)[2]))
        np.testing.assert_allclose(m8, m1, rtol=1e-5, atol=1e-5)


@pytest.mark.multidevice
class TestCompressedExchange:
    """int8 center exchange on a real multi-device mesh: sound (finite,
    coupled, near the raw-exchange run) and layout-consistent."""

    def test_compressed_close_to_raw(self):
        util.require_devices(MULTI_N)
        from repro.distributed import int8_codec

        raw_p, raw_st, _ = _run_on_mesh(1.0, 8)
        cmp_p, cmp_st, _ = _run_on_mesh(1.0, 8, compression=int8_codec())
        assert np.all(np.isfinite(cmp_p))
        # per-sync quantization error is <= scale/2 elementwise (scale ~
        # max|mean|/127); over STEPS/SYNC syncs the trajectories stay close
        np.testing.assert_allclose(cmp_p, raw_p, atol=0.05)
        np.testing.assert_allclose(
            np.asarray(cmp_st.center), np.asarray(raw_st.center), atol=0.05
        )

    def test_compressed_center_replicated_across_shards(self):
        """The decoded all-gathered center must come out bit-identical on
        every shard (check_rep=False would hide divergence)."""
        util.require_devices(MULTI_N)
        from jax.experimental.shard_map import shard_map

        from repro.distributed import int8_codec
        from repro.distributed.sharding import chain_specs

        sampler = _sampler(1.0, int8_codec())
        params = _init()
        tree = {"params": params, "state": sampler.init(params)}
        specs = chain_specs(tree, K, "chain")
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:8]), ("chain",))
        mu = jnp.asarray(MU)

        def chunk(key, tree):
            p, st = tree["params"], tree["state"]
            for t in range(2 * SYNC):
                rng = jax.random.fold_in(key, t)
                upd, st = sampler.update(p - mu, st, params=p, rng=rng)
                p = jax.tree.map(lambda a, u: a + u, p, upd)
            return jax.tree.map(lambda x: x[None], (st.mean_theta_stale, st.center))

        cents = shard_map(
            chunk, mesh=mesh, in_specs=(P(), specs), out_specs=P("chain"), check_rep=False
        )(jax.random.key(3), tree)
        for c in jax.tree.leaves(cents):
            c = np.asarray(c)
            assert np.abs(c - c[0]).max() == 0.0


@pytest.mark.multidevice
class TestMeshValidation:
    def test_missing_chain_axis_rejected(self):
        util.require_devices(2)
        sampler = _sampler(1.0)
        ex = _executor(sampler)
        params = _init()
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:2]), ("data",))
        with pytest.raises(ValueError, match="no 'chain' axis"):
            ex.run_sharded(params, sampler.init(params), num_steps=4,
                           key=jax.random.key(0), mesh=mesh)

    def test_indivisible_chain_count_rejected(self):
        util.require_devices(3)
        sampler = _sampler(1.0)
        ex = _executor(sampler)
        params = _init()  # K=8 chains
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:3]), ("chain",))
        with pytest.raises(ValueError, match="divisible"):
            ex.run_sharded(params, sampler.init(params), num_steps=4,
                           key=jax.random.key(0), mesh=mesh)


@pytest.mark.multidevice
class TestShardedServeEngine:
    """Mesh-sharded ServeEngine: identical tokens, one compiled decode
    program, live refresh re-places members once per promotion."""

    def _requests(self, n=6):
        from repro.serve.engine.scheduler import Request

        return [
            Request(rid=i, prompt=np.arange(1, 3 + i % 3, dtype=np.int32),
                    max_new=5, arrival_step=0)
            for i in range(n)
        ]

    def _engine(self, mesh, members=None, **kw):
        from test_serve_engine import STUB_CFG, stub_members, stub_model

        from repro.serve.engine import ServeEngine

        return ServeEngine(
            STUB_CFG, stub_model(), stub_members(4) if members is None else members,
            num_slots=4, max_seq=16, eos_id=None, mesh=mesh, **kw,
        )

    def test_tokens_identical_and_one_decode_program(self):
        util.require_devices(MULTI_N)
        from repro.launch.mesh import make_engine_mesh

        eng0 = self._engine(None)
        rep0 = eng0.run(self._requests())
        tok0 = {r.rid: r.tokens.tolist() for r in rep0.results}

        eng1 = self._engine(make_engine_mesh(2, 4))
        rep1 = eng1.run(self._requests())
        assert eng1.decode_trace_count == 1, rep1.trace_counts
        assert {r.rid: r.tokens.tolist() for r in rep1.results} == tok0

    def test_indivisible_axes_fall_back_to_replication(self):
        util.require_devices(MULTI_N)
        # member axis 8 does not divide K=4, slot axis 1 trivially divides:
        # both leading dims must quietly replicate, tokens unchanged
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:8]).reshape(8, 1),
                                 ("member", "slot"))
        eng0 = self._engine(None)
        tok0 = {r.rid: r.tokens.tolist() for r in eng0.run(self._requests()).results}
        eng = self._engine(mesh)
        assert {r.rid: r.tokens.tolist() for r in eng.run(self._requests()).results} == tok0
        assert eng.decode_trace_count == 1

    def test_overlapped_refresh_parks_on_spare_device(self):
        """DESIGN.md §9 on a mesh: make_engine_mesh(2,2) on 8 forced devices
        leaves 4 spare — the RefreshScheduler must park the background chain
        there, pre-stage candidates with the engine's NamedShardings, and
        promote >= 3 times without retracing or stalling decode."""
        util.require_devices(MULTI_N)
        from test_serve_engine import stub_members

        from repro import core
        from repro.launch.mesh import make_engine_mesh
        from repro.serve.engine import RefreshScheduler, SnapshotRegistry
        from repro.serve.engine.scheduler import Request

        stack = stub_members(4)
        reg = SnapshotRegistry(stack)
        center = jax.tree.map(lambda x: x[0], stack)
        sched = RefreshScheduler(
            reg,
            core.sgld(step_size=8e-5),
            lambda p: jax.tree.map(lambda x, c: 2500.0 * (x - c), p, center),
            jax.tree.map(lambda x: jnp.broadcast_to(x[0][None], x.shape) + 0.0, stack),
            key=jax.random.PRNGKey(8),
            chunk_steps=4,
        )
        mesh = make_engine_mesh(2, 2)
        eng = self._engine(mesh, members=reg, refresher=sched, refresh_every=2)
        mesh_devs = set(np.asarray(mesh.devices).flat)
        assert sched.device is not None and sched.device not in mesh_devs
        reqs = [
            Request(rid=i, prompt=np.arange(1, 3 + i % 3, dtype=np.int32),
                    max_new=8, arrival_step=i)
            for i in range(8)
        ]
        report = eng.run(reqs)
        assert reg.promoted >= 3, reg.stats()
        assert eng.decode_trace_count == 1, report.trace_counts
        assert eng._placed_version == reg.version
        rf = report.refresher
        assert rf["decode_steps_stalled"] == 0  # lazy gate: decode never blocked
        assert rf["micro_chunks"] >= rf["proposals"] >= rf["promotions"] >= 3

    def test_refresh_replaces_members_once_per_version(self):
        util.require_devices(MULTI_N)
        from test_serve_engine import stub_members

        from repro.launch.mesh import make_engine_mesh
        from repro.serve.engine import SnapshotRegistry

        reg = SnapshotRegistry(stub_members(4))
        eng = self._engine(make_engine_mesh(2, 4), members=reg)
        m0 = eng._members()
        assert eng._members() is m0  # cached: no re-place without promotion
        reg.propose(stub_members(4))
        m1 = eng._members()
        assert eng._placed_version == reg.version
        rep = eng.run(self._requests())
        assert eng.decode_trace_count == 1, rep.trace_counts


@pytest.mark.slow
class TestMultideviceRelaunch:
    """Relaunch proxy: run the whole multidevice suite in a forced-8-device
    child pytest — the same entry point the CI lane uses — so `-m slow`
    covers DESIGN.md §7 from a plain single-device session."""

    def test_suite_passes_under_forced_devices(self):
        out = util.run_multidevice_suite()
        tail = (out.stdout + out.stderr)[-4000:]
        assert out.returncode == 0, tail
        # the child must actually RUN the suite, not skip-collect it
        assert " passed" in out.stdout, tail
