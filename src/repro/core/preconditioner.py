"""Diagonal mass-matrix adaptation (RMSProp-style, à la scale-adapted SGHMC).

Maintains m̂ = sqrt(E[g²]) per parameter and exposes M^{-1} as a pytree the
samplers can consume in place of the scalar ``mass``.  Adaptation is frozen
after ``burnin`` steps so the sampler targets a fixed (valid) Hamiltonian
afterwards.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PrecondState(NamedTuple):
    v: any  # running E[g^2]
    step: jnp.ndarray


def rmsprop_preconditioner(decay: float = 0.99, eps: float = 1e-8, burnin: int = 1000):
    def init(params):
        return PrecondState(
            v=jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32), params),
            step=jnp.zeros((), jnp.int32),
        )

    def update(state, grads):
        adapt = (state.step < burnin).astype(jnp.float32)
        new_v = jax.tree.map(
            lambda v, g: v + adapt * (1 - decay) * (jnp.square(g.astype(jnp.float32)) - v),
            state.v,
            grads,
        )
        minv = jax.tree.map(lambda v: 1.0 / (jnp.sqrt(v) + eps), new_v)
        return minv, PrecondState(v=new_v, step=state.step + 1)

    return init, update
