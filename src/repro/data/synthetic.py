"""Synthetic datasets (offline container — no MNIST/CIFAR downloads).

Teacher-generated classification data with the same shapes/sizes as the
paper's datasets: a fixed random teacher network defines p(y|x); inputs are
class-conditioned Gaussian mixtures.  Everything is deterministic in the
seed, so experiments are exactly reproducible.  The paper's measurements
(posterior NLL vs. steps, comparing parallelization schemes on the SAME
target) are preserved under this substitution (DESIGN.md §10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _teacher_labels(x, key, hidden: int = 64, num_classes: int = 10, temp: float = 2.0):
    d = x.shape[-1]
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jax.random.normal(k1, (d, hidden)) / np.sqrt(d)
    w2 = jax.random.normal(k2, (hidden, num_classes)) / np.sqrt(hidden)
    logits = jnp.tanh(x @ w1) @ w2 * temp
    return jax.random.categorical(k3, logits, axis=-1)


def synthetic_mnist(n: int = 60_000, seed: int = 0):
    """(x, y): x (n, 784) in [0,1]-ish, y (n,) in [0,10). MNIST-shaped."""
    key = jax.random.PRNGKey(seed)
    kx, km, kt = jax.random.split(key, 3)
    centers = 0.5 + 0.2 * jax.random.normal(km, (10, 784))
    comp = jax.random.randint(kx, (n,), 0, 10)
    x = centers[comp] + 0.15 * jax.random.normal(kt, (n, 784))
    y = _teacher_labels(x, jax.random.PRNGKey(seed + 1))
    return np.asarray(x, np.float32), np.asarray(y, np.int32)


def synthetic_cifar10(n: int = 50_000, seed: int = 0):
    """(x, y): x (n, 32, 32, 3), y (n,). CIFAR-shaped."""
    key = jax.random.PRNGKey(seed)
    kx, km, kt = jax.random.split(key, 3)
    centers = 0.1 * jax.random.normal(km, (10, 32, 32, 3))
    comp = jax.random.randint(kx, (n,), 0, 10)
    x = centers[comp] + 0.25 * jax.random.normal(kt, (n, 32, 32, 3))
    y = _teacher_labels(x.reshape(n, -1)[:, ::4], jax.random.PRNGKey(seed + 1))
    return np.asarray(x, np.float32), np.asarray(y, np.int32)


def synthetic_token_stream(vocab_size: int, seed: int = 0):
    """Deterministic zipfian-unigram + local-bigram token sampler.

    Returns sample(step, shape) -> int32 tokens; stateless in ``step`` so the
    pipeline can resume from a checkpointed step index without replaying."""
    base = jax.random.PRNGKey(seed)
    ranks = jnp.arange(1, vocab_size + 1, dtype=jnp.float32)
    logits = -1.1 * jnp.log(ranks)  # zipf(1.1)

    def sample(step: int, shape):
        key = jax.random.fold_in(base, step)
        toks = jax.random.categorical(key, logits, shape=shape)
        # cheap local structure: every other token correlates with predecessor
        shifted = jnp.roll(toks, 1, axis=-1)
        mix = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.3, shape)
        return jnp.where(mix, (shifted * 31 + 7) % vocab_size, toks).astype(jnp.int32)

    return sample


def token_batch(sampler, step: int, batch_shape, seq_len: int):
    """LM batch dict: inputs + next-token labels."""
    toks = sampler(step, tuple(batch_shape) + (seq_len + 1,))
    return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
