"""Analytic roofline model (per-cell FLOPs / HBM / collective bytes).

WHY ANALYTIC: XLA's ``compiled.cost_analysis()`` counts a ``scan``
(while-loop) body ONCE — not multiplied by trip count (verified empirically;
see EXPERIMENTS.md §Roofline "HLO caveat"), and its bytes-accessed metric
assumes no fusion.  Since every model here scans over layer periods, HLO
numbers are structurally wrong for per-step totals.  We therefore derive
the three terms from the architecture configuration + sharding layout (the
standard MFU-accounting approach), and keep the HLO artifacts as SCHEDULE
evidence (which collectives exist, where they sit) plus lower-bound
cross-checks.

All *_model functions return GLOBAL per-step quantities; analyze_cell
divides by the mesh to per-device terms in seconds.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro import configs
from repro.models import active_params, num_params, get_model
from repro.models.common import ModelConfig

HW = {"peak_flops_bf16": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

_REMAT_FWD = 1.0  # extra forward recompute under nothing_saveable remat


def _attn_ctx(seq: int, window) -> float:
    """Average attended context per query under causal (+ window) masking."""
    if window and window < seq:
        # first `window` tokens: ramp; rest attend `window`
        ramp = window * (window + 1) / 2
        return (ramp + (seq - window) * window) / seq
    return (seq + 1) / 2


def _layer_fwd_flops(cfg: ModelConfig, kind, B: int, S: int, ctx_seq: int) -> float:
    """Forward FLOPs for ONE layer over B*S tokens (ctx_seq: kv context for
    attention — equals S for train/prefill, cache length for decode)."""
    D, dh = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    T = B * S
    f = 0.0
    if kind.kind == "attn":
        f += 2 * T * D * dh * (Hq + 2 * Hkv)  # q, k, v projections
        f += 2 * T * Hq * dh * D  # output projection
        ctx = _attn_ctx(ctx_seq, kind.window) if S > 1 else min(ctx_seq, kind.window or ctx_seq)
        f += 4 * T * ctx * Hq * dh  # QK^T + AV
        if kind.moe:
            E, K, Fe = cfg.moe_num_experts, cfg.moe_top_k, cfg.moe_d_ff
            cf = cfg.capacity_factor
            f += 2 * T * D * E  # router
            f += 6 * T * K * cf * D * Fe  # expert FFN (gated, capacity-padded)
            g = min(512, S)  # dispatch/combine einsums (group size)
            C = max(int(g * K * cf / E), K)
            f += 2 * 2 * T * E * C * D / 1  # dispatch + combine per group token
        else:
            f += 2 * T * D * cfg.d_ff * (3 if cfg.mlp_gated else 2)
    elif kind.kind == "rglru":
        R = cfg.rnn_width or D
        f += 2 * T * D * R * 2  # two input branches
        f += 2 * T * cfg.rglru_conv_width * R  # depthwise conv
        f += 2 * T * R * R * 2  # a/i gates
        f += 9 * T * R  # scan combine
        f += 2 * T * R * D  # out proj
        f += 2 * T * D * cfg.d_ff * 3  # MLP sublayer
    elif kind.kind == "mlstm":
        up = 2 * D
        f += 2 * T * D * up * 2  # up projections
        f += 2 * T * 4 * up  # conv
        f += 2 * T * up * up * 3  # q, k, v
        if S > 1:  # parallel (quadratic) train form
            f += 2 * T * S * cfg.num_heads * (up // cfg.num_heads) * 2 + 2 * T * S * cfg.num_heads
        else:  # recurrent decode: C update + read
            dh_in = up // cfg.num_heads
            f += 6 * B * cfg.num_heads * dh_in * dh_in
        f += 2 * T * up * D  # down
    elif kind.kind == "slstm":
        NH = cfg.num_heads
        f += 2 * T * D * D * 4  # input gates
        f += 2 * T * NH * dh * dh * 4  # recurrent mixing
        ff = int(D * 4 / 3)
        f += 2 * T * D * ff * 3  # gated FFN
    return f


def _vocab_flops(cfg: ModelConfig, tokens: float) -> float:
    return 2 * tokens * cfg.d_model * cfg.vocab_size


def flops_model(arch: str, shape_name: str, overrides: dict | None = None,
                remat: str = "full") -> dict:
    """GLOBAL FLOPs per step, decomposed."""
    cfg = configs.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = configs.SHAPES[shape_name]
    B, S = cell.global_batch, cell.seq_len
    kinds = cfg.layer_kinds
    if cell.kind == "decode":
        fwd = sum(_layer_fwd_flops(cfg, k, B, 1, S) for k in kinds)
        fwd += _vocab_flops(cfg, B)
        if cfg.family == "audio":  # cross-attention reads
            fwd += 4 * B * cfg.enc_seq * cfg.num_heads * cfg.head_dim * cfg.num_layers
        return {"total": fwd, "fwd": fwd, "factor": 1.0}
    fwd = sum(_layer_fwd_flops(cfg, k, B, S, S) for k in kinds)
    fwd += _vocab_flops(cfg, B * S)
    if cfg.family == "audio":
        enc_kind = configs.get_config(arch).pattern[0]
        fwd += cfg.enc_layers * _layer_fwd_flops(cfg, enc_kind, B, cfg.enc_seq, cfg.enc_seq)
        fwd += 4 * B * S * cfg.enc_seq * cfg.num_heads * cfg.head_dim * cfg.num_layers / S  # cross per dec token ~ enc_seq
    if cell.kind == "prefill":
        return {"total": fwd, "fwd": fwd, "factor": 1.0}
    factor = 3.0 + (_REMAT_FWD if remat == "full" else 0.0)  # bwd = 2x fwd
    return {"total": fwd * factor, "fwd": fwd, "factor": factor}


@dataclass
class Layout:
    """Sharding layout factors for the cell (from launch/specs rules)."""
    devices: int
    tp: int  # model-axis size weights are divided by (TP contractions)
    fsdp: int  # axis size params are additionally sharded+gathered over
    chains: int
    b_local: int  # per-device batch rows
    sync_every: int = 4
    style: str = "tp_fsdp"


def _layout(arch: str, shape_name: str, multi_pod: bool, num_chains=None,
            sync_every: int = 4, style: str = "tp_fsdp", tp_size=None) -> Layout:
    cell = configs.SHAPES[shape_name]
    pods = 2 if multi_pod else 1
    pure_dp = arch in {"whisper-base", "xlstm-350m"}
    if pure_dp:
        style = "dp"
    if cell.kind == "train":
        k_single = num_chains or configs.EC_CHAINS[arch]
        k = k_single * pods
        chips = 256 // k_single  # per-chain chips (per pod)
        if style == "dp":
            tp, fsdp, rows_div = 1, 1, chips
        elif style == "fsdp2d":
            tp, fsdp, rows_div = 1, chips, chips
        else:  # tp_fsdp (tp_size re-balances the ratio)
            tp = tp_size or 16
            fsdp, rows_div = chips // tp, chips // tp
        per_dev = max(cell.global_batch // (k * rows_div), 1)
        return Layout(256 * pods, tp, fsdp, k, per_dev, sync_every, style)
    fsdp_serve = arch in {"grok-1-314b", "gemma3-27b", "gemma2-27b", "qwen2-vl-7b"}
    if style == "dp":
        tp, fsdp = 1, 1
        data = 16 * pods
    elif style == "fsdp2d":
        tp, fsdp = 1, 256 * pods
        data = 16 * pods
    else:
        tp = tp_size or 16
        data = (256 // tp) * pods
        fsdp = data if fsdp_serve else 1
    return Layout(256 * pods, tp, fsdp, 1,
                  max(cell.global_batch // data, 1), sync_every, style)


def hbm_model(arch: str, shape_name: str, multi_pod: bool = False,
              overrides: dict | None = None, *, flash_attn: bool = False,
              num_chains=None, shard_style: str = "tp_fsdp",
              remat: str = "full", fused_sampler: bool = False,
              tp_size=None) -> dict:
    """PER-DEVICE HBM bytes per step (first-order traffic model)."""
    cfg = configs.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = configs.SHAPES[shape_name]
    lay = _layout(arch, shape_name, multi_pod, num_chains, style=shard_style, tp_size=tp_size)
    pbytes = np.dtype(cfg.param_dtype).itemsize
    abytes = np.dtype(cfg.compute_dtype).itemsize
    P_total = num_params(cfg) * pbytes  # one chain's params
    P_read = P_total / lay.tp  # bytes each device reads per full pass
    B, S = cell.global_batch, cell.seq_len
    D, L = cfg.d_model, cfg.num_layers

    out = {}
    if cell.kind == "decode":
        model = get_model(cfg)
        cache = model.make_cache(cfg, B, S, cfg.compute_dtype, abstract=True)
        cache_bytes = sum(
            int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
            for x in jax_tree_leaves(cache)
        )
        out["weights"] = P_read
        out["kv_cache"] = cache_bytes / lay.devices  # read once per token
        out["activations"] = lay.b_local * D * L * abytes * 4
        out["total"] = sum(out.values())
        return out

    tok_local = lay.b_local * S
    act = tok_local * D * L * abytes
    # weight reads per pass: fwd + bwd (+ remat re-forward)
    w_passes = (3.0 if remat == "full" else 2.0) if cell.kind == "train" else 1.0
    out["weights"] = P_read * w_passes
    # activations: block IO ~6 streams/layer fwd; remat re-writes fwd acts
    if cell.kind == "train":
        act_factor = 10.0 if remat == "full" else 8.0
    else:
        act_factor = 5.0
    out["activations"] = act * act_factor
    # attention score materialization (XLA baseline); flash kernel removes it
    if not flash_attn:
        score_bytes = 0.0
        for k in cfg.layer_kinds:
            if k.kind == "attn":
                ctx = _attn_ctx(S, k.window)
                score_bytes += lay.b_local * cfg.num_heads * S * ctx * 4 * 2  # f32 write+read
            if k.kind == "mlstm":
                score_bytes += lay.b_local * cfg.num_heads * S * S * 4 * 2
        out["attn_scores"] = score_bytes * (1.5 if cell.kind == "train" else 1.0)
    if cell.kind == "train":
        # sampler sweep: read theta, p, g, c̃; write theta, p
        # (grads are param-dtype: value_and_grad matches the param dtype)
        state_local = P_total * lay.chains / lay.devices
        grads_local = P_total * lay.chains / lay.devices
        # fused Pallas kernel: on-chip noise + single pass = 4 reads 2 writes
        streams = 6.0 if fused_sampler else (5.0 + 1.0)
        out["sampler"] = (streams - 1.0) * state_local + grads_local
        if not fused_sampler:  # XLA materializes the Gaussian noise tensor
            out["sampler_noise"] = 2 * state_local
        out["grads_write"] = grads_local
    out["total"] = sum(out.values())
    return out


def jax_tree_leaves(tree):
    import jax

    return jax.tree.leaves(tree)


def collective_model(arch: str, shape_name: str, multi_pod: bool = False,
                     overrides: dict | None = None, *, num_chains=None,
                     sync_every: int = 4, sync_compression: float = 1.0,
                     shard_style: str = "tp_fsdp", remat: str = "full",
                     tp_size=None) -> dict:
    """PER-DEVICE collective bytes per step (ring-algorithm first order:
    all-gather/reduce-scatter of N bytes over an axis costs ~N bytes on the
    wire per device; all-reduce costs ~2N)."""
    cfg = configs.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = configs.SHAPES[shape_name]
    lay = _layout(arch, shape_name, multi_pod, num_chains, sync_every, style=shard_style, tp_size=tp_size)
    pbytes = np.dtype(cfg.param_dtype).itemsize
    abytes = np.dtype(cfg.compute_dtype).itemsize
    P_total = num_params(cfg) * pbytes
    B, S = cell.global_batch, cell.seq_len
    D, L = cfg.d_model, cfg.num_layers
    out = {}
    w_passes = (3.0 if remat == "full" else 2.0) if cell.kind == "train" else 1.0
    if lay.fsdp > 1:
        out["fsdp_allgather"] = P_total / lay.tp * w_passes
    if lay.tp > 1:
        # megatron-style: ~2 activation all-reduces per layer per pass,
        # all-reduce wire ~ 2x payload
        act_ar = 2 * lay.b_local * (S if cell.kind != "decode" else 1) * D * abytes * L * 2
        out["tp_allreduce"] = act_ar * (2.0 if cell.kind == "train" else 1.0)
    if cell.kind == "train":
        grads_bytes = num_params(cfg) * pbytes
        if lay.style == "dp":
            out["grad_allreduce"] = 2 * grads_bytes  # ring AR over the DP group
        elif lay.fsdp > 1:
            out["grad_reduce_scatter"] = grads_bytes / lay.tp
        # EC elastic-coupling exchange: pmean(theta) over the chain axis,
        # every s steps (amortized) — the paper's ONLY cross-chain traffic
        if lay.chains > 1:
            shard = P_total / (lay.tp * lay.fsdp) * sync_compression
            out["ec_sync_amortized"] = 2 * shard / lay.sync_every
    out["total"] = sum(out.values())
    return out


def analyze_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 overrides: dict | None = None, *, flash_attn: bool = False,
                 num_chains=None, sync_every: int = 4,
                 sync_compression: float = 1.0, shard_style: str = "tp_fsdp",
                 remat: str = "full", fused_sampler: bool = False,
                 tp_size=None) -> dict:
    cell = configs.SHAPES[shape_name]
    lay = _layout(arch, shape_name, multi_pod, num_chains, sync_every,
                  style=shard_style, tp_size=tp_size)
    fl = flops_model(arch, shape_name, overrides, remat=remat)
    # flops_model uses the GLOBAL batch = all chains' tokens together, so
    # dividing by the device count is chain-correct.
    flops_dev = fl["total"] / lay.devices
    hbm = hbm_model(arch, shape_name, multi_pod, overrides,
                    flash_attn=flash_attn, num_chains=num_chains,
                    shard_style=shard_style, remat=remat, fused_sampler=fused_sampler,
                    tp_size=tp_size)
    coll = collective_model(arch, shape_name, multi_pod, overrides,
                            num_chains=num_chains, sync_every=sync_every,
                            sync_compression=sync_compression,
                            shard_style=shard_style, remat=remat, tp_size=tp_size)
    cfg = configs.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    n_act = active_params(cfg)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    model_flops = (6.0 if cell.kind == "train" else 2.0) * n_act * tokens
    t_c = flops_dev / HW["peak_flops_bf16"]
    t_m = hbm["total"] / HW["hbm_bw"]
    t_x = coll["total"] / HW["ici_bw"]
    dom = max(t_c, t_m, t_x)
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chains": lay.chains,
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": ["compute", "memory", "collective"][[t_c, t_m, t_x].index(dom)],
        "roofline_frac": t_c / dom if dom else 0.0,
        "flops_per_dev": flops_dev,
        "hbm_breakdown": hbm,
        "coll_breakdown": coll,
        "model_flops_global": model_flops,
        "useful_ratio": model_flops / (flops_dev * lay.devices) if flops_dev else 0.0,
    }
