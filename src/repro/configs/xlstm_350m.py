"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (7 mLSTM : 1 sLSTM), blocks carry their own projections (d_ff=0).
[arXiv:2405.04517; unverified]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

_PATTERN = (LayerKind("mlstm"),) * 7 + (LayerKind("slstm"),)

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    vocab_size=50304,
    d_model=1024,
    num_layers=24,  # 3 periods of [7 mLSTM + 1 sLSTM]
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,  # sLSTM: num_heads * head_dim == d_model
    d_ff=0,
    pattern=_PATTERN,
    act="gelu",
    tie_embeddings=True,
    param_dtype=jnp.float32,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=4,
    num_heads=2,
    num_kv_heads=2,
    head_dim=32,
    pattern=(LayerKind("mlstm"), LayerKind("slstm")),
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
