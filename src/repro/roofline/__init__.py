from .analytic import analyze_cell, collective_model, flops_model, hbm_model
