"""ChainExecutor equivalence battery (the ISSUE-4 acceptance gate).

The executor replaced every per-step Python driver in the repo; these tests
pin its contract:

* trajectories are BIT-IDENTICAL (f32) to the removed driver — one jitted
  step per Python iteration — for SGHMC, EC-SGHMC (fused and unfused) and
  the async approach-I baseline, in every key mode;
* chunking is invisible: any ``chunk_steps`` split yields the same bits,
  which is what makes checkpoint/preemption boundaries free;
* the sweep axis (stacked seeds or a vmapped hyperparameter grid via
  ``sampler_factory``) matches member-by-member runs;
* in-carry diagnostics (Welford moments, batch-means ESS) agree with the
  trajectory statistics they replace;
* the shard_map chain routing keeps the s-periodic center sync as the
  program's ONLY cross-chain collective — checked on the lowered HLO in a
  subprocess with 4 forced host devices (the acceptance criterion).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro import diagnostics as diag
from repro.run import ChainExecutor, ess_feedback_adapter, rollout

MU = jnp.array([2.0, -1.0])
STEPS = 96
K = 4


def grad_U(p):
    return p - MU


def start(shape=(K, 2)):
    """Fresh start buffer per call — the executor DONATES its carry."""
    return jnp.broadcast_to(jnp.array([-2.0, 3.0]), shape) + 0.0


def per_step_reference(sampler, params, *, keys=None, key=None, key_mode="keys",
                       num_steps=STEPS):
    """THE removed driver: one jitted step per Python iteration, gradients
    at ``grad_targets`` (stale snapshots for approach-I samplers)."""
    state = sampler.init(params)

    @jax.jit
    def step(params, state, rng):
        targets = sampler.grad_targets(state, params) if sampler.grad_targets else params
        upd, state = sampler.update(grad_U(targets), state, params=params, rng=rng)
        return core.apply_updates(params, upd), state

    traj = []
    for t in range(num_steps):
        if key_mode == "keys":
            rng = keys[t]
        elif key_mode == "fold":
            rng = jax.random.fold_in(key, t)
        else:  # carry
            key, rng = jax.random.split(key)
        params, state = step(params, state, rng)
        traj.append(np.asarray(params))
    return np.stack(traj)


SAMPLERS = {
    "sghmc": lambda: core.sghmc(step_size=1e-2, friction=1.0),
    "ec_s1": lambda: core.ec_sghmc(step_size=1e-2, alpha=1.0, sync_every=1,
                                   noise_convention="eq6"),
    "ec_s4": lambda: core.ec_sghmc(step_size=1e-2, alpha=1.0, sync_every=4,
                                   noise_convention="eq6"),
    "ec_fused_s1": lambda: core.ec_sghmc(step_size=1e-2, alpha=1.0, sync_every=1,
                                         fused=True),
    "ec_fused_s4": lambda: core.ec_sghmc(step_size=1e-2, alpha=1.0, sync_every=4,
                                         fused=True),
}


class TestBitIdentity:
    """Acceptance criterion: executor == removed per-step driver, exactly."""

    @pytest.mark.parametrize("name", list(SAMPLERS))
    def test_keys_mode(self, name):
        sampler = SAMPLERS[name]()
        keys = jax.random.split(jax.random.PRNGKey(0), STEPS)
        res = rollout(sampler, grad_U, start(), num_steps=STEPS, keys=keys,
                      chunk_steps=32)
        ref = per_step_reference(sampler, start(), keys=keys)
        np.testing.assert_array_equal(np.asarray(res.trace), ref)

    def test_async_grad_targets(self):
        """Approach-I: gradients must be evaluated at the stale worker
        snapshots, not the server params."""
        sampler = core.async_sghmc(step_size=1e-2, num_workers=K, sync_every=2)
        keys = jax.random.split(jax.random.PRNGKey(1), STEPS)
        res = rollout(sampler, grad_U, start((2,)), num_steps=STEPS, keys=keys,
                      chunk_steps=32)
        ref = per_step_reference(sampler, start((2,)), keys=keys)
        np.testing.assert_array_equal(np.asarray(res.trace), ref)

    def test_carry_key_mode(self):
        """``key_mode='carry'`` reproduces the legacy split-per-step RNG
        sequence of the posterior driver."""
        sampler = SAMPLERS["ec_s4"]()
        # the base key joins the donated carry and is consumed — the
        # reference needs its own instance
        res = rollout(sampler, grad_U, start(), num_steps=STEPS,
                      key=jax.random.PRNGKey(2), key_mode="carry", chunk_steps=24)
        ref = per_step_reference(sampler, start(), key=jax.random.PRNGKey(2),
                                 key_mode="carry")
        np.testing.assert_array_equal(np.asarray(res.trace), ref)

    def test_fold_key_mode(self):
        """``key_mode='fold'`` reproduces the training loop's absolute-step
        fold_in stream."""
        sampler = SAMPLERS["sghmc"]()
        key = jax.random.key(3)
        res = rollout(sampler, grad_U, start(), num_steps=STEPS, key=key,
                      key_mode="fold", chunk_steps=32)
        ref = per_step_reference(sampler, start(), key=key, key_mode="fold")
        np.testing.assert_array_equal(np.asarray(res.trace), ref)


class TestChunking:
    def test_chunk_split_invisible(self):
        """Any chunk_steps partition produces the same bits — checkpoints
        and preemption boundaries cannot perturb the dynamics."""
        keys = jax.random.split(jax.random.PRNGKey(4), STEPS)
        outs = []
        for chunk in (STEPS, 32, 16):
            sampler = SAMPLERS["ec_s4"]()
            res = rollout(sampler, grad_U, start(), num_steps=STEPS, keys=keys,
                          chunk_steps=chunk)
            outs.append(np.asarray(res.trace))
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])

    def test_resume_from_start_step(self):
        """fold mode + start_step: a split run (resume) is bit-identical to
        one uninterrupted run — the training loop's auto-resume contract."""
        sampler = SAMPLERS["ec_s4"]()
        key = jax.random.key(5)
        full = rollout(sampler, grad_U, start(), num_steps=STEPS, key=key,
                       key_mode="fold", chunk_steps=STEPS)

        half = STEPS // 2
        sampler2 = SAMPLERS["ec_s4"]()
        first = rollout(sampler2, grad_U, start(), num_steps=half, key=key,
                        key_mode="fold", chunk_steps=half)
        ex = ChainExecutor(
            sampler=sampler2, grad_fn=lambda t, _b: grad_U(t),
            trace_fn=lambda p: p, chunk_steps=half, key_mode="fold",
        )
        second = ex.run(first.params, first.state, num_steps=half, key=key,
                        start_step=half)
        resumed = np.concatenate([np.asarray(first.trace), np.asarray(second.trace)])
        np.testing.assert_array_equal(np.asarray(full.trace), resumed)

    def test_early_stop(self):
        sampler = SAMPLERS["sghmc"]()
        keys = jax.random.split(jax.random.PRNGKey(6), STEPS)
        ex = ChainExecutor(sampler=sampler, grad_fn=lambda t, _b: grad_U(t),
                           chunk_steps=16, key_mode="keys")
        stops = []

        def on_chunk(step_end, params, state, outs):
            stops.append(step_end)
            return step_end < 32

        res = ex.run(start(), sampler.init(start()), num_steps=STEPS, keys=keys,
                     on_chunk=on_chunk)
        assert res.steps == 32 and stops == [16, 32]


class TestTraceAndDiagnostics:
    def test_thinning(self):
        """thin=4 keeps exactly every 4th post-update sample."""
        keys = jax.random.split(jax.random.PRNGKey(7), STEPS)
        sampler = SAMPLERS["sghmc"]()
        full = rollout(sampler, grad_U, start(), num_steps=STEPS, keys=keys,
                       chunk_steps=32)
        sampler2 = SAMPLERS["sghmc"]()
        thinned = rollout(sampler2, grad_U, start(), num_steps=STEPS, keys=keys,
                          thin=4, chunk_steps=32)
        np.testing.assert_array_equal(
            np.asarray(thinned.trace), np.asarray(full.trace)[3::4]
        )

    def test_in_carry_moments_match_trajectory(self):
        keys = jax.random.split(jax.random.PRNGKey(8), STEPS)
        sampler = SAMPLERS["ec_s1"]()
        res = rollout(sampler, grad_U, start(), num_steps=STEPS, keys=keys,
                      moments=True, chunk_steps=32)
        traj = np.asarray(res.trace)
        np.testing.assert_allclose(
            np.asarray(diag.welford_mean(res.moments)), traj.mean(0), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(diag.welford_var(res.moments)), traj.var(0), rtol=1e-3, atol=1e-5
        )

    def test_moments_from_burnin(self):
        keys = jax.random.split(jax.random.PRNGKey(9), STEPS)
        burn = 32
        sampler = SAMPLERS["sghmc"]()
        res = rollout(sampler, grad_U, start(), num_steps=STEPS, keys=keys,
                      moments=True, moments_from=burn, chunk_steps=48)
        traj = np.asarray(res.trace)
        np.testing.assert_allclose(
            np.asarray(diag.welford_mean(res.moments)), traj[burn:].mean(0),
            rtol=1e-5, atol=1e-5,
        )

    def test_streaming_ess_tracks_fft_estimate(self):
        """Batch-means ESS from the carry lands within a small factor of the
        full-trajectory FFT estimate on a well-mixed chain."""
        n = 4096
        sampler = core.sghmc(step_size=0.3, friction=2.0)
        keys = jax.random.split(jax.random.PRNGKey(10), n)
        res = rollout(sampler, grad_U, start(), num_steps=n, keys=keys,
                      moments=False, chunk_steps=n,
                      ess_probe_fn=lambda p: p[0], ess_batch_len=64)
        stream = float(np.sum(np.asarray(diag.batch_ess_estimate(res.ess))))
        traj = np.asarray(res.trace)[:, 0, :]  # (T, 2) chain-0 series
        fft = float(np.sum(diag.effective_sample_size_nd(traj[None])))
        assert 0.2 * fft < stream < 5.0 * fft, (stream, fft)

    def test_collect_stats_series(self):
        sampler = SAMPLERS["ec_s4"]()
        keys = jax.random.split(jax.random.PRNGKey(11), STEPS)
        ex = ChainExecutor(sampler=sampler, grad_fn=lambda t, _b: grad_U(t),
                           trace_fn=lambda p: p, thin=8, collect_stats=True,
                           chunk_steps=32, key_mode="keys")
        res = ex.run(start(), sampler.init(start()), num_steps=STEPS, keys=keys)
        assert res.stats is not None
        k = next(iter(res.stats))
        assert res.stats[k].shape[0] == STEPS // 8  # one row per thin point


class TestSweep:
    def test_stacked_seeds_match_members(self):
        """The vmapped sweep program equals per-member runs, bitwise."""
        R = 3
        keys = jnp.stack([jax.random.split(jax.random.PRNGKey(20 + r), STEPS)
                          for r in range(R)])
        sampler = SAMPLERS["ec_s4"]()
        swept = rollout(sampler, grad_U, start((R, K, 2)), num_steps=STEPS,
                        keys=keys, chunk_steps=32, sweep=True)
        for r in range(R):
            sampler_r = SAMPLERS["ec_s4"]()
            member = rollout(sampler_r, grad_U, start(), num_steps=STEPS,
                             keys=keys[r], chunk_steps=32)
            np.testing.assert_array_equal(
                np.asarray(swept.trace)[r], np.asarray(member.trace)
            )

    def test_hyper_factory_grid(self):
        """An (alpha, step_size) grid built INSIDE the traced program via
        sampler_factory matches directly constructed samplers."""
        hyper = {"alpha": jnp.array([0.0, 1.0]), "eps": jnp.array([5e-3, 1e-2])}

        def factory(h):
            return core.ec_sghmc(step_size=h["eps"], alpha=h["alpha"], sync_every=4,
                                 friction=1.0, center_friction=1.0,
                                 noise_convention="eq6")

        grid = 2
        p0 = start((grid, K, 2))
        st0 = jax.vmap(lambda h, p: factory(h).init(p))(hyper, p0)
        keys = jnp.stack([jax.random.split(jax.random.PRNGKey(30 + i), STEPS)
                          for i in range(grid)])
        ex = ChainExecutor(sampler_factory=factory, grad_fn=lambda t, _b: grad_U(t),
                           trace_fn=lambda p: p, chunk_steps=32, key_mode="keys")
        res = ex.run(p0, st0, num_steps=STEPS, keys=keys, hyper=hyper)
        for i, (alpha, eps) in enumerate([(0.0, 5e-3), (1.0, 1e-2)]):
            direct = core.ec_sghmc(step_size=eps, alpha=alpha, sync_every=4,
                                   friction=1.0, center_friction=1.0,
                                   noise_convention="eq6")
            member = rollout(direct, grad_U, start(), num_steps=STEPS,
                             keys=keys[i], chunk_steps=32)
            np.testing.assert_allclose(
                np.asarray(res.trace)[i], np.asarray(member.trace),
                rtol=0, atol=1e-6,
            )


class TestAdaptationHook:
    """ISSUE-6: host-side adaptation at chunk boundaries (the FeedbackESS
    loop).  The hook must (a) never retrace the compiled chunk when only
    hyper VALUES change, (b) be bit-invisible when it is a no-op, and
    (c) actually close the diagnostics → dynamics loop."""

    def test_value_updates_do_not_retrace(self):
        """The compile-count pin: sampler_factory runs at TRACE time only,
        so its invocation count equals the number of chunk programs built —
        exactly one here, no matter how often adapt_fn swaps the step size."""
        calls = []

        def factory(h):
            calls.append(1)
            return core.sgld(step_size=h["eps"])

        keys = jax.random.split(jax.random.PRNGKey(40), STEPS)
        ex = ChainExecutor(sampler_factory=factory, grad_fn=lambda t, _b: grad_U(t),
                           trace_fn=lambda p: p, chunk_steps=16, key_mode="keys")
        boundaries = []

        def adapt(step_end, carry, h):
            boundaries.append(step_end)
            # new VALUE, same aval (jnp.float32 scalar) -> must not retrace
            return {"eps": jnp.asarray(1e-2 / (1.0 + len(boundaries)), jnp.float32)}

        p0 = start()
        st0 = core.sgld(step_size=1e-2).init(p0)
        hyper = {"eps": jnp.asarray(1e-2, jnp.float32)}
        res = ex.run(p0, st0, num_steps=STEPS, keys=keys, hyper=hyper,
                     sweep=False, adapt_fn=adapt)
        assert res.steps == STEPS
        assert len(calls) == 1, f"chunk retraced: factory ran {len(calls)}x"
        # hook fires at every boundary except the final one
        assert boundaries == list(range(16, STEPS, 16))

    def test_noop_adapter_bit_identical_chunked_vs_unchunked(self):
        """A constant schedule through the hook is invisible: chunked run
        with an adapter that re-submits the same value == one unchunked run
        with no adapter, bit-for-bit."""
        keys = jax.random.split(jax.random.PRNGKey(41), STEPS)

        def factory(h):
            return core.ec_sghmc(step_size=h["eps"], alpha=1.0, sync_every=4,
                                 friction=1.0, center_friction=1.0,
                                 noise_convention="eq6")

        outs = []
        for chunk, adapt in ((STEPS, None),
                             (16, lambda s, c, h: {"eps": jnp.asarray(h["eps"])})):
            ex = ChainExecutor(sampler_factory=factory,
                               grad_fn=lambda t, _b: grad_U(t),
                               trace_fn=lambda p: p, chunk_steps=chunk,
                               key_mode="keys")
            p0 = start()
            st0 = factory({"eps": jnp.asarray(1e-2, jnp.float32)}).init(p0)
            res = ex.run(p0, st0, num_steps=STEPS, keys=keys,
                         hyper={"eps": jnp.asarray(1e-2, jnp.float32)},
                         sweep=False, adapt_fn=adapt)
            outs.append(np.asarray(res.trace))
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_adapted_value_reaches_the_dynamics(self):
        """Zeroing the step size at the first boundary must freeze the SGLD
        chain for every later chunk — proof the replacement value feeds the
        traced program, not a stale closure."""
        keys = jax.random.split(jax.random.PRNGKey(42), STEPS)
        ex = ChainExecutor(sampler_factory=lambda h: core.sgld(step_size=h["eps"]),
                           grad_fn=lambda t, _b: grad_U(t),
                           trace_fn=lambda p: p, chunk_steps=16, key_mode="keys")
        p0 = start()
        st0 = core.sgld(step_size=1e-2).init(p0)
        res = ex.run(p0, st0, num_steps=STEPS, keys=keys,
                     hyper={"eps": jnp.asarray(1e-2, jnp.float32)}, sweep=False,
                     adapt_fn=lambda s, c, h: {"eps": jnp.asarray(0.0, jnp.float32)})
        traj = np.asarray(res.trace)
        assert not np.array_equal(traj[0], traj[15])  # moved while eps > 0
        np.testing.assert_array_equal(traj[16:], np.broadcast_to(traj[16], traj[16:].shape))

    def test_ess_feedback_adapter_closes_the_loop(self):
        """End-to-end FeedbackESS: in-carry streaming ESS -> controller
        update -> new step size in the next chunk's hyper."""
        controller = core.feedback_ess(1e-2, target_ess_rate=0.9, gain=0.5)
        ex = ChainExecutor(
            sampler_factory=lambda h: core.sghmc(step_size=h["step_size"], friction=1.0),
            grad_fn=lambda t, _b: grad_U(t), chunk_steps=256, key_mode="keys",
            ess_probe_fn=lambda p: p[0], ess_batch_len=32,
        )
        n = 1024
        keys = jax.random.split(jax.random.PRNGKey(43), n)
        p0 = start()
        st0 = core.sghmc(step_size=1e-2, friction=1.0).init(p0)
        res = ex.run(p0, st0, num_steps=n, keys=keys,
                     hyper={"step_size": jnp.asarray(controller.eps0, jnp.float32)},
                     sweep=False, adapt_fn=ess_feedback_adapter(controller))
        assert res.steps == n
        # an ESS rate of 0.9/step is unattainable -> the controller must
        # have grown eps, within bounds
        assert controller.value > controller.eps0
        assert controller.lo <= controller.value <= controller.hi

    def test_adapter_requires_ess_probe(self):
        ex = ChainExecutor(
            sampler_factory=lambda h: core.sghmc(step_size=h["step_size"], friction=1.0),
            grad_fn=lambda t, _b: grad_U(t), chunk_steps=16, key_mode="keys",
        )
        keys = jax.random.split(jax.random.PRNGKey(44), STEPS)
        p0 = start()
        st0 = core.sghmc(step_size=1e-2, friction=1.0).init(p0)
        controller = core.feedback_ess(1e-2, target_ess_rate=0.5)
        with pytest.raises(ValueError, match="ess_probe_fn"):
            ex.run(p0, st0, num_steps=STEPS, keys=keys,
                   hyper={"step_size": jnp.asarray(1e-2, jnp.float32)}, sweep=False,
                   adapt_fn=ess_feedback_adapter(controller))


_SHARDED_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro import core
    from repro.run import ChainExecutor

    MU = jnp.array([2.0, -1.0])
    K, SYNC, STEPS = 4, 4, 64
    assert jax.device_count() == 4, jax.device_count()
    mesh = jax.make_mesh((4,), ("chain",))

    sampler = core.ec_sghmc(step_size=1e-2, alpha=1.0, sync_every=SYNC,
                            noise_convention="eq6", chain_axis="chain")
    ex = ChainExecutor(sampler=sampler, grad_fn=lambda t, _b: t - MU,
                       moments=True, chunk_steps=STEPS, key_mode="fold")
    params = jnp.broadcast_to(jnp.array([-2.0, 3.0]), (K, 2)) + 0.0
    state = sampler.init(params)

    lowered = ex.lower_sharded(params, state, num_steps=STEPS,
                               key=jax.random.key(0), mesh=mesh)
    hlo = lowered.as_text()
    n_allreduce = hlo.count("all_reduce") + hlo.count("all-reduce")
    others = sum(hlo.count(op) for op in
                 ("all_gather", "all-gather", "all_to_all", "all-to-all",
                  "collective_permute", "collective-permute"))
    print(f"COLLECTIVES allreduce={n_allreduce} others={others}")

    params = jnp.broadcast_to(jnp.array([-2.0, 3.0]), (K, 2)) + 0.0
    state = sampler.init(params)
    res = ex.run_sharded(params, state, num_steps=2048, key=jax.random.key(0),
                         mesh=mesh)
    import repro.diagnostics as diag
    mean = np.asarray(diag.welford_mean(res.moments)).mean(axis=0)
    spread = float(np.abs(np.asarray(res.params) -
                          np.asarray(res.params).mean(0)).mean())
    ok = np.all(np.isfinite(np.asarray(res.params)))
    print(f"RUN ok={ok} mean0={mean[0]:.3f} mean1={mean[1]:.3f} spread={spread:.3f}")

    # nominally-replicated center state must stay bit-identical per shard:
    # the chain_axis sampler folds axis_index into per-chain noise ONLY,
    # so the shard-invariant step key gives every shard the same center
    # draw (check_rep=False would otherwise hide silent divergence)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import chain_specs

    params = jnp.broadcast_to(jnp.array([-2.0, 3.0]), (K, 2)) + 0.0
    tree = {"params": params, "state": sampler.init(params)}
    specs = chain_specs(tree, K, "chain")

    def chunk(key, tree):
        p, st = tree["params"], tree["state"]
        for t in range(2 * SYNC):
            rng = jax.random.fold_in(key, t)
            upd, st = sampler.update(p - MU, st, params=p, rng=rng)
            p = jax.tree.map(lambda a, u: a + u, p, upd)
        return jax.tree.map(lambda x: x[None], (st.center, st.center_momentum))

    cents = shard_map(chunk, mesh=mesh, in_specs=(P(), specs),
                      out_specs=P("chain"), check_rep=False)(
        jax.random.key(0), tree)
    diffs = [float(np.abs(np.asarray(c) - np.asarray(c)[0]).max()) for c in cents]
    print(f"CENTER maxdiff={max(diffs):.3e}")

    # compressed center exchange: the packed-int8 all_gather must be the
    # program's ONLY collective.  NB the lowered text is StableHLO MLIR and
    # the substring "all_gather" also appears in the instruction's
    # all_gather_dim attribute — count call sites, not substrings.
    from repro.distributed import int8_codec
    csampler = core.ec_sghmc(step_size=1e-2, alpha=1.0, sync_every=SYNC,
                             noise_convention="eq6", chain_axis="chain",
                             compression=int8_codec())
    cex = ChainExecutor(sampler=csampler, grad_fn=lambda t, _b: t - MU,
                        moments=True, chunk_steps=STEPS, key_mode="fold")
    params = jnp.broadcast_to(jnp.array([-2.0, 3.0]), (K, 2)) + 0.0
    state = csampler.init(params)
    chlo = cex.lower_sharded(params, state, num_steps=STEPS,
                             key=jax.random.key(0), mesh=mesh).as_text()
    c_allgather = chlo.count('"stablehlo.all_gather"(')
    c_allreduce = chlo.count("all_reduce") + chlo.count("all-reduce")
    c_others = sum(chlo.count(op) for op in
                   ("all_to_all", "all-to-all",
                    "collective_permute", "collective-permute"))
    print(f"CCOLLECTIVES allgather={c_allgather} allreduce={c_allreduce} "
          f"others={c_others}")

    params = jnp.broadcast_to(jnp.array([-2.0, 3.0]), (K, 2)) + 0.0
    state = csampler.init(params)
    cres = cex.run_sharded(params, state, num_steps=2048, key=jax.random.key(0),
                           mesh=mesh)
    cok = np.all(np.isfinite(np.asarray(cres.params)))
    cmean = np.asarray(diag.welford_mean(cres.moments)).mean(axis=0)
    print(f"CRUN ok={cok} mean0={cmean[0]:.3f} mean1={cmean[1]:.3f}")
""")


@pytest.mark.slow
class TestShardedCollective:
    """Acceptance criterion: under shard_map the s-periodic center sync is
    the program's ONLY cross-chain collective.  Runs in a subprocess so 4
    host devices can be forced without polluting this process's JAX."""

    @pytest.fixture(scope="class")
    def sharded_output(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
        ).strip()
        env["PYTHONPATH"] = (
            os.path.join(os.path.dirname(__file__), "..", "src")
            + os.pathsep + env.get("PYTHONPATH", "")
        )
        out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                             capture_output=True, text=True, env=env, timeout=600)
        assert out.returncode == 0, out.stderr[-3000:]
        return out.stdout

    def test_exactly_one_collective_in_scan_body(self, sharded_output):
        """The scan body appears once in the lowered program; the pmean of
        the sync branch must be its only collective (one per sync period at
        runtime), and no other collective kinds may appear."""
        line = [l for l in sharded_output.splitlines() if l.startswith("COLLECTIVES")][0]
        fields = dict(kv.split("=") for kv in line.split()[1:])
        assert int(fields["allreduce"]) == 1, line
        assert int(fields["others"]) == 0, line

    def test_sharded_run_stays_coupled(self, sharded_output):
        line = [l for l in sharded_output.splitlines() if l.startswith("RUN")][0]
        fields = dict(kv.split("=") for kv in line.split()[1:])
        assert fields["ok"] == "True"
        # alpha=1 coupling pulls the post-burn-in mean toward MU and keeps
        # chains from drifting apart
        assert abs(float(fields["mean0"]) - 2.0) < 0.5, line
        assert abs(float(fields["mean1"]) + 1.0) < 0.5, line
        assert float(fields["spread"]) < 3.0, line

    def test_compressed_exchange_single_all_gather(self, sharded_output):
        """With ``compression=int8_codec()`` the sync's packed exchange
        lowers to exactly ONE all_gather — no all_reduce, nothing else: the
        4x-smaller wire format does not cost a second collective."""
        line = [l for l in sharded_output.splitlines() if l.startswith("CCOLLECTIVES")][0]
        fields = dict(kv.split("=") for kv in line.split()[1:])
        assert int(fields["allgather"]) == 1, line
        assert int(fields["allreduce"]) == 0, line
        assert int(fields["others"]) == 0, line

    def test_compressed_run_stays_coupled(self, sharded_output):
        line = [l for l in sharded_output.splitlines() if l.startswith("CRUN")][0]
        fields = dict(kv.split("=") for kv in line.split()[1:])
        assert fields["ok"] == "True"
        assert abs(float(fields["mean0"]) - 2.0) < 0.5, line
        assert abs(float(fields["mean1"]) + 1.0) < 0.5, line

    def test_replicated_center_stays_replicated(self, sharded_output):
        """Center state is replicated by spec (check_rep=False hides
        violations): every shard must compute bit-identical center noise
        from the shard-invariant step key."""
        line = [l for l in sharded_output.splitlines() if l.startswith("CENTER")][0]
        assert float(line.split("=")[1]) == 0.0, line
