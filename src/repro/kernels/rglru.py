"""RG-LRU linear-recurrence Pallas kernel (recurrentgemma hot loop).

    h_t = a_t * h_{t-1} + x_t          (elementwise over channels)

TPU-native chunked scan: grid (B, R/rblk, S/sblk) with the sequence axis
innermost ("arbitrary"); each block computes its local prefix scan fully
vectorized (superposition: h = local_scan(x) + cumprod(a) * h_carry) and the
carry crosses blocks through VMEM scratch.  HBM traffic is exactly one read
of (a, x) and one write of h — XLA's associative_scan does log(S) passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams across jax releases;
# accept either so the kernels track the installed toolchain
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _scan_block(a, x):
    """Vectorized within-block scan: returns (h_local, cumprod_a).
    a, x: (sblk, rblk) f32; h assumes zero carry."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    A, h = jax.lax.associative_scan(combine, (a, x), axis=0)
    return h, A


def _rglru_kernel(a_ref, x_ref, h0_ref, o_ref, carry, *, num_sblocks):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        carry[...] = h0_ref[...].astype(jnp.float32)  # (1, rblk)

    a = a_ref[0].astype(jnp.float32)  # (sblk, rblk)
    x = x_ref[0].astype(jnp.float32)
    h_local, A = _scan_block(a, x)
    h = h_local + A * carry[...]  # (sblk, rblk) + (sblk,rblk)*(1,rblk)
    o_ref[0] = h.astype(o_ref.dtype)
    carry[...] = h[-1:, :]


def rglru_scan(a, x, h0=None, *, block_r: int = 128, block_s: int = 256, interpret: bool = True):
    """a, x: (B, S, R); h0: (B, R) or None. Returns h: (B, S, R)."""
    B, S, R = a.shape
    rblk = min(block_r, R)
    sblk = min(block_s, S)
    assert R % rblk == 0 and S % sblk == 0, (R, S, rblk, sblk)
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)
    grid = (B, R // rblk, S // sblk)
    kernel = functools.partial(_rglru_kernel, num_sblocks=S // sblk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, sblk, rblk), lambda b, r, t: (b, t, r)),
            pl.BlockSpec((1, sblk, rblk), lambda b, r, t: (b, t, r)),
            pl.BlockSpec((1, rblk), lambda b, r, t: (b, r)),
        ],
        out_specs=pl.BlockSpec((1, sblk, rblk), lambda b, r, t: (b, t, r)),
        out_shape=jax.ShapeDtypeStruct((B, S, R), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, rblk), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, x, h0)
