"""Satellite gate: identity preconditioning must be a NO-OP, bit-for-bit.

``rmsprop_preconditioner(decay=1.0, eps=0.0)`` holds V̂ at its all-ones
init and returns M⁻¹ exactly 1.0, and every adaptive sampler deliberately
groups its arithmetic so that multiplying by that runtime-1.0 array
reproduces the unpreconditioned sampler's float ops exactly (same RNG split
structure, same term association — see ``core.scale_adapted`` /
``core.preconditioned_sgld``).  Any drift in grouping, noise scaling, or
key plumbing breaks exact equality here long before it would move a
stationary moment.

Also pins ``schedules.feedback_ess`` frozen against ``schedules.constant``:
a frozen controller IS a constant schedule.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core

MU, PREC = 0.7, jnp.array([3.0, 0.5])
STEPS = 30
IDENTITY = dict(decay=1.0, precond_eps=0.0, burnin=10)  # v ≡ 1, M⁻¹ ≡ 1.0


def _grad(th):
    return PREC * (th - MU)


def _traj(sampler, shape=(4, 2), seed=0, grad=None):
    """EAGER step loop (no scan/jit): inside one fused program XLA may
    contract a*b+c into an FMA differently for the two graph shapes, which
    breaks strict bitwise comparison for reasons that have nothing to do
    with the samplers.  Op-by-op dispatch pins the actual term grouping."""
    grad = grad or _grad
    params = jax.random.normal(jax.random.PRNGKey(11), shape, jnp.float32)
    state = sampler.init(params)
    keys = jax.random.split(jax.random.PRNGKey(seed), STEPS)
    out = []
    for k in keys:
        g = grad(params)
        upd, state = sampler.update(g, state, params=params, rng=k)
        params = core.apply_updates(params, upd)
        out.append(np.asarray(params))
    return np.stack(out)


class TestIdentityPreconditioningBitExact:
    def test_sa_ec_sghmc_equals_ec_sghmc(self):
        """The tentpole pin: identity-preconditioned EC-SGHMC == plain
        EC-SGHMC bit-for-bit across sync boundaries (s=4) at T=1."""
        kw = dict(step_size=0.05, alpha=1.0, friction=1.0, center_friction=1.0,
                  sync_every=4, noise_convention="eq6", center_noise_in_p=True)
        t_pre = _traj(core.scale_adapted_ec_sghmc(**kw, **IDENTITY))
        t_ref = _traj(core.ec_sghmc(mass=1.0, **kw))
        np.testing.assert_array_equal(t_pre, t_ref)

    def test_sa_ec_sghmc_equals_ec_sghmc_fused(self):
        """Same pin through the fused kernels: the preconditioned Pallas
        kernel with M⁻¹ ≡ 1 must match the plain kernel bit-for-bit (same
        counter-bit noise streams — identical key-split structure in the
        tree wrappers)."""
        kw = dict(step_size=0.05, alpha=0.7, sync_every=2, fused=True)
        iso = lambda th: 1.3 * (th - MU)
        t_pre = _traj(core.scale_adapted_ec_sghmc(**kw, **IDENTITY), shape=(2, 128), grad=iso)
        t_ref = _traj(core.ec_sghmc(mass=1.0, **kw), shape=(2, 128), grad=iso)
        np.testing.assert_array_equal(t_pre, t_ref)

    def test_sa_sghmc_equals_sghmc(self):
        kw = dict(step_size=0.05, friction=1.5, noise_convention="eq4")
        t_pre = _traj(core.scale_adapted_sghmc(**kw, **IDENTITY))
        t_ref = _traj(core.sghmc(mass=1.0, **kw))
        np.testing.assert_array_equal(t_pre, t_ref)

    def test_psgld_equals_sgld(self):
        kw = dict(step_size=0.05, temperature=0.8)
        t_pre = _traj(core.preconditioned_sgld(**kw, **IDENTITY))
        t_ref = _traj(core.sgld(**kw))
        np.testing.assert_array_equal(t_pre, t_ref)

    def test_identity_minv_is_exactly_one(self):
        """The premise the pins rest on, stated directly."""
        p_init, p_update = core.rmsprop_preconditioner(decay=1.0, eps=0.0, burnin=10)
        st = p_init(jnp.zeros((3, 5)))
        minv, st = p_update(st, jnp.full((3, 5), 7.3))
        assert np.all(np.asarray(minv) == np.float32(1.0))
        minv, _ = p_update(st, jnp.full((3, 5), -123.4))
        assert np.all(np.asarray(minv) == np.float32(1.0))


class TestFeedbackESSFrozenIsConstant:
    def test_frozen_matches_constant_schedule(self):
        fb = core.feedback_ess(3e-3, target_ess_rate=0.1, freeze_at=0)
        fb.update(1e9, step=0)  # past freeze_at: freezes without moving
        const = core.constant(3e-3)
        for t in (0, 1, 17, 10_000):
            step = jnp.asarray(t, jnp.int32)
            np.testing.assert_array_equal(np.asarray(fb(step)), np.asarray(const(step)))

    def test_frozen_update_is_noop(self):
        fb = core.feedback_ess(1e-2, target_ess_rate=0.5)
        fb.freeze()
        before = fb.value
        for rate in (0.0, 0.25, 5.0):
            assert fb.update(rate) == before
        assert fb.value == before

    def test_unfrozen_update_moves_toward_target(self):
        fb = core.feedback_ess(1e-2, target_ess_rate=0.5, gain=0.5)
        v0 = fb.value
        fb.update(0.05)  # mixing too slow -> grow eps
        assert fb.value > v0
        v1 = fb.value
        fb.update(5.0)  # mixing plenty -> shrink back
        assert fb.value < v1

    def test_bounds_respected(self):
        fb = core.feedback_ess(1e-2, target_ess_rate=0.5, gain=10.0, bounds=(0.5, 2.0))
        for _ in range(50):
            fb.update(0.0)
        assert fb.value == pytest.approx(2e-2)
        for _ in range(50):
            fb.update(100.0)
        assert fb.value == pytest.approx(5e-3)

    def test_as_schedule_accepts_controller(self):
        """FeedbackESS satisfies the schedule protocol: ``as_schedule`` must
        pass it through untouched (idempotence on callables)."""
        fb = core.feedback_ess(2e-3, target_ess_rate=0.1)
        assert core.as_schedule(fb) is fb
