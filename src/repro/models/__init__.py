from . import encdec, layers, mlp, moe, recurrent, registry, resnet, transformer
from .common import (
    LayerKind,
    ModelConfig,
    ParamSpec,
    abstract_params,
    active_params,
    cast_specs,
    init_params,
    num_params,
    param_axes,
)
from .registry import ModelDef, get_model
