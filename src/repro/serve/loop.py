"""Serving steps: prefill (prompt -> cache) and greedy decode.

``decode_step``/``serve_step`` is what the decode_* and long_* dry-run cells
lower: one new token against a KV/recurrent cache of seq_len.

``ensemble_diagnostics`` reports the dispersion of a chain-ensemble before
it serves: a collapsed ensemble (zero spread) silently degrades Bayesian
model averaging to a single model, and the serving tier is where that must
be caught.

``collect_ensemble`` is the device-resident collection path: the sampler
run that produces the K ensemble members compiles as ONE chunked-scan
program (``repro.run.rollout``) with thinned trace collection — members
never round-trip to the host individually.  The interactive ``generate``
loop below is the single per-step Python loop this repo still allows."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.diagnostics import ensemble_spread
from repro.models import ModelDef
from repro.models.common import ModelConfig
from repro.run import rollout


def make_prefill_step(cfg: ModelConfig, model: ModelDef, max_seq: int, cache_dtype=None):
    def prefill_step(params, batch):
        logits, cache = model.prefill(cfg, params, batch, max_seq, cache_dtype)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, model: ModelDef):
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(cfg, params, cache, tokens)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, new_cache

    return serve_step


def ensemble_diagnostics(params_stack, *, min_rel_spread: float = 1e-6) -> dict:
    """Ensemble-spread health report for a (K, ...)-stacked posterior
    ensemble about to serve.  Returns the shared spread summary plus a
    ``collapsed`` flag — K identical samples waste K× serve compute for a
    single model's predictions."""
    out = ensemble_spread(params_stack)
    out["collapsed"] = bool(out["rel_spread"] < min_rel_spread)
    return out


def collect_ensemble(
    sampler,
    grad_fn,
    params0,
    *,
    num_samples: int,
    key,
    thin: int = 16,
    burn: int | None = None,
):
    """Draw ``num_samples`` ensemble members as thinned posterior samples of
    one device-resident sampler run.

    The whole run — burn-in, thinning, trace collection — is a single
    chunked ``lax.scan`` program; only the (num_samples, ...) member stack
    comes back to the host, stacked on a leading axis ready for
    ``ensemble_decode`` / ``ensemble_diagnostics``.  ``grad_fn(theta)``
    is the gradient of whatever potential the ensemble should target
    (posterior for a trained model, prior bootstrap for a demo).  ``burn``
    defaults to one thinning interval and is rounded up so every kept
    sample is post-burn-in."""
    if num_samples < 1 or thin < 1:
        raise ValueError("num_samples and thin must be >= 1")
    burn = thin if burn is None else thin * -(-burn // thin)  # ceil to a thin multiple
    steps = burn + num_samples * thin
    keys = jax.random.split(key, steps)
    res = rollout(
        sampler, grad_fn, params0,
        num_steps=steps, keys=keys, thin=thin, moments=False,
        chunk_steps=steps,
    )
    members = jax.tree.map(lambda a: jnp.asarray(a[-num_samples:]), res.trace)
    return members, res


def generate(cfg: ModelConfig, model: ModelDef, params, batch, max_seq: int, num_tokens: int):
    """Host-side greedy generation loop (examples / integration tests)."""
    prefill = jax.jit(make_prefill_step(cfg, model, max_seq))
    step = jax.jit(make_decode_step(cfg, model))
    tok, cache = prefill(params, batch)
    out = [tok]
    for _ in range(num_tokens - 1):
        tok, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
