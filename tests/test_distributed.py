"""Distribution-substrate tests: compression codec, EC tolerance to a
quantized center exchange (the paper's robustness argument), data pipeline
determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from util import import_hypothesis

given, settings, st = import_hypothesis()  # deterministic tests run bare

from repro import core
from repro.data import synthetic_token_stream
from repro.data.pipeline import ShardedLoader, chain_batches
from repro.distributed.compression import int8_codec
from util import gaussian_grad, run_sampler


class TestInt8Codec:
    @pytest.mark.parametrize("shape", [(100,), (8, 128), (3, 5, 7)])
    def test_roundtrip_error_bounded(self, shape):
        codec = int8_codec()
        x = jax.random.normal(jax.random.PRNGKey(0), shape) * 5.0
        y = codec.decode(codec.encode(x))
        assert y.shape == x.shape
        # error bounded by scale/2 per block (127 levels)
        blk_max = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(y - x))) <= blk_max / 127.0 + 1e-6

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 2000), scale=st.floats(1e-3, 1e3))
    def test_property_relative_error(self, n, scale):
        codec = int8_codec()
        x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * scale
        y = codec.decode(codec.encode(x))
        err = float(jnp.max(jnp.abs(y - x)))
        assert err <= scale * 0.2 + 1e-9  # per-block scales keep error local

    def test_wire_ratio(self):
        assert int8_codec().ratio < 0.3  # ~4x smaller than f32


class TestECWithCompressedSync:
    def test_stationary_mean_preserved(self):
        """Quantizing the center exchange must not bias the sampler mean —
        the quantization error acts as extra center noise C (paper §3)."""
        mu = jnp.array([2.0, -1.0])
        ec_plain = core.ec_sghmc(step_size=5e-2, alpha=1.0, sync_every=4)
        ec_comp = core.ec_sghmc(step_size=5e-2, alpha=1.0, sync_every=4,
                                compression=int8_codec())
        p0 = jnp.zeros((4, 2))
        t_plain = run_sampler(ec_plain, p0, gaussian_grad(mu), 6000, collect_from=2000)
        t_comp = run_sampler(ec_comp, p0, gaussian_grad(mu), 6000, collect_from=2000)
        m_plain = t_plain.reshape(-1, 2).mean(0)
        m_comp = t_comp.reshape(-1, 2).mean(0)
        np.testing.assert_allclose(m_comp, np.asarray(mu), atol=0.25)
        # and the two agree with each other
        np.testing.assert_allclose(m_comp, m_plain, atol=0.3)


class TestPipeline:
    def test_stateless_batches_are_deterministic(self):
        x = np.arange(1000, dtype=np.float32).reshape(100, 10)
        y = np.arange(100, dtype=np.int32) % 10
        l1 = ShardedLoader(x, y, batch_size=8, num_chains=3, seed=7)
        l2 = ShardedLoader(x, y, batch_size=8, num_chains=3, seed=7)
        b1, b2 = l1.batch(42), l2.batch(42)
        np.testing.assert_array_equal(np.asarray(b1["x"]), np.asarray(b2["x"]))
        assert b1["x"].shape == (3, 8, 10)

    def test_chains_get_different_data(self):
        x = np.random.default_rng(0).normal(size=(1000, 4)).astype(np.float32)
        y = np.zeros(1000, np.int32)
        b = ShardedLoader(x, y, batch_size=16, num_chains=4, seed=0).batch(0)
        flat = np.asarray(b["x"]).reshape(4, -1)
        for i in range(4):
            for j in range(i + 1, 4):
                assert not np.array_equal(flat[i], flat[j])

    def test_token_stream_resumable(self):
        s = synthetic_token_stream(1000, seed=3)
        a = chain_batches(s, 17, 2, 4, 32)
        b = chain_batches(s, 17, 2, 4, 32)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
        # labels are next-token shifted inputs
        np.testing.assert_array_equal(
            np.asarray(a["tokens"][..., 1:]), np.asarray(a["labels"][..., :-1])
        )

    def test_token_stream_in_vocab(self):
        s = synthetic_token_stream(257, seed=1)
        t = s(0, (64,))
        assert int(t.min()) >= 0 and int(t.max()) < 257
