"""Naive asynchronous SGHMC — the paper's "approach I" baseline (§2).

A parameter server holds a SINGLE chain (theta, p).  K workers each hold a
stale snapshot thetã^k of the server parameters (pulled when they last
pushed).  Every step, the workers whose round-robin phase matches
``t mod s`` push a stochastic gradient computed at their stale snapshot and
pull fresh parameters; the server averages the O arrived gradients and
advances Eq. 4 with them:

    ĝ_t = (1/O) sum_{k arrived} grad Ũ(thetã^k_t)      (staleness = s steps)

With s=1 (and K arriving every step) this is synchronous-parallel SGHMC and
keeps all guarantees; for s > 1 the stale gradients act as extra noise — the
regime where the paper shows this scheme breaks down while EC-SGHMC holds up
(Fig. 2 left, s=8).

SPMD emulation notes (DESIGN.md §2): worker snapshots are a (K, ...)-stacked
state; gradients must be evaluated at ``grad_targets(state, params)`` (the
snapshots), NOT at the server params — exactly the information pattern of a
real async parameter server.  Steps where no worker reports leave the server
dynamics idle (identity update), matching a waiting server.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .schedules import as_schedule
from .sghmc import _noise_scale
from .tree_util import tree_random_normal
from .types import Sampler


class AsyncSGHMCState(NamedTuple):
    momentum: any  # server-side p : (...)
    snapshots: any  # worker-side thetã^k : (K, ...)
    step: jnp.ndarray


def async_sghmc(
    step_size,
    num_workers: int,
    friction: float = 1.0,
    mass: float = 1.0,
    sync_every: int = 1,  # s : staleness / communication period
    temperature: float = 1.0,
    noise_convention: str = "eq4",
) -> Sampler:
    schedule = as_schedule(step_size)
    minv = 1.0 / mass
    s = int(sync_every)
    K = int(num_workers)
    # round-robin phases: worker k reports at steps t with t % s == k % s
    phases = jnp.arange(K) % s

    def init(params):
        return AsyncSGHMCState(
            momentum=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            snapshots=jax.tree.map(
                lambda p: jnp.broadcast_to(p[None].astype(jnp.float32), (K,) + p.shape),
                params,
            ),
            step=jnp.zeros((), jnp.int32),
        )

    def grad_targets(state, params):
        del params
        return state.snapshots

    def update(grads, state, params, rng):
        """``grads`` have a leading worker axis K (evaluated at snapshots)."""
        eps = schedule(state.step)
        arrived = (state.step % s) == phases  # (K,) bool
        n_arrived = jnp.sum(arrived.astype(jnp.float32))
        any_arrived = n_arrived > 0

        def avg(g):
            w = arrived.astype(jnp.float32).reshape((K,) + (1,) * (g.ndim - 1))
            return jnp.sum(w * g.astype(jnp.float32), axis=0) / jnp.maximum(n_arrived, 1.0)

        ghat = jax.tree.map(avg, grads)

        sigma = temperature**0.5 * _noise_scale(eps, friction, 0.0, noise_convention)
        noise = tree_random_normal(rng, state.momentum, jnp.float32)

        gate = any_arrived.astype(jnp.float32)  # idle server <=> identity step
        updates = jax.tree.map(lambda p: gate * eps * minv * p, state.momentum)
        new_momentum = jax.tree.map(
            lambda p, g, n: p
            + gate * (-eps * g - eps * friction * minv * p + sigma * n),
            state.momentum,
            ghat,
            noise,
        )
        # arrived workers pull the post-update server params
        new_params = jax.tree.map(lambda th, u: th.astype(jnp.float32) + u, params, updates)
        new_snapshots = jax.tree.map(
            lambda snap, th: jnp.where(
                arrived.reshape((K,) + (1,) * (th.ndim)), th[None], snap
            ),
            state.snapshots,
            new_params,
        )
        return updates, AsyncSGHMCState(
            momentum=new_momentum, snapshots=new_snapshots, step=state.step + 1
        )

    return Sampler(init, update, grad_targets)
