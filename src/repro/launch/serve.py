"""Serving launcher: batched greedy decoding (+ optional chain-ensemble
posterior averaging — serve K posterior samples, average the predictive
distribution: Bayesian model averaging, the reason one samples posteriors
at all).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 16 --gen 8 --ensemble 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro import core
from repro.models import get_model, init_params
from repro.serve.loop import (
    collect_ensemble,
    ensemble_diagnostics,
    make_decode_step,
    make_prefill_step,
)

# prior-bootstrap ensemble: members are thinned SGLD draws from
# N(params_init, PRIOR_SCALE^2 I) — a posterior stand-in when no sampled
# checkpoint is supplied; the spread matches the init scale so BMA is
# exercised with realistic dispersion.
PRIOR_SCALE = 0.02
_PREC = 1.0 / PRIOR_SCALE**2
_EPS = 0.2 / _PREC  # eps*lam = 0.2: stable, mixes in ~5 steps


def _bootstrap_ensemble(specs, key, num: int):
    center = init_params(specs, key)
    grad_fn = lambda p: jax.tree.map(lambda x, x0: _PREC * (x - x0), p, center)
    start = jax.tree.map(lambda x: x + 0.0, center)  # rollout donates its input
    members, res = collect_ensemble(
        core.sgld(step_size=_EPS), grad_fn, start,
        num_samples=num, key=jax.random.fold_in(key, 1), thin=16,
    )
    return members, res


def ensemble_decode(cfg, model, params_stack, batch, max_seq: int, num_tokens: int):
    """Average predictive probs over the chain/ensemble axis of params."""
    k = jax.tree.leaves(params_stack)[0].shape[0]

    def prefill_one(p):
        return model.prefill(cfg, p, batch, max_seq)

    logits, caches = jax.vmap(prefill_one)(params_stack)
    probs = jnp.mean(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=0)
    tok = jnp.argmax(probs[:, -1], -1).astype(jnp.int32)[:, None]
    out = [tok]

    def step_one(p, c, t):
        return model.decode_step(cfg, p, c, t)

    vstep = jax.jit(jax.vmap(step_one, in_axes=(0, 0, None)))
    for _ in range(num_tokens - 1):
        logits, caches = vstep(params_stack, caches, tok)
        probs = jnp.mean(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=0)
        tok = jnp.argmax(probs[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--ensemble", type=int, default=1, help="posterior samples to average")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    max_seq = args.prompt_len + args.gen + 1
    key = jax.random.PRNGKey(args.seed)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.02 * jax.random.normal(key, (args.batch, cfg.enc_seq, cfg.d_model))

    t0 = time.time()
    if args.ensemble > 1:
        # device-resident collection: one compiled sampler run, thinned
        # trace = the ensemble (repro.serve.loop.collect_ensemble)
        params, res = _bootstrap_ensemble(
            model.param_specs(cfg), jax.random.PRNGKey(args.seed), args.ensemble
        )
        health = ensemble_diagnostics(params)
        print(
            f"ensemble: K={health['num_chains']} spread={health['chain_spread']:.3e} "
            f"rel={health['rel_spread']:.3e} "
            f"(collected at {res.steps_per_s:.0f} steps/s)"
            + (" [COLLAPSED — BMA is a no-op]" if health["collapsed"] else "")
        )
        toks = ensemble_decode(cfg, model, params, batch, max_seq, args.gen)
    else:
        params = init_params(model.param_specs(cfg), key)
        prefill = jax.jit(make_prefill_step(cfg, model, max_seq))
        step = jax.jit(make_decode_step(cfg, model))
        tok, cache = prefill(params, batch)
        out = [tok]
        for _ in range(args.gen - 1):
            tok, cache = step(params, cache, tok)
            out.append(tok)
        toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s, ensemble={args.ensemble})")
    print(toks)
    return toks


if __name__ == "__main__":
    main()
