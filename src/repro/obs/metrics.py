"""Typed metrics registry with a namespaced key scheme.

Canonical naming contract (DESIGN.md §11):

* keys are ``<subsystem>.<object>.<metric>`` — e.g.
  ``serve.alloc.blocks_high_water``;
* monotone counts end in ``_total``;
* high-water gauges end in ``_high_water``;
* histograms carry a unit suffix (``_s``, ``_bytes``).

Every pre-existing ``stats()`` dict in the repo predates this scheme and
drifted (``high_water`` vs ``bytes_high_water`` vs ``blocks_high_water``,
bare counts vs ``_total``).  Rather than break the keys tests and benches
pin, :func:`absorb` maps each legacy dict into canonical metrics through a
per-namespace rename table; the legacy dicts stay as-is at their call
sites and the registry is the single place the canonical names exist.

Everything here is plain host-side Python — values entering ``absorb``/
``observe`` may be jnp scalars (they are coerced via ``float``/``int``,
which blocks only on already-materialized chunk-boundary stats, never on
in-flight decode work).
"""
from __future__ import annotations

import json
import math


class Counter:
    """Monotone count.  ``inc`` by non-negative amounts only."""

    __slots__ = ("name", "value")

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, amount: int = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment {amount}")
        self.value += int(amount)


class Gauge:
    """Last-written value (plus an optional high-water companion)."""

    __slots__ = ("name", "value")

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value) -> None:
        self.value = float(value)


class Histogram:
    """Fixed log-spaced-bucket histogram with streaming count/sum/min/max
    and interpolated quantiles.  Buckets span [lo, hi] in ``n`` decades-ish
    geometric steps; underflow/overflow land in the edge buckets."""

    __slots__ = ("name", "edges", "counts", "count", "total", "min", "max")

    kind = "histogram"

    def __init__(self, name: str, lo: float = 1e-6, hi: float = 1e3, n: int = 64):
        if not (0 < lo < hi):
            raise ValueError("need 0 < lo < hi")
        self.name = name
        ratio = (hi / lo) ** (1.0 / n)
        self.edges = [lo * ratio**i for i in range(n + 1)]
        self.counts = [0] * n
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        # geometric bucket index, clamped to the edge buckets
        if v <= self.edges[0]:
            i = 0
        elif v >= self.edges[-1]:
            i = len(self.counts) - 1
        else:
            lo, ratio = self.edges[0], self.edges[1] / self.edges[0]
            i = min(len(self.counts) - 1, int(math.log(v / lo, ratio)))
        self.counts[i] += 1

    def quantile(self, q: float) -> float:
        """Within-bucket linearly interpolated quantile; NaN when empty."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c > 0:
                frac = (target - seen) / c
                return self.edges[i] + frac * (self.edges[i + 1] - self.edges[i])
            seen += c
        return self.max

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "mean": self.mean if self.count else None,
            "p50": self.quantile(0.5) if self.count else None,
            "p99": self.quantile(0.99) if self.count else None,
        }


# legacy stats()-dict key -> canonical metric name, per namespace.  A key
# absent from its table passes through under its own (already canonical)
# name; values that are not numeric are skipped (e.g. device strings).
RENAMES = {
    "serve.pool": {
        "num_slots": "slots",
        "active": "slots_active",
        "high_water": "slots_high_water",
        "acquired": "slots_acquired_total",
        "released": "slots_released_total",
        "parked": "members_parked",
        "restored": "members_restored_total",
    },
    "serve.alloc": {
        "prefix_queries": "prefix_queries_total",
        "prefix_hits": "prefix_hits_total",
        "shared_block_hits": "shared_block_hits_total",
        "prefix_invalidated": "prefix_invalidated_total",
    },
    "serve.registry": {
        "promoted": "promotions_total",
        "rejected": "rejections_total",
        "staged_total": "staged_total",
    },
    "serve.refresh": {
        "refreshes": "refreshes_total",
        "micro_chunks": "micro_chunks_total",
        "micro_steps": "micro_steps_total",
        "steps_done": "steps_total",
        "backpressure_ticks": "backpressure_ticks_total",
        "flips_deferred": "flips_deferred_total",
        "decode_steps_stalled": "decode_steps_stalled_total",
        "promotions": "promotions_total",
        "proposals": "proposals_total",
        "rejections": "rejections_total",
    },
    "serve.engine": {
        "decode_steps": "decode_steps_total",
        "total_tokens": "tokens_total",
        "admitted": "admitted_total",
        "retired": "retired_total",
    },
    "executor": {
        "chunks": "chunks_total",
        "steps": "steps_total",
    },
}

_COUNTER_SUFFIX = "_total"


class MetricsRegistry:
    """Get-or-create store of named metrics.  Type mismatches on an
    existing name raise — a key is a counter or a gauge, never both."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.kind}, not a {cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, lo: float = 1e-6, hi: float = 1e3, n: int = 64) -> Histogram:
        return self._get(name, Histogram, lo, hi, n)

    def absorb(self, namespace: str, stats: dict) -> None:
        """Fold a legacy ``stats()`` dict into canonical metrics under
        ``namespace``.  Counters are SET to the source's running total
        (legacy dicts are cumulative already), so absorbing twice is
        idempotent rather than double-counting."""
        table = RENAMES.get(namespace, {})
        for key, value in stats.items():
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                try:
                    value = float(value)
                except (TypeError, ValueError):
                    continue
                if value != value:  # NaN
                    continue
            name = f"{namespace}.{table.get(key, key)}"
            if name.endswith(_COUNTER_SUFFIX):
                c = self.counter(name)
                c.value = int(value)
            else:
                self.gauge(name).set(value)

    def snapshot(self) -> dict:
        """Flat ``{name: value-or-summary}`` dict, sorted by name."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def dump_jsonl(self, path) -> None:
        with open(path, "a") as f:
            f.write(json.dumps({"kind": "metrics", **self.snapshot()}) + "\n")


_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _REGISTRY


def reset_default() -> MetricsRegistry:
    global _REGISTRY
    _REGISTRY = MetricsRegistry()
    return _REGISTRY
