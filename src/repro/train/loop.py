"""The training loop: sampler-driven posterior sampling with fault
tolerance (atomic checkpoints, auto-resume, simulated preemption) and
elastic chain scaling."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply_updates
from . import checkpoint as ckpt_lib


@dataclass
class LoopConfig:
    num_steps: int = 200
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    preempt_at: Optional[int] = None  # simulate a kill after this step
    seed: int = 0


class Preempted(RuntimeError):
    pass


def run(
    train_step: Callable,  # (params, state, batch, rng) -> (params, state, metrics)
    init_params,
    init_state,
    batch_fn: Callable,  # (step) -> batch
    cfg: LoopConfig,
    num_chains: int = 1,
    alpha: float = 1.0,
):
    """Returns (params, state, history).  Auto-resumes from cfg.ckpt_dir."""
    params, state = init_params, init_state
    start = 0
    if cfg.ckpt_dir:
        got = ckpt_lib.restore_elastic(
            cfg.ckpt_dir, params, state, num_chains=num_chains, alpha=alpha, seed=cfg.seed
        )
        if got is not None:
            start, params, state, extra = got
            print(f"[loop] resumed from step {start}" + (" (elastic)" if extra.get("elastic_resample") else ""))

    step_jit = jax.jit(train_step, donate_argnums=(0, 1))
    key = jax.random.key(cfg.seed)
    history = []
    t0 = time.time()
    for t in range(start, cfg.num_steps):
        batch = batch_fn(t)
        params, state, metrics = step_jit(params, state, batch, jax.random.fold_in(key, t))
        if cfg.ckpt_dir and (t + 1) % cfg.ckpt_every == 0:
            ckpt_lib.save(cfg.ckpt_dir, t + 1, params, state)
            ckpt_lib.prune(cfg.ckpt_dir, cfg.keep_ckpts)
        if (t + 1) % cfg.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = t + 1
            m["wall_s"] = round(time.time() - t0, 2)
            history.append(m)
            print(f"[loop] step {t+1}: " + " ".join(f"{k}={v:.5g}" for k, v in m.items() if k != "step"))
        if cfg.preempt_at is not None and (t + 1) == cfg.preempt_at:
            raise Preempted(f"simulated preemption at step {t + 1}")
    return params, state, history
