"""Property suite for ``core.schedules`` and ``core.preconditioner``.

Hypothesis-driven where available (``tests/util.import_hypothesis`` supplies
no-op stubs otherwise), with deterministic fallbacks so a bare environment
still exercises every contract:

- Welling–Teh schedules: strictly positive and non-increasing for
  γ ∈ (0.5, 1] over any step range.
- ``as_schedule``: idempotent on callables, exact (bit-level f32) on floats.
- Preconditioners: M⁻¹ strictly positive for arbitrary gradients, and
  BIT-FROZEN for every step ≥ burnin — the invariant the frozen-
  preconditioner oracle (``repro.diagnostics.oracle``) rests on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import schedules

from util import import_hypothesis

given, settings, st = import_hypothesis()


def _steps(lo=0, hi=5000, n=64):
    return jnp.linspace(lo, hi, n).astype(jnp.int32)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


class TestPolynomialDecay:
    @given(
        a=st.floats(1e-5, 10.0, allow_nan=False, allow_infinity=False),
        b=st.floats(1.0, 100.0, allow_nan=False, allow_infinity=False),
        gamma=st.floats(0.5, 1.0, exclude_min=True, allow_nan=False),
    )
    @settings(max_examples=50, deadline=None)
    def test_positive_and_nonincreasing(self, a, b, gamma):
        sched = schedules.polynomial_decay(a, b, gamma)
        eps = np.asarray(jax.vmap(sched)(_steps()))
        assert np.all(eps > 0.0)
        assert np.all(np.diff(eps) <= 0.0)

    def test_positive_and_nonincreasing_deterministic(self):
        for gamma in (0.51, 0.75, 1.0):
            sched = schedules.polynomial_decay(1e-2, 10.0, gamma)
            eps = np.asarray(jax.vmap(sched)(_steps()))
            assert np.all(eps > 0.0)
            assert np.all(np.diff(eps) <= 0.0)

    def test_matches_closed_form(self):
        sched = schedules.polynomial_decay(0.5, 4.0, 0.75)
        t = jnp.asarray(100, jnp.int32)
        np.testing.assert_allclose(
            np.asarray(sched(t)), 0.5 * (4.0 + 100.0) ** (-0.75), rtol=1e-6
        )


class TestAsSchedule:
    def test_idempotent_on_callables(self):
        for f in (
            schedules.constant(1e-3),
            schedules.polynomial_decay(1e-2, 10.0, 0.75),
            schedules.feedback_ess(1e-3, target_ess_rate=0.1),
        ):
            assert schedules.as_schedule(f) is f

    @given(x=st.floats(1e-8, 1e3, allow_nan=False, allow_infinity=False))
    @settings(max_examples=50, deadline=None)
    def test_exact_on_floats(self, x):
        sched = schedules.as_schedule(x)
        got = np.asarray(sched(jnp.asarray(7, jnp.int32)))
        assert got == np.float32(x)

    def test_exact_on_floats_deterministic(self):
        for x in (3e-4, 1.0, 123.456):
            got = np.asarray(schedules.as_schedule(x)(jnp.asarray(0, jnp.int32)))
            assert got == np.float32(x)


# ---------------------------------------------------------------------------
# preconditioners
# ---------------------------------------------------------------------------

FAMILIES = ["rmsprop", "adam"]


def _factory(name, *, burnin=8, decay=0.9, eps=1e-8):
    return core.get_preconditioner(name, burnin=burnin, decay=decay, eps=eps)


def _grad_stream(shape, n, seed=0, scale=1.0):
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    return [scale * jax.random.normal(k, shape, jnp.float32) for k in keys]


class TestPreconditionerPositivity:
    @pytest.mark.parametrize("name", FAMILIES)
    def test_minv_strictly_positive(self, name):
        p_init, p_update = _factory(name)
        state = p_init(jnp.zeros((4, 3)))
        for g in _grad_stream((4, 3), 20, seed=1, scale=10.0):
            minv, state = p_update(state, g)
            m = np.asarray(minv)
            assert np.all(np.isfinite(m))
            assert np.all(m > 0.0)

    @pytest.mark.parametrize("name", FAMILIES)
    def test_minv_positive_for_zero_grads(self, name):
        """eps keeps M⁻¹ finite even when V̂ collapses to ~0 (adam inits at
        zero; zero gradients never grow it)."""
        p_init, p_update = _factory(name)
        state = p_init(jnp.zeros(5))
        for _ in range(3):
            minv, state = p_update(state, jnp.zeros(5))
        m = np.asarray(minv)
        assert np.all(np.isfinite(m)) and np.all(m > 0.0)

    @given(scale=st.floats(1e-3, 1e3, allow_nan=False, allow_infinity=False))
    @settings(max_examples=25, deadline=None)
    def test_minv_positive_across_grad_scales(self, scale):
        for name in FAMILIES:
            p_init, p_update = _factory(name)
            state = p_init(jnp.zeros(7))
            for g in _grad_stream((7,), 5, seed=3, scale=scale):
                minv, state = p_update(state, g)
                assert np.all(np.asarray(minv) > 0.0)


class TestPreconditionerFreeze:
    @pytest.mark.parametrize("name", FAMILIES)
    def test_minv_bit_frozen_after_burnin(self, name):
        """For every step ≥ burnin the returned M⁻¹ must be BIT-identical no
        matter what gradients arrive — the frozen-preconditioner oracle
        contract (DESIGN.md §6)."""
        burnin = 6
        p_init, p_update = _factory(name, burnin=burnin)
        state = p_init(jnp.zeros((2, 4)))
        grads = _grad_stream((2, 4), burnin + 10, seed=5, scale=3.0)
        frozen = None
        for t, g in enumerate(grads):
            minv, state = p_update(state, g)
            if t == burnin:
                frozen = np.asarray(minv)
                frozen_v = np.asarray(state.v)
            elif t > burnin:
                np.testing.assert_array_equal(np.asarray(minv), frozen)
                np.testing.assert_array_equal(np.asarray(state.v), frozen_v)

    @pytest.mark.parametrize("name", FAMILIES)
    def test_adapts_before_burnin(self, name):
        """The freeze test is vacuous unless V̂ actually moves pre-burnin."""
        p_init, p_update = _factory(name, burnin=100)
        state = p_init(jnp.zeros(3))
        v0 = np.asarray(state.v)
        _, state = p_update(state, jnp.full(3, 2.0))
        assert not np.array_equal(np.asarray(state.v), v0)

    def test_frozen_mass_inv_matches_update_output(self):
        """``frozen_mass_inv`` must reproduce the rmsprop formula exactly —
        it is how the battery feeds the oracle."""
        p_init, p_update = _factory("rmsprop", burnin=4, eps=1e-8)
        state = p_init(jnp.zeros(6))
        for g in _grad_stream((6,), 8, seed=7):
            minv, state = p_update(state, g)
        np.testing.assert_array_equal(
            np.asarray(core.frozen_mass_inv(state, eps=1e-8)), np.asarray(minv)
        )

    def test_unknown_family_raises(self):
        with pytest.raises(ValueError, match="unknown preconditioner"):
            core.get_preconditioner("nesterov", burnin=1, decay=0.9, eps=1e-8)
