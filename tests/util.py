"""Shared test helpers."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import core

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
MULTIDEVICE_DEVICES = 8


def multidevice_env(n: int = MULTIDEVICE_DEVICES) -> dict:
    """Subprocess environment for the forced-``n``-device harness: CPU
    platform with ``--xla_force_host_platform_device_count=n`` plus the
    child marker that un-skips ``@pytest.mark.multidevice`` tests."""
    from repro.launch.mesh import forced_device_env

    env = forced_device_env(n)
    env["REPRO_MULTIDEVICE_CHILD"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(REPO_ROOT / "src"), env.get("PYTHONPATH")) if p
    )
    return env


def require_devices(n: int = MULTIDEVICE_DEVICES):
    """Graceful in-child skip when forcing did not take (e.g. the user
    pinned ``JAX_PLATFORMS`` to a non-CPU plugin, where the forced-host-
    device flag does not exist).  Returns the device list otherwise."""
    import pytest

    devs = jax.devices()
    if len(devs) < n:
        pytest.skip(
            f"needs {n} devices, backend has {len(devs)} "
            "(forced host-device count unavailable on this platform)"
        )
    return devs


def run_multidevice_suite(extra_args=(), n: int = MULTIDEVICE_DEVICES, timeout: int = 900):
    """Re-launch pytest in a forced-``n``-device subprocess over the
    ``multidevice``-marked subset; returns CompletedProcess.  This is the
    single entry point shared by the CI lane and the slow relaunch proxy."""
    cmd = [
        sys.executable,
        "-m",
        "pytest",
        "-q",
        "-m",
        "multidevice",
        *extra_args,
    ]
    return subprocess.run(
        cmd,
        cwd=str(REPO_ROOT),
        env=multidevice_env(n),
        capture_output=True,
        text=True,
        timeout=timeout,
    )


def run_sampler(sampler, params, grad_fn, num_steps, seed=0, collect_from=0):
    """Drive a sampler with exact gradients via lax.scan; return trajectory
    (num_steps, *params.shape) of the param vector."""
    state = sampler.init(params)

    def body(carry, key):
        p, st = carry
        targets = sampler.grad_targets(st, p) if sampler.grad_targets else p
        g = grad_fn(targets)
        upd, st = sampler.update(g, st, params=p, rng=key)
        p = core.apply_updates(p, upd)
        return (p, st), p

    keys = jax.random.split(jax.random.PRNGKey(seed), num_steps)
    (_, _), traj = jax.lax.scan(body, (params, state), keys)
    return np.asarray(traj[collect_from:])


def gaussian_grad(mu, prec=1.0):
    """grad U for N(mu, prec^-1 I): U = 0.5 * prec * ||x - mu||^2.
    Handles a leading chain axis transparently (elementwise)."""

    def grad(theta):
        return prec * (theta - mu)

    return grad


def import_hypothesis():
    """(given, settings, st) — real hypothesis when installed, else no-op
    stubs that mark @given tests as skipped.  Unlike a module-level
    ``pytest.importorskip``, this keeps every DETERMINISTIC test in a
    property-test module running in a bare environment (the kernel-vs-
    reference and codec round-trip checks must not vanish just because
    requirements-dev.txt isn't installed)."""
    try:
        from hypothesis import given, settings, strategies as st

        return given, settings, st
    except ModuleNotFoundError:
        import pytest

        def given(*args, **kwargs):
            del args, kwargs
            return pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")

        def settings(*args, **kwargs):
            del args, kwargs
            return lambda f: f

        class _StrategyStub:
            """st.integers(...) etc. evaluate at decoration time; any
            attribute is a callable returning None."""

            def __getattr__(self, name):
                return lambda *a, **k: None

        return given, settings, _StrategyStub()
