"""Unit battery for repro.diagnostics: Welford streaming moments, FFT-ESS /
split-R̂, the Gaussian-target oracle's self-consistency, sampler stats
hooks, and spread summaries.  (The oracle-vs-sampler acceptance gate lives
in tests/test_stationary.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro import diagnostics as diag


class TestWelford:
    def _stream(self, xs):
        st = diag.welford_init(xs[0])
        for x in xs:
            st = diag.welford_add(st, x)
        return st

    def test_matches_numpy(self):
        xs = np.random.default_rng(0).normal(2.0, 3.0, (500, 7)).astype(np.float32)
        st = self._stream(list(xs))
        np.testing.assert_allclose(np.asarray(diag.welford_mean(st)), xs.mean(0), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(diag.welford_var(st)), xs.var(0), rtol=1e-4)
        np.testing.assert_allclose(
            np.asarray(diag.welford_var(st, ddof=1)), xs.var(0, ddof=1), rtol=1e-4
        )

    def test_pytree_structure(self):
        tree = {"w": jnp.ones((3, 2)), "b": {"x": jnp.arange(4.0)}}
        st = diag.welford_init(tree)
        st = diag.welford_add(st, tree)
        st = diag.welford_add(st, jax.tree.map(lambda x: 3.0 * x, tree))
        mean = diag.welford_mean(st)
        assert jax.tree.structure(mean) == jax.tree.structure(tree)
        np.testing.assert_allclose(np.asarray(mean["w"]), 2.0 * np.ones((3, 2)))
        np.testing.assert_allclose(np.asarray(diag.welford_var(st)["b"]["x"]),
                                   np.arange(4.0) ** 2)

    def test_scan_compatible(self):
        """The accumulator must ride as a lax.scan carry (the streaming
        use-case: moments over a million steps with O(1) memory)."""
        samples = jax.random.normal(jax.random.PRNGKey(0), (200, 5))

        def body(st, x):
            return diag.welford_add(st, x), ()

        st0 = diag.welford_init(samples[0])
        st, _ = jax.lax.scan(body, st0, samples)
        ref = np.asarray(samples)
        np.testing.assert_allclose(np.asarray(diag.welford_mean(st)), ref.mean(0), atol=1e-5)
        np.testing.assert_allclose(np.asarray(diag.welford_var(st)), ref.var(0), rtol=1e-4)

    def test_merge_equals_whole(self):
        xs = np.random.default_rng(1).normal(size=(300, 4)).astype(np.float32)
        a = self._stream(list(xs[:120]))
        b = self._stream(list(xs[120:]))
        merged = diag.welford_merge(a, b)
        whole = self._stream(list(xs))
        assert float(merged.count) == 300
        np.testing.assert_allclose(
            np.asarray(diag.welford_mean(merged)), np.asarray(diag.welford_mean(whole)), rtol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(diag.welford_var(merged)), np.asarray(diag.welford_var(whole)), rtol=1e-4
        )

    def test_merge_with_empty(self):
        xs = [np.float32(v) for v in (1.0, 2.0, 3.0)]
        st = self._stream(xs)
        empty = diag.welford_init(xs[0])
        for m in (diag.welford_merge(st, empty), diag.welford_merge(empty, st)):
            assert float(m.count) == 3
            np.testing.assert_allclose(float(diag.welford_mean(m)), 2.0, rtol=1e-6)

    def test_chain_summary_pools_leading_axis(self):
        """Leaves carry the repo's (K, ...) chain axis; pooled variance must
        equal the flat variance over (chains x time)."""
        rng = np.random.default_rng(2)
        k, t, d = 3, 400, 2
        xs = rng.normal(size=(t, k, d)).astype(np.float32)
        xs += rng.normal(size=(1, k, 1)) * 2.0  # distinct per-chain offsets
        st = self._stream(list(xs))
        cs = diag.chain_summary(st)
        flat = xs.transpose(1, 0, 2).reshape(k * t, d)
        np.testing.assert_allclose(np.asarray(cs.pooled_mean), flat.mean(0), atol=1e-5)
        np.testing.assert_allclose(np.asarray(cs.pooled_var), flat.var(0), rtol=1e-3)
        assert np.all(np.asarray(cs.between_chain_var) > np.asarray(cs.within_chain_var) * 0.5)


class TestESS:
    def test_iid_is_about_n(self):
        x = np.random.default_rng(0).normal(size=(4, 2000))
        ess = diag.effective_sample_size(x)
        assert 0.5 * x.size < ess <= 1.6 * x.size

    def test_ar1_matches_theory(self):
        """AR(1) with coefficient rho has ESS = N (1-rho)/(1+rho)."""
        rng = np.random.default_rng(1)
        rho, n, m = 0.9, 50_000, 2
        x = np.zeros((m, n))
        for c in range(m):
            z = rng.normal(size=n)
            for t in range(1, n):
                z[t] = rho * z[t - 1] + np.sqrt(1 - rho**2) * z[t]
            x[c] = z
        ess = diag.effective_sample_size(x)
        expected = m * n * (1 - rho) / (1 + rho)
        assert 0.6 * expected < ess < 1.6 * expected

    def test_disagreeing_chains_deflate_ess(self):
        rng = np.random.default_rng(2)
        x = rng.normal(size=(4, 1000))
        shifted = x + np.array([[0.0], [5.0], [-5.0], [10.0]])
        assert diag.effective_sample_size(shifted) < 0.05 * diag.effective_sample_size(x)

    def test_constant_chain_no_crash(self):
        assert diag.effective_sample_size(np.ones((2, 100))) > 0

    def test_coupled_ess_discounts_correlated_chains(self):
        """Perfectly co-moving chains carry ONE chain of information: the
        pooled estimator reports ~K x, coupled_ess must not."""
        rng = np.random.default_rng(8)
        n, rho = 20_000, 0.8
        z = rng.normal(size=n)
        for t in range(1, n):
            z[t] = rho * z[t - 1] + np.sqrt(1 - rho**2) * z[t]
        x = np.stack([z] * 4)  # 4 identical "chains"
        single = diag.effective_sample_size(z)
        coupled = diag.coupled_ess(x)
        pooled = diag.effective_sample_size(x)
        assert coupled == pytest.approx(single, rel=1e-6)
        assert pooled > 2.5 * coupled  # the overstatement coupled_ess avoids

    def test_nd_shapes(self):
        x = np.random.default_rng(3).normal(size=(2, 500, 3, 2))
        ess = diag.effective_sample_size_nd(x)
        assert ess.shape == (3, 2) and np.all(ess > 0)
        rh = diag.split_rhat_nd(x)
        assert rh.shape == (3, 2) and np.all(np.isfinite(rh))

    def test_split_rhat_converged(self):
        x = np.random.default_rng(4).normal(size=(4, 4000))
        assert abs(diag.split_rhat(x) - 1.0) < 0.02

    def test_split_rhat_flags_disagreement(self):
        rng = np.random.default_rng(5)
        x = rng.normal(size=(4, 1000)) + np.array([[0.0], [3.0], [0.0], [-3.0]])
        assert diag.split_rhat(x) > 1.5

    def test_split_rhat_frozen_chains(self):
        """Zero within-half variance: identical constants are vacuously
        converged; DISTINCT constants must read as divergence, not 1.0."""
        assert diag.split_rhat(np.ones((3, 100))) == 1.0
        frozen = np.concatenate([np.zeros((2, 100)), np.ones((2, 100))])
        assert diag.split_rhat(frozen) == float("inf")

    def test_split_rhat_flags_drift(self):
        """Split-R̂ (unlike plain R̂) catches a trend WITHIN each chain."""
        rng = np.random.default_rng(6)
        n = 2000
        x = rng.normal(size=(4, n)) + np.linspace(0, 4, n)[None, :]
        assert diag.split_rhat(x) > 1.2

    def test_autocorrelation_lag0(self):
        rho = diag.autocorrelation(np.random.default_rng(7).normal(size=(3, 256)))
        np.testing.assert_allclose(rho[:, 0], 1.0)
        assert np.all(np.abs(rho[:, 1:]) < 1.0 + 1e-9)


class TestOracle:
    def test_alpha0_equals_independent_sghmc(self):
        """The acceptance-criteria identity: alpha=0 decouples Eq. 5/6 into
        K independent SGHMC chains — the oracle must agree EXACTLY."""
        for conv, cnp in (("eq4", False), ("eq6", False)):
            ec = diag.ec_sghmc_stationary(
                step_size=0.1, alpha=0.0, num_chains=4, friction=1.3, sync_every=8,
                noise_convention=conv, center_noise_in_p=cnp,
            )
            sg = diag.sghmc_stationary(step_size=0.1, friction=1.3, noise_convention=conv)
            assert ec.theta_var == pytest.approx(sg.theta_var, rel=1e-12)
            assert ec.momentum_var == pytest.approx(sg.momentum_var, rel=1e-12)
            assert ec.theta_cross_cov == 0.0

    def test_alpha0_sync_period_irrelevant(self):
        vs = {
            s: diag.ec_sghmc_stationary(
                step_size=0.1, alpha=0.0, num_chains=4, sync_every=s
            ).theta_var
            for s in (1, 4, 8)
        }
        assert vs[1] == pytest.approx(vs[4], rel=1e-12) == pytest.approx(vs[8], rel=1e-12)

    def test_sgld_closed_form(self):
        eps = 0.05
        o = diag.sgld_stationary(step_size=eps)
        assert o.theta_var == pytest.approx(2 * eps / (1 - (1 - eps) ** 2), rel=1e-12)

    def test_small_eps_recovers_target_variance(self):
        """eq4 noise: as eps -> 0 the discrete chain targets N(mu, 1/lam)."""
        o = diag.sghmc_stationary(step_size=1e-3, friction=1.0, precision=2.0)
        assert o.theta_var == pytest.approx(0.5, rel=5e-3)
        o = diag.sgld_stationary(step_size=1e-3, precision=2.0)
        assert o.theta_var == pytest.approx(0.5, rel=5e-3)

    def test_coupling_induces_positive_cross_covariance(self):
        o = diag.ec_sghmc_stationary(step_size=0.1, alpha=1.0, num_chains=4, sync_every=1)
        assert o.theta_cross_cov > 0.0
        assert o.theta_var > o.theta_cross_cov
        assert o.spectral_radius < 1.0

    def test_staleness_ramps_phase_variance(self):
        """Between syncs the stale center lets chains drift: the per-phase
        stationary variance must not be constant for s > 1."""
        o = diag.ec_sghmc_stationary(step_size=0.1, alpha=1.0, num_chains=4, sync_every=8)
        assert o.phase_theta_vars.shape == (8,)
        assert np.ptp(o.phase_theta_vars) > 1e-6

    def test_unstable_raises(self):
        with pytest.raises(ValueError):
            diag.sghmc_stationary(step_size=3.0, friction=0.1)
        with pytest.raises(ValueError):
            diag.sgld_stationary(step_size=2.5)

    def test_lyapunov_solver(self):
        rng = np.random.default_rng(0)
        A = 0.9 * np.linalg.qr(rng.normal(size=(5, 5)))[0]  # contraction
        q = rng.normal(size=(5, 5))
        Q = q @ q.T
        sigma = diag.lyapunov_stationary(A, Q)
        np.testing.assert_allclose(sigma, A @ sigma @ A.T + Q, atol=1e-9)

    def test_noise_sigmas_match_sampler_formula(self):
        sp, sr = diag.noise_sigmas(0.1, 1.0, 2.0, 1.0, "eq6", True)
        assert sp == pytest.approx(0.1 * np.sqrt(2 * 3.0), rel=1e-6)
        assert sr == pytest.approx(0.1 * np.sqrt(2 * 2.0), rel=1e-6)
        sp4, _ = diag.noise_sigmas(0.1, 1.0, 2.0, 0.25, "eq4", False)
        assert sp4 == pytest.approx(0.5 * np.sqrt(2 * 0.1), rel=1e-6)


class TestSamplerStatsHook:
    def test_sghmc_stats(self):
        s = core.sghmc(step_size=1e-2)
        params = jnp.ones((4, 3))
        st = s.init(params)
        out = jax.jit(s.stats)(st, params)
        assert float(out["momentum_norm"]) == 0.0 and int(out["step"]) == 0

    def test_ec_sghmc_stats_values(self):
        ec = core.ec_sghmc(step_size=1e-2, alpha=2.0)
        params = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
        st = ec.init(params)
        out = jax.jit(ec.stats)(st, params)
        # center = chain mean at init, so rms == sqrt(mean (theta - mean)^2)
        manual = np.sqrt(np.mean((np.asarray(params) - np.asarray(params).mean(0)) ** 2))
        assert float(out["chain_center_rms"]) == pytest.approx(manual, rel=1e-5)
        # coupling energy = (1/K) sum_i (alpha/2)||theta^i - c||^2
        centered = np.asarray(params) - np.asarray(params).mean(0)
        manual_e = 0.5 * 2.0 * np.sum(centered**2) / 4
        assert float(out["coupling_energy"]) == pytest.approx(manual_e, rel=1e-4)
        for v in out.values():
            assert np.isfinite(float(v))

    def test_ec_sgld_stats(self):
        ec = core.ec_sgld(step_size=1e-2, alpha=1.0)
        params = jax.random.normal(jax.random.PRNGKey(1), (3, 5))
        out = ec.stats(ec.init(params), params)
        assert set(out) >= {"step", "center_momentum_norm", "chain_center_rms"}

    def test_stateless_samplers_expose_none(self):
        assert core.sgld(step_size=1e-2).stats is None


class TestSpread:
    def test_cross_chain_spread_matches_numpy(self):
        tree = {
            "a": jax.random.normal(jax.random.PRNGKey(0), (4, 3, 2)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (4, 5)),
        }
        got = float(diag.cross_chain_spread(tree))
        a, b = np.asarray(tree["a"]), np.asarray(tree["b"])
        want = (a.var(0).sum() + b.var(0).sum()) / (a.var(0).size + b.var(0).size)
        assert got == pytest.approx(want, rel=1e-5)

    def test_chain_center_rms_matches_numpy(self):
        chains = jax.random.normal(jax.random.PRNGKey(2), (6, 10))
        center = jnp.zeros((10,))
        got = float(diag.chain_center_rms(chains, center))
        assert got == pytest.approx(np.sqrt(np.mean(np.asarray(chains) ** 2)), rel=1e-5)

    def test_ensemble_spread_keys(self):
        stack = {"w": jax.random.normal(jax.random.PRNGKey(3), (3, 4, 4))}
        out = diag.ensemble_spread(stack)
        assert out["num_chains"] == 3
        assert out["chain_spread"] > 0 and np.isfinite(out["rel_spread"])
        collapsed = {"w": jnp.broadcast_to(stack["w"][:1], (3, 4, 4))}
        assert diag.ensemble_spread(collapsed)["chain_spread"] < 1e-10

    def test_pooled_moments(self):
        x = np.random.default_rng(4).normal(size=(3, 100, 2))
        m, v = diag.pooled_moments(x)
        np.testing.assert_allclose(m, x.reshape(-1, 2).mean(0))
        np.testing.assert_allclose(v, x.reshape(-1, 2).var(0))
