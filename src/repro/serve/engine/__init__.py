"""Posterior-predictive serving engine (DESIGN.md §5).

Continuous batching over a fixed slot axis (one compiled decode program;
admissions/completions are data), a recycled per-slot cache pool with
int8-parked idle caches, Bayesian model averaging over K ensemble members,
and live snapshot refresh from a background coupled-sampler run gated by
ensemble-spread diagnostics.
"""
from .bma import BMA_MODES, mixture_logprobs, reference_bma_decode
from .cache_pool import CachePool, ParkedCache
from .engine import ServeEngine, ServeReport
from .registry import ChainRefresher, SnapshotRegistry
from .scheduler import FCFSQueue, Request, RequestResult, synthetic_trace

__all__ = [
    "BMA_MODES",
    "CachePool",
    "ChainRefresher",
    "FCFSQueue",
    "ParkedCache",
    "Request",
    "RequestResult",
    "ServeEngine",
    "ServeReport",
    "SnapshotRegistry",
    "mixture_logprobs",
    "reference_bma_decode",
    "synthetic_trace",
]
