"""Shared driver: posterior sampling over classification models (the
paper's Fig. 2 experiment machinery).

Metric identical to the paper: negative log likelihood of the *posterior
predictive* on held-out data, over sampling steps.  For parallel samplers
the predictive averages over all K chains (Bayesian model averaging) —
that, not single-chain quality, is what a sampler earns its keep for.

The step loop is device-resident: ``repro.run.ChainExecutor`` scans whole
``eval_every``-sized chunks as one compiled program (sampler mode, so
approach-I samplers get their gradients at the stale snapshots), with the
Welford moments and the streaming batch-means ESS riding the scan carry.
The host only touches the chain at eval boundaries — predictive NLL,
probe collection and checkpointable state all live there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import math

from repro import core
from repro import diagnostics as diag
from repro.data.pipeline import ShardedLoader
from repro.run import ChainExecutor


def sgd_map(lr: float, beta: float = 0.9):
    """Map SGD-with-momentum (lr, beta) to SGHMC (step_size, friction):
    eps = sqrt(lr (1-beta)), V = (1-beta)/eps.  Equilibrium step eps/V = lr
    and momentum decay per step = eps*V = 1-beta — the scale-adapted SGHMC
    parameterization that makes burn-in practical."""
    eps = math.sqrt(lr * (1.0 - beta))
    return eps, (1.0 - beta) / eps


def run_sampling(
    apply_fn,  # (params, x) -> logits
    nll_fn,  # (params, batch) -> (sum_nll, count)
    init_params_fn,  # (rng) -> params (single chain)
    sampler,
    num_chains: int,
    train,  # (x, y)
    test,  # (x, y)
    *,
    n_data: int,
    steps: int,
    batch_size: int = 100,
    eval_every: int = 20,
    weight_decay: float = 1e-5,
    burnin_frac: float = 0.25,
    seed: int = 0,
    collect_diagnostics: bool = False,
):
    """When ``collect_diagnostics`` is set, additionally returns a dict of
    shared convergence diagnostics (repro.diagnostics): post-burn-in probe
    ESS / split-R̂ (FFT, from the thinned probe trace) and their streaming
    batch-means counterpart straight out of the scan carry, parameter
    moments, cross-chain spread, and the sampler's own stats hook."""
    prior = core.gaussian_prior(weight_decay)
    pot = core.make_potential(nll_fn, n_data=n_data, prior=prior)
    params1 = init_params_fn(jax.random.PRNGKey(seed))
    if num_chains > 1:
        params = core.tree_broadcast_axis0(params1, num_chains)
    else:
        params = params1
    state = sampler.init(params)
    xt, yt = jnp.asarray(test[0]), jnp.asarray(test[1])

    # async samplers (grad_targets, single server chain) evaluate gradients
    # at K stacked worker snapshots — their batches carry the worker axis
    stacked_grads = num_chains > 1 or sampler.grad_targets is not None
    k_batch = num_chains
    if sampler.grad_targets is not None and num_chains == 1:
        k_batch = jax.tree.leaves(state.snapshots)[0].shape[0]
    loader = ShardedLoader(train[0], train[1], batch_size, k_batch, seed)
    grad_fn_inner = jax.vmap(pot.grad) if stacked_grads else pot.grad

    @jax.jit
    def predictive_nll(prob_sum, n_models):
        probs = prob_sum / n_models
        logp = jnp.log(jnp.maximum(probs, 1e-12))
        gold = jnp.take_along_axis(logp, yt[:, None], axis=-1)[:, 0]
        return -jnp.mean(gold)

    @jax.jit
    def chain_probs(params):
        f = lambda p: jax.nn.softmax(apply_fn(p, xt).astype(jnp.float32), -1)
        if num_chains > 1:
            return jnp.sum(jax.vmap(f)(params), axis=0)
        return f(params)

    def probe_fn(params):
        """First few coordinates of the first leaf, per chain — the scalar
        series the ESS / R̂ estimators run on."""
        leaf = jax.tree.leaves(params)[0].astype(jnp.float32)
        k = leaf.shape[0] if num_chains > 1 else 1
        return leaf.reshape(k, -1)[:, :4]

    burnin = int(steps * burnin_frac)
    executor = ChainExecutor(
        sampler=sampler,
        grad_fn=lambda targets, batch: grad_fn_inner(targets, batch),
        batch_fn=loader.batch,
        trace_fn=probe_fn if collect_diagnostics else None,
        moments=collect_diagnostics,
        moments_from=burnin,
        ess_probe_fn=probe_fn if collect_diagnostics else None,
        ess_batch_len=max(int(math.sqrt(max(steps - burnin, 1))), 8),
        chunk_steps=eval_every,
        key_mode="carry",
    )

    curve = []
    eval_state = {"prob_sum": jnp.zeros((xt.shape[0], 10), jnp.float32), "n_acc": 0}

    def on_chunk(step_end, params, state, outs):
        if step_end % eval_every != 0:
            return
        if step_end - 1 >= burnin:  # accumulate posterior predictive after burn-in
            eval_state["prob_sum"] = eval_state["prob_sum"] + chain_probs(params)
            eval_state["n_acc"] += num_chains
        cur = chain_probs(params)
        nll_now = float(predictive_nll(cur, num_chains))
        n_acc = eval_state["n_acc"]
        nll_avg = float(predictive_nll(eval_state["prob_sum"], max(n_acc, 1))) if n_acc else nll_now
        curve.append({"step": step_end, "nll": nll_now, "nll_bma": nll_avg})

    result = executor.run(
        params, state,
        num_steps=steps,
        key=jax.random.PRNGKey(seed + 1),
        on_chunk=on_chunk,
    )
    params, state = result.params, result.state
    if not collect_diagnostics:
        return params, curve

    chains = np.moveaxis(np.asarray(result.trace)[burnin:], 1, 0)  # (K, T', 4)
    # element-weighted mean variance (same convention as cross_chain_spread)
    var_leaves = jax.tree.leaves(diag.welford_var(result.moments))
    param_var = float(
        sum(float(jnp.sum(v)) for v in var_leaves)
        / max(sum(int(v.size) for v in var_leaves), 1)
    )
    info = {
        # pooled assumes independent chains (upper bound under coupling);
        # chain_mean is the conservative coupled-chain estimate
        "probe_ess": float(np.sum(diag.effective_sample_size_nd(chains))),
        "probe_ess_chain_mean": float(np.sum(diag.coupled_ess_nd(chains))),
        "probe_split_rhat": float(np.max(diag.split_rhat_nd(chains))),
        # straight out of the scan carry — zero host syncs during sampling
        "probe_ess_streaming": float(np.sum(np.asarray(diag.batch_ess_estimate(result.ess)))),
        "param_var": param_var,
        "chain_spread": float(diag.cross_chain_spread(params)) if num_chains > 1 else 0.0,
        "steps_per_s": result.steps_per_s,
    }
    if sampler.stats is not None:
        info["sampler_stats"] = {
            k: float(v) for k, v in sampler.stats(state, params).items()
        }
    return params, curve, info
