"""Serving-engine battery: continuous batching, BMA-vs-reference, cache
pooling, snapshot registry gating, and the shared token-selection helper.

The two acceptance pins from the issue live here:

* ``test_single_decode_program`` — a trace with requests arriving
  mid-decode lowers to ONE compiled decode program (no retrace per
  admission), asserted on the engine's trace counter;
* ``test_engine_matches_sequential_reference`` — engine BMA output (tokens
  AND mixture log-prob trajectories) matches the sequential per-member
  reference within float tolerance, per request, under staggered arrivals.
"""
from __future__ import annotations

import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, core
from repro.models import get_model, init_params
from repro.models.registry import ModelDef
from repro.run import ChainExecutor
from repro.serve import generate
from repro.serve.engine import (
    CachePool,
    ChainRefresher,
    RefreshScheduler,
    Request,
    ServeEngine,
    SnapshotRegistry,
    mixture_logprobs,
    reference_bma_decode,
    synthetic_trace,
)
from repro.serve.loop import ensemble_diagnostics
from repro.serve.sampling import GREEDY, SamplingParams, mask_after_eos, select_tokens


# ---------------------------------------------------------------------------
# tiny real model + stub model
# ---------------------------------------------------------------------------


def tiny_cfg():
    return configs.get_config("qwen3-0.6b", smoke=True).replace(
        vocab_size=64, d_model=32, num_layers=2, num_heads=2, num_kv_heads=1,
        head_dim=16, d_ff=48,
    )


def member_stack(cfg, model, k: int, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    return jax.vmap(lambda kk: init_params(model.param_specs(cfg), kk))(keys)


def members_list(stack, k):
    return [jax.tree.map(lambda x: x[i], stack) for i in range(k)]


STUB_VOCAB = 11


def stub_model():
    """Deterministic counter model: next token = (last + 1) % vocab, via
    one-hot logits — exact EOS arithmetic with zero model noise.  Params
    hold a per-member logit scale so BMA has something to average."""

    def param_specs(cfg):
        raise NotImplementedError

    def prefill(cfg, params, batch, max_seq, cache_dtype=None):
        tokens = batch["tokens"]
        last = tokens[:, -1:]
        logits = params["scale"] * jax.nn.one_hot(
            (last + 1) % STUB_VOCAB, STUB_VOCAB, dtype=jnp.float32
        )
        return logits, {"t": jnp.asarray(tokens.shape[1], jnp.int32), "last": last}

    def decode_step(cfg, params, cache, tokens):
        logits = params["scale"] * jax.nn.one_hot(
            (tokens + 1) % STUB_VOCAB, STUB_VOCAB, dtype=jnp.float32
        )
        return logits, {"t": cache["t"] + 1, "last": tokens}

    def make_cache(cfg, batch, max_seq, dtype, abstract: bool = False):
        tree = {
            "t": jax.ShapeDtypeStruct((), jnp.int32),
            "last": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        }
        if abstract:
            return tree
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tree)

    return ModelDef(param_specs, None, prefill, decode_step, make_cache, None)


STUB_CFG = SimpleNamespace(compute_dtype=jnp.float32, vocab_size=STUB_VOCAB)


def stub_members(k: int):
    return {"scale": 10.0 * (1.0 + jnp.arange(k, dtype=jnp.float32)[:, None])}


# ---------------------------------------------------------------------------
# token selection helper (shared legacy/engine)
# ---------------------------------------------------------------------------


class TestSelectTokens:
    def test_greedy_is_argmax(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (5, 33))
        np.testing.assert_array_equal(
            np.asarray(select_tokens(logits)), np.argmax(np.asarray(logits), -1)
        )

    def test_greedy_needs_no_key_temperature_does(self):
        logits = jnp.zeros((2, 8))
        select_tokens(logits, None, GREEDY)
        with pytest.raises(ValueError):
            select_tokens(logits, None, SamplingParams(temperature=1.0))

    def test_top_k_support(self):
        key = jax.random.PRNGKey(1)
        logits = jax.random.normal(key, (64, 40))
        sp = SamplingParams(temperature=1.3, top_k=5)
        toks = np.asarray(select_tokens(logits, key, sp))
        top5 = np.argsort(np.asarray(logits), -1)[:, -5:]
        assert all(toks[i] in top5[i] for i in range(64))

    def test_top_k_1_any_temperature_is_greedy(self):
        logits = jax.random.normal(jax.random.PRNGKey(2), (7, 19))
        sp = SamplingParams(temperature=3.0, top_k=1)
        np.testing.assert_array_equal(
            np.asarray(select_tokens(logits, jax.random.PRNGKey(3), sp)),
            np.asarray(select_tokens(logits)),
        )

    def test_sampling_deterministic_in_key(self):
        logits = jax.random.normal(jax.random.PRNGKey(4), (16, 25))
        sp = SamplingParams(temperature=0.7, top_k=10)
        a = select_tokens(logits, jax.random.PRNGKey(5), sp)
        b = select_tokens(logits, jax.random.PRNGKey(5), sp)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_mask_after_eos(self):
        toks = jnp.array([[3, 7, 5, 7, 2], [1, 2, 3, 4, 5]])
        out = np.asarray(mask_after_eos(toks, eos_id=7, pad_id=0))
        np.testing.assert_array_equal(out, [[3, 7, 0, 0, 0], [1, 2, 3, 4, 5]])


class TestBMAMath:
    def test_probs_mode_is_arithmetic_mixture(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 17))
        lp = np.asarray(mixture_logprobs(logits, "probs"))
        expect = np.log(np.mean(jax.nn.softmax(np.asarray(logits, np.float32), -1), 0))
        np.testing.assert_allclose(lp, expect, atol=1e-6)

    def test_logprobs_mode_normalized(self):
        logits = jax.random.normal(jax.random.PRNGKey(1), (4, 5, 13))
        lp = np.asarray(mixture_logprobs(logits, "logprobs"))
        np.testing.assert_allclose(np.exp(lp).sum(-1), 1.0, atol=1e-6)

    def test_k1_both_modes_are_log_softmax(self):
        logits = jax.random.normal(jax.random.PRNGKey(2), (1, 3, 9))
        expect = np.asarray(jax.nn.log_softmax(logits[0], -1))
        for mode in ("probs", "logprobs"):
            np.testing.assert_allclose(
                np.asarray(mixture_logprobs(logits, mode)), expect, atol=1e-6
            )


# ---------------------------------------------------------------------------
# generate: EOS stop + masking (satellite)
# ---------------------------------------------------------------------------


class TestGenerateEOS:
    def test_stops_early_and_masks(self):
        model = stub_model()
        params = {"scale": jnp.float32(10.0)}
        # counter model: prompt ends at 3 -> emits 4, 5, 6(=eos), stop
        batch = {"tokens": jnp.array([[1, 2, 3]], jnp.int32)}
        toks = generate(STUB_CFG, model, params, batch, max_seq=16, num_tokens=8, eos_id=6)
        assert toks.shape[1] == 3  # stopped well before the 8-token budget
        np.testing.assert_array_equal(np.asarray(toks), [[4, 5, 6]])

    def test_masks_mixed_rows(self):
        model = stub_model()
        params = {"scale": jnp.float32(10.0)}
        # row0 hits eos=6 after 2 tokens; row1 only at the budget edge
        batch = {"tokens": jnp.array([[3, 4], [0, 1]], jnp.int32)}
        toks = np.asarray(
            generate(STUB_CFG, model, params, batch, max_seq=16, num_tokens=5, eos_id=6, pad_id=9)
        )
        np.testing.assert_array_equal(toks[0], [5, 6, 9, 9, 9])
        np.testing.assert_array_equal(toks[1], [2, 3, 4, 5, 6])

    def test_no_eos_keeps_full_budget(self):
        model = stub_model()
        params = {"scale": jnp.float32(10.0)}
        batch = {"tokens": jnp.array([[0]], jnp.int32)}
        toks = generate(STUB_CFG, model, params, batch, max_seq=16, num_tokens=4)
        assert toks.shape == (1, 4)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class TestServeEngine:
    def test_engine_matches_sequential_reference(self):
        """Staggered arrivals, slots recycled; every request's tokens AND
        mixture log-prob rows must match running it alone through the
        sequential per-member reference."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        k = 3
        stack = member_stack(cfg, model, k)
        engine = ServeEngine(
            cfg, model, stack, num_slots=2, max_seq=24, record_logprobs=True
        )
        reqs = synthetic_trace(
            5, vocab_size=cfg.vocab_size, prompt_lens=(5, 8), max_new=6,
            mean_interarrival=2.0, seed=3,
        )
        report = engine.run(reqs)
        assert len(report.results) == 5
        assert report.pool["active"] == 0  # every slot recycled
        for req in reqs:
            res = next(r for r in report.results if r.rid == req.rid)
            ref_toks, ref_lp = reference_bma_decode(
                cfg, model, members_list(stack, k),
                {"tokens": jnp.asarray(req.prompt)[None]}, 24, req.max_new,
            )
            assert res.num_tokens == req.max_new
            np.testing.assert_array_equal(res.tokens, np.asarray(ref_toks)[0])
            np.testing.assert_allclose(
                res.logprobs, np.asarray(ref_lp)[:, 0], atol=1e-5
            )

    @pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
    def test_single_decode_program(self, paged):
        """Mid-decode admissions + member swap + slot recycling never
        retrace: exactly ONE compiled decode program for the whole trace.
        The paged variant adds block-table churn (page alloc/free, decode
        growth) — all of it data, none of it shape."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        stack = member_stack(cfg, model, 2)
        engine = ServeEngine(cfg, model, stack, num_slots=2, max_seq=24,
                             paged=paged, block_size=8)
        reqs = synthetic_trace(
            6, vocab_size=cfg.vocab_size, prompt_lens=(5,), max_new=5,
            mean_interarrival=1.5, seed=4,
        )
        report = engine.run(reqs)
        assert report.decode_steps > 5  # genuinely interleaved, not one batch
        assert report.trace_counts["decode"] == 1, report.trace_counts
        assert engine.decode_trace_count == 1
        # same engine, more load, a registry swap: still no retrace
        engine.registry.propose(jax.tree.map(lambda x: x * 1.01, stack))
        more = synthetic_trace(
            3, vocab_size=cfg.vocab_size, prompt_lens=(5,), max_new=4,
            mean_interarrival=1.0, seed=5,
        )
        engine.run(more)
        assert engine.decode_trace_count == 1

    def test_engine_eos_and_budget(self):
        model = stub_model()
        engine = ServeEngine(STUB_CFG, model, stub_members(2), num_slots=2,
                             max_seq=32, eos_id=6)
        reqs = [
            Request(rid=0, prompt=np.array([2], np.int32), max_new=8),  # 3,4,5,6 -> eos
            Request(rid=1, prompt=np.array([7], np.int32), max_new=3),  # 8,9,10: budget
        ]
        report = engine.run(reqs)
        r0, r1 = report.results
        assert r0.hit_eos and r0.num_tokens == 4
        np.testing.assert_array_equal(r0.tokens, [3, 4, 5, 6])
        assert not r1.hit_eos and r1.num_tokens == 3
        np.testing.assert_array_equal(r1.tokens, [8, 9, 10])

    def test_sampled_path_top_k_1_equals_greedy(self):
        cfg = tiny_cfg()
        model = get_model(cfg)
        stack = member_stack(cfg, model, 2)
        reqs = synthetic_trace(
            3, vocab_size=cfg.vocab_size, prompt_lens=(6,), max_new=4,
            mean_interarrival=1.0, seed=6,
        )
        greedy = ServeEngine(cfg, model, stack, num_slots=2, max_seq=16).run(reqs)
        sampled = ServeEngine(
            cfg, model, stack, num_slots=2, max_seq=16,
            sampling=SamplingParams(temperature=2.0, top_k=1),
        ).run(reqs)
        for a, b in zip(greedy.results, sampled.results):
            np.testing.assert_array_equal(a.tokens, b.tokens)

    def test_sampled_path_deterministic_in_seed(self):
        cfg = tiny_cfg()
        model = get_model(cfg)
        stack = member_stack(cfg, model, 2)
        reqs = synthetic_trace(
            4, vocab_size=cfg.vocab_size, prompt_lens=(5,), max_new=5,
            mean_interarrival=2.0, seed=7,
        )
        mk = lambda: ServeEngine(
            cfg, model, stack, num_slots=2, max_seq=16,
            sampling=SamplingParams(temperature=0.9, top_k=8), seed=11,
        ).run(reqs)
        a, b = mk(), mk()
        for ra, rb in zip(a.results, b.results):
            np.testing.assert_array_equal(ra.tokens, rb.tokens)

    def test_admission_refuses_cache_overflow(self):
        model = stub_model()
        engine = ServeEngine(STUB_CFG, model, stub_members(1), num_slots=1, max_seq=8)
        bad = [Request(rid=0, prompt=np.arange(5, dtype=np.int32), max_new=4)]
        with pytest.raises(ValueError, match="max_seq"):
            engine.run(bad)

    def test_generate_refuses_cache_overflow(self):
        """Same guard on the host loop: dynamic_update_slice clamps the
        write index at max_seq-1, so an oversized budget would silently
        corrupt the tail instead of failing loudly."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        batch = {"tokens": jnp.arange(1, 7, dtype=jnp.int32)[None]}
        with pytest.raises(ValueError, match="max_seq"):
            generate(cfg, model, params, batch, max_seq=8, num_tokens=4)
        # the boundary case still runs: 6 + 2 == 8
        out = generate(cfg, model, params, batch, max_seq=8, num_tokens=2)
        assert out.shape == (1, 2)

    def test_max_steps_truncation_recycles_slots(self):
        model = stub_model()
        engine = ServeEngine(STUB_CFG, model, stub_members(1), num_slots=2, max_seq=64)
        reqs = [
            Request(rid=i, prompt=np.array([0], np.int32), max_new=30) for i in range(2)
        ]
        report = engine.run(reqs, max_steps=3)
        assert report.pool["active"] == 0  # truncated slots recycled
        assert all(r.truncated for r in report.results)
        assert all(0 < r.num_tokens < 30 for r in report.results)
        # engine still fully usable afterwards, and per-run decode_steps
        # excludes the first run's ticks
        rep2 = engine.run(
            [Request(rid=9, prompt=np.array([0], np.int32), max_new=4)]
        )
        (r9,) = rep2.results
        assert not r9.truncated and r9.num_tokens == 4
        np.testing.assert_array_equal(r9.tokens, [1, 2, 3, 4])
        assert rep2.decode_steps == 3  # admit emits 1, then 3 decode ticks

    def test_queueing_when_oversubscribed(self):
        model = stub_model()
        engine = ServeEngine(STUB_CFG, model, stub_members(1), num_slots=1, max_seq=64)
        reqs = [
            Request(rid=i, prompt=np.array([0], np.int32), max_new=4, arrival_step=0)
            for i in range(3)
        ]
        report = engine.run(reqs)
        assert [r.rid for r in report.results] == [0, 1, 2]  # FCFS order
        admits = sorted(r.admitted_step for r in report.results)
        assert admits[0] < admits[1] < admits[2]  # strictly serialized on 1 slot
        assert report.pool["high_water"] == 1


# ---------------------------------------------------------------------------
# ensemble diagnostics + snapshot registry (satellite)
# ---------------------------------------------------------------------------


class TestSyntheticTrace:
    def test_sub_tick_interarrival_is_not_clamped(self):
        """mean < 1 must genuinely raise the offered load (multiple
        arrivals per tick), not silently degrade to one per tick."""
        heavy = synthetic_trace(200, vocab_size=8, mean_interarrival=0.25, seed=0)
        light = synthetic_trace(200, vocab_size=8, mean_interarrival=1.0, seed=0)
        assert heavy[-1].arrival_step < light[-1].arrival_step / 2
        span = heavy[-1].arrival_step
        assert span == pytest.approx(200 * 0.25, rel=0.5)

    def test_rejects_nonpositive_rate(self):
        with pytest.raises(ValueError):
            synthetic_trace(2, vocab_size=8, mean_interarrival=0.0)


class TestRegistry:
    def test_collapsed_ensemble_flagged(self):
        p = init_params(get_model(tiny_cfg()).param_specs(tiny_cfg()), jax.random.PRNGKey(0))
        collapsed = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (3,) + x.shape), p)
        health = ensemble_diagnostics(collapsed)
        assert health["collapsed"] and health["rel_spread"] < 1e-6

    def test_registry_refuses_collapsed_keeps_serving_old(self):
        cfg = tiny_cfg()
        model = get_model(cfg)
        stack = member_stack(cfg, model, 2)
        reg = SnapshotRegistry(stack)
        collapsed = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), stack)
        assert not reg.propose(collapsed)
        assert reg.version == 0 and reg.rejected == 1
        # old members untouched
        np.testing.assert_array_equal(
            np.asarray(jax.tree.leaves(reg.members)[0]), np.asarray(jax.tree.leaves(stack)[0])
        )
        assert reg.propose(jax.tree.map(lambda x: x * 1.01, stack))
        assert reg.version == 1

    def test_registry_rejects_wrong_k(self):
        stack = {"w": jnp.ones((3, 4))}
        reg = SnapshotRegistry({"w": jnp.arange(8.0).reshape(2, 4)})
        with pytest.raises(ValueError):
            reg.propose(stack)

    def test_validate_rejects_collapsed_initial(self):
        with pytest.raises(ValueError):
            SnapshotRegistry({"w": jnp.ones((3, 4))}, validate=True)

    def test_live_refresh_through_engine(self):
        """Background chain-stacked SGLD feeds the registry at chunk
        boundaries while the engine serves; promotions happen and the
        decode program still compiles exactly once."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        k = 2
        stack = member_stack(cfg, model, k)
        center = jax.tree.map(lambda x: x[0], stack)
        grad_fn = lambda p: jax.tree.map(lambda x, c: 2500.0 * (x - c), p, center)
        start = jax.tree.map(lambda x: jnp.broadcast_to(x[0][None], x.shape) + 0.0, stack)
        reg = SnapshotRegistry(stack)
        refresher = ChainRefresher(
            reg, core.sgld(step_size=8e-5), grad_fn, start,
            key=jax.random.PRNGKey(8), chunk_steps=8, total_steps=32,
        )
        engine = ServeEngine(
            cfg, model, reg, num_slots=2, max_seq=16,
            refresher=refresher, refresh_every=3,
        )
        reqs = synthetic_trace(
            4, vocab_size=cfg.vocab_size, prompt_lens=(5,), max_new=6,
            mean_interarrival=2.0, seed=9,
        )
        report = engine.run(reqs)
        assert report.registry["version"] >= 1  # at least one promotion
        assert report.refresher["refreshes"] >= 1
        assert report.trace_counts["decode"] == 1  # swap is data, not shape
        assert len(report.results) == 4

    def test_refresher_exhausts(self):
        grad_fn = lambda p: p
        start = jnp.zeros((2, 3))
        reg = SnapshotRegistry(start + jnp.arange(2.0)[:, None])
        refr = ChainRefresher(
            reg, core.sgld(step_size=0.1), grad_fn, start,
            key=jax.random.PRNGKey(0), chunk_steps=4, total_steps=8,
        )
        assert refr.refresh()  # independent per-element noise => spread > 0
        assert refr.refresh() and not refr.exhausted
        assert not refr.refresh() and refr.exhausted  # total_steps consumed

    def test_chain_refresher_pump_amortizes_chunks(self):
        """Bound to a refresh_every=4 engine, an 8-step chunk splits into
        four 2-step micro-chunks paced one per tick — no single pump (and
        hence no single request) eats the whole chunk, and proposal
        boundaries stay at exact chunk multiples."""
        grad_fn = lambda p: p
        start = jnp.zeros((2, 3))
        reg = SnapshotRegistry(start + jnp.arange(2.0)[:, None])
        refr = ChainRefresher(
            reg, core.sgld(step_size=0.1), grad_fn, start,
            key=jax.random.PRNGKey(0), chunk_steps=8, total_steps=16,
        )
        refr.bind(SimpleNamespace(refresh_every=4))
        assert refr.micro_steps == 2  # largest divisor of 8 <= ceil(8/4)
        before = [refr.micro_chunks]
        flips = []
        for i in range(8):
            flips.append(refr.pump(i))
            before.append(refr.micro_chunks)
        assert [b - a for a, b in zip(before, before[1:])] == [1] * 8  # 1 micro/tick
        assert flips == [False, False, False, True] * 2  # chunk boundaries only
        assert refr.refreshes == 2 and refr.steps_done == 16
        assert reg.version == 2  # every proposal promoted (noise => spread)
        assert not refr.pump(8) and refr.exhausted

    def test_chain_refresher_split_is_bit_identical(self):
        """DESIGN.md §3: fold keying makes micro-chunking invisible — the
        bound (micro-chunked) refresher promotes exactly the members the
        legacy whole-chunk refresher does."""
        grad_fn = lambda p: p
        # fresh arrays per refresher: the stream DONATES the start carry
        mk = lambda: ChainRefresher(
            SnapshotRegistry(jnp.zeros((2, 3)) + jnp.arange(2.0)[:, None]),
            core.sgld(step_size=0.1), grad_fn, jnp.zeros((2, 3)),
            key=jax.random.PRNGKey(3), chunk_steps=8, total_steps=8,
        )
        legacy = mk()
        legacy.refresh()
        split = mk()
        split.bind(SimpleNamespace(refresh_every=4))
        for i in range(4):
            split.pump(i)
        assert split.micro_steps < split.chunk_steps  # genuinely split
        np.testing.assert_array_equal(
            np.asarray(legacy.registry.members), np.asarray(split.registry.members)
        )


class TestOverlappedRefresh:
    """DESIGN.md §9: the RefreshScheduler's lazy gate, pointer-flip
    promotions (compile-count pinned), credit pacing and observability."""

    @staticmethod
    def _toy_sched(reg, start, **kw):
        base = dict(key=jax.random.PRNGKey(0), chunk_steps=4, total_steps=8)
        base.update(kw)
        return RefreshScheduler(
            reg, core.sgld(step_size=0.1), lambda p: p, start, **base
        )

    @staticmethod
    def _model_sched(stack, reg, **kw):
        """Chain-stacked SGLD around member 0 of a real tiny-model stack
        (same dynamics as test_live_refresh_through_engine)."""
        center = jax.tree.map(lambda x: x[0], stack)
        grad_fn = lambda p: jax.tree.map(lambda x, c: 2500.0 * (x - c), p, center)
        start = jax.tree.map(lambda x: jnp.broadcast_to(x[0][None], x.shape) + 0.0, stack)
        base = dict(key=jax.random.PRNGKey(8), chunk_steps=4)
        base.update(kw)
        return RefreshScheduler(reg, core.sgld(step_size=8e-5), grad_fn, start, **base)

    def test_stage_flip_lazy_gate(self):
        """stage() never touches the serving stack; flip_staged() promotes
        or rejects on the deferred device verdict; restaging replaces."""
        stack = {"w": jnp.arange(8.0).reshape(2, 4)}
        reg = SnapshotRegistry(stack)
        assert not reg.staged_ready()  # nothing staged
        reg.stage(jax.tree.map(lambda x: x * 1.5, stack))
        assert reg.staged is not None and reg.version == 0  # serving unchanged
        deadline = time.monotonic() + 10.0
        while not reg.staged_ready() and time.monotonic() < deadline:
            time.sleep(0.001)
        assert reg.staged_ready()  # verdict computed without a host fetch
        assert reg.flip_staged() and reg.version == 1 and reg.staged is None
        # collapsed candidate: staged, then rejected at flip — serving intact
        reg.stage({"w": jnp.ones((2, 4))})
        assert not reg.flip_staged()
        assert reg.version == 1 and reg.rejected == 1
        assert not reg.flip_staged()  # nothing staged -> no-op
        # restaging replaces the parked candidate; last one wins
        reg.stage(jax.tree.map(lambda x: x * 2.0, stack))
        reg.stage(jax.tree.map(lambda x: x * 3.0, stack))
        assert reg.staged_total == 4
        assert reg.flip_staged()
        np.testing.assert_allclose(
            np.asarray(reg.members["w"]), np.asarray(stack["w"]) * 3.0
        )
        with pytest.raises(ValueError):
            reg.stage({"w": jnp.ones((3, 4))})  # K mismatch still refused

    def test_scheduler_sync_parity_and_exhaustion(self):
        """refresh() mirrors ChainRefresher semantics, including the
        exhaustion contract."""
        start = jnp.zeros((2, 3))
        reg = SnapshotRegistry(start + jnp.arange(2.0)[:, None])
        sched = self._toy_sched(reg, start)
        assert sched.refresh()
        assert sched.refresh() and not sched.exhausted
        assert not sched.refresh() and sched.exhausted
        assert not sched.pump(0)  # exhausted pump is a cheap no-op
        st = sched.stats()
        assert st["promotions"] == 2 and st["exhausted"]

    def test_scheduler_drains_last_candidate_on_exhaustion(self):
        """A candidate staged at the final boundary is not stranded: the
        pump after exhaustion force-flips it.  Pumps are polled on a
        deadline because dispatch is backpressured on the previous micro's
        device-side completion."""
        start = jnp.zeros((2, 3))
        reg = SnapshotRegistry(start + jnp.arange(2.0)[:, None])
        sched = self._toy_sched(reg, start, chunk_steps=4, total_steps=4)
        flipped, deadline = [], time.monotonic() + 10.0
        for i in range(10_000):
            flipped.append(sched.pump(i))
            if (reg.version >= 1 and sched.exhausted) or time.monotonic() > deadline:
                break
            time.sleep(0.001)
        assert reg.version == 1 and sched.exhausted
        assert any(flipped)

    @pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
    def test_compile_pin_across_promotions(self, paged):
        """The acceptance pin: one compiled decode program across >= 3
        overlapped promotions, dense and paged.  Candidates are pre-staged
        with the engine's placement, so a flip is a pointer swap the
        compiled program cannot observe."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        stack = member_stack(cfg, model, 2)
        reg = SnapshotRegistry(stack)
        sched = self._model_sched(stack, reg)
        engine = ServeEngine(
            cfg, model, reg, num_slots=2, max_seq=24, paged=paged, block_size=8,
            refresher=sched, refresh_every=2,
        )
        reqs = synthetic_trace(
            8, vocab_size=cfg.vocab_size, prompt_lens=(5,), max_new=8,
            mean_interarrival=1.5, seed=4,
        )
        report = engine.run(reqs)
        assert reg.promoted >= 3, reg.stats()
        assert report.trace_counts["decode"] == 1, report.trace_counts
        assert engine.decode_trace_count == 1
        # observability surfaced through ServeReport (satellite)
        rf = report.refresher
        assert rf["promotions"] == reg.promoted
        assert rf["micro_chunks"] >= rf["proposals"] >= rf["promotions"]
        assert rf["per_refresh_wall_s"] >= 0.0
        assert {"decode_steps_stalled", "stall_wall_s", "flips_deferred",
                "rejections", "pump_wall_s"} <= rf.keys()
        assert len(report.results) == 8

    def test_warmup_compiles_before_serving(self):
        """bind() pre-compiles the micro-chunk and gate programs: the first
        pump's dispatch must not add compile cost to a serving request.
        Proxy assertion: after bind, the scheduler's executor already holds
        a compiled micro-chunk program."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        stack = member_stack(cfg, model, 2)
        reg = SnapshotRegistry(stack)
        sched = self._model_sched(stack, reg, total_steps=1 << 20)
        ServeEngine(
            cfg, model, reg, num_slots=2, max_seq=16,
            refresher=sched, refresh_every=2,
        )
        assert sched._ex is not None and len(sched._ex._compiled) == 1
        assert sched.micro_steps == 2  # paced to the cadence
        assert sched.micro_chunks == 0  # warm-up did not advance the stream

    def test_promotion_invalidates_stale_prefix_entries(self):
        """Engine-level satellite: a mid-flight registry version bump
        eagerly drops old-version prefix-sharing entries from the paged
        allocator — without waiting for their last sharer to exit — and
        the live sharers keep decoding unharmed."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        stack = member_stack(cfg, model, 2)
        reg = SnapshotRegistry(stack)
        sched = self._model_sched(stack, reg)
        engine = ServeEngine(
            cfg, model, reg, num_slots=2, max_seq=24, paged=True, block_size=8,
            refresher=sched, refresh_every=2,
        )
        prompt = np.arange(1, 9, dtype=np.int32)  # exactly one full block
        reqs = [Request(rid=i, prompt=prompt.copy(), max_new=8, arrival_step=2 * i)
                for i in range(6)]
        report = engine.run(reqs)
        assert reg.promoted >= 1
        st = engine.pool.stats()
        # every promotion had at least one same-version entry alive (the
        # shared prompt's sharers decode for 8 ticks) -> eager drops fired
        assert st["prefix_invalidated"] >= 1
        assert all(k[0] == reg.version for k in engine.pool.alloc._prefix)
        engine.pool.alloc.check()
        assert engine.decode_trace_count == 1
        assert len(report.results) == 6


# ---------------------------------------------------------------------------
# cache pool
# ---------------------------------------------------------------------------


class TestCachePool:
    def _pool(self, compress):
        cfg = tiny_cfg()
        return CachePool(cfg, get_model(cfg), num_members=2, num_slots=3,
                         max_seq=8, compress_parked=compress)

    def _fill(self, pool, seed=0):
        pool.caches = jax.tree.map(
            lambda a: a
            + jax.random.normal(jax.random.PRNGKey(seed), a.shape).astype(a.dtype)
            if jnp.issubdtype(a.dtype, jnp.floating)
            else a + 1,
            pool.caches,
        )

    def test_acquire_release_recycle(self):
        pool = self._pool(False)
        a, b = pool.acquire(), pool.acquire()
        assert a != b and pool.free_slots == 1
        pool.release(a)
        with pytest.raises(ValueError):
            pool.release(a)  # double free
        c = pool.acquire()
        assert pool.stats()["high_water"] == 2
        del b, c

    def test_pool_exhaustion(self):
        pool = self._pool(False)
        for _ in range(3):
            pool.acquire()
        with pytest.raises(IndexError):
            pool.acquire()

    @pytest.mark.parametrize("compress", [False, True])
    def test_park_restore_roundtrip(self, compress):
        pool = self._pool(compress)
        slot = pool.acquire()
        self._fill(pool)
        orig = jax.tree.map(lambda a: np.asarray(a[:, slot]), pool.caches)
        parked = pool.park(slot)
        assert pool.free_slots == 3  # park released the slot
        assert parked.compressed == compress
        slot2 = pool.restore(parked)
        back = jax.tree.map(lambda a: np.asarray(a[:, slot2]), pool.caches)
        for o, r in zip(jax.tree.leaves(orig), jax.tree.leaves(back)):
            if np.issubdtype(o.dtype, np.floating):
                tol = 0.05 if compress else 1e-7  # int8 block codec error
                np.testing.assert_allclose(
                    o.astype(np.float32), r.astype(np.float32), atol=tol
                )
            else:
                np.testing.assert_array_equal(o, r)  # int leaves exact


# ---------------------------------------------------------------------------
# executor chunk-boundary snapshot stream (the registry's refresh hook)
# ---------------------------------------------------------------------------


class TestExecutorStream:
    def _executor(self, chunk):
        return ChainExecutor(
            sampler=core.sgld(step_size=0.1),
            grad_fn=lambda t, _b: t,
            chunk_steps=chunk,
            key_mode="fold",
        )

    def test_stream_matches_run(self):
        p = jnp.ones((2, 3))
        key = jax.random.PRNGKey(0)
        ex1 = self._executor(8)
        final_run = ex1.run(p + 0.0, ex1.sampler.init(p), num_steps=24, key=key)
        ex2 = self._executor(8)
        snaps = list(ex2.stream(p + 0.0, ex2.sampler.init(p), num_steps=24, key=key))
        assert [s.step for s in snaps] == [8, 16, 24]
        np.testing.assert_array_equal(
            np.asarray(final_run.params), np.asarray(snaps[-1].params)
        )

    def test_snapshots_survive_donation(self):
        p = jnp.zeros((2, 3))
        ex = self._executor(4)
        snaps = list(ex.stream(p, ex.sampler.init(p), num_steps=12, key=jax.random.PRNGKey(1)))
        # every yielded copy is still readable after the full run consumed
        # (and donated) the live carry
        vals = [float(jnp.sum(s.params)) for s in snaps]
        assert len(set(vals)) == 3
