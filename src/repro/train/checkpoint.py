"""Fault-tolerant checkpointing (pure numpy — no orbax in this container).

* ATOMIC: state is written to ``<dir>/tmp.<step>`` then os.replace()'d to
  ``<dir>/step_<step>`` — a crash mid-write can never corrupt the latest
  valid checkpoint.
* SELF-VALIDATING: a manifest records leaf count, shapes and a checksum;
  restore() verifies and falls back to the previous checkpoint when the
  newest is damaged (torn disk, partial preemption).
* ELASTIC: ``restore_elastic`` re-shapes the chain axis — a job restarted
  with a different K resamples new chains from the center variable
  (theta^i | c ~ N(c, (K/alpha) I), the stationary conditional implied by
  Eq. 5) instead of failing. Dead chains are recoverable the same way.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import resample_chain_from_center
from repro.core.ec_sghmc import ECSGHMCState
from repro.obs import get_logger

log = get_logger("ckpt")

_SEP = "::"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(ckpt_dir, step: int, params, sampler_state, extra: dict | None = None):
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"tmp.{step}"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    payload = {"params": params, "state": sampler_state}
    flat, _ = _flatten(payload)
    np.savez(tmp / "arrays.npz", **flat)
    def _leaf_sum(v):  # NaN/inf-robust (a diverged model must still checkpoint)
        s = float(np.nansum(np.abs(v).astype(np.float64)))
        return int((s if np.isfinite(s) else 0.0) * 1000) % 2**31

    manifest = {
        "step": int(step),
        "leaves": len(flat),
        "checksum": int(sum(_leaf_sum(v) for v in flat.values() if v.dtype.kind == "f")),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on POSIX
    return final


def _checkpoints(ckpt_dir):
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    return sorted(p for p in ckpt_dir.iterdir() if p.name.startswith("step_"))


def _load_one(path: Path, template):
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}
    if len(flat) != manifest["leaves"]:
        raise IOError(f"{path}: leaf count mismatch")
    for k, v in flat.items():
        if list(v.shape) != manifest["shapes"][k]:
            raise IOError(f"{path}: shape mismatch for {k}")
    # rebuild against the template's structure
    tpl_flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, tpl_leaf in tpl_flat:
        key = _SEP.join(str(x) for x in p)
        if key not in flat:
            raise IOError(f"{path}: missing leaf {key}")
        if hasattr(tpl_leaf, "shape") and tuple(flat[key].shape) != tuple(tpl_leaf.shape):
            raise IOError(
                f"{path}: template shape mismatch for {key}: "
                f"stored {flat[key].shape} vs wanted {tpl_leaf.shape}"
            )
        leaves.append(jnp.asarray(flat[key]))
    payload = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(template), leaves)
    return manifest["step"], payload, manifest.get("extra", {})


def restore(ckpt_dir, params_template, state_template):
    """Latest VALID checkpoint (walks backward past corrupted ones).
    Returns (step, params, state, extra) or None."""
    template = {"params": params_template, "state": state_template}
    for path in reversed(_checkpoints(ckpt_dir)):
        try:
            step, payload, extra = _load_one(path, template)
            return step, payload["params"], payload["state"], extra
        except Exception as e:  # corrupted — try the previous one
            log.warning(f"skipping {path.name}: {e}")
    return None


def restore_elastic(ckpt_dir, params_template, state_template, num_chains: int, alpha: float, seed: int = 0):
    """Restore; if the checkpointed chain count differs from ``num_chains``,
    resample chains from the center (elastic K scaling)."""
    # try exact restore first
    exact = restore(ckpt_dir, params_template, state_template)
    if exact is not None:
        return exact
    # chain-count mismatch: load raw, rebuild from center
    for path in reversed(_checkpoints(ckpt_dir)):
        try:
            with np.load(path / "arrays.npz") as z:
                flat = {k: z[k] for k in z.files}
            manifest = json.loads((path / "manifest.json").read_text())
            # guard: this checkpoint must hold EC center state
            if not any(f"{_SEP}.center" in k for k in flat):
                continue
            # use template structure for center
            tpl_flat, _ = jax.tree_util.tree_flatten_with_path(state_template.center)
            prefix = f"['state']{_SEP}.center"

            def center_key(p):
                suffix = _SEP.join(str(x) for x in p)
                return prefix + (_SEP + suffix if suffix else "")

            center = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(state_template.center),
                [jnp.asarray(flat[center_key(p)]) for p, _ in tpl_flat],
            )
            stub = ECSGHMCState(
                momentum=None, center=center, center_momentum=jax.tree.map(jnp.zeros_like, center),
                center_stale=center, mean_theta_stale=center, step=jnp.asarray(manifest["step"], jnp.int32),
            )
            params, state = resample_chain_from_center(
                stub, alpha=alpha, rng=jax.random.PRNGKey(seed), num_chains=num_chains
            )
            return manifest["step"], params, state, {"elastic_resample": True}
        except Exception as e:
            log.warning(f"elastic restore failed for {path.name}: {e}")
    return None


def prune(ckpt_dir, keep: int = 3):
    ckpts = _checkpoints(ckpt_dir)
    for p in ckpts[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
