"""jit'd dispatch wrappers for the Pallas kernels: shape guards, padding,
platform selection (interpret=True on CPU — the kernel body runs in Python
for validation; compiled on real TPU), and pytree-level entry points."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import bma_select as _bs
from . import flash_attention as _fa
from . import fused_ecsghmc as _fe
from . import paged_attention as _pa
from . import rglru as _rg


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# --- fused EC-SGHMC ----------------------------------------------------------

_LANES = _fe.LANES
_ROWS = _fe.BLOCK_ROWS
_TILE = _LANES * _ROWS


def _pad_flat(x):
    n = x.size
    pad = (-n) % _TILE
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(-1, _LANES), n


@functools.partial(jax.jit, static_argnames=("stochastic_round",))
def fused_ec_update(
    theta, p, g, c_tilde, key,
    *, eps, friction, mass, alpha, sigma_p, stochastic_round=True,
):
    """Single-leaf fused Eq. 6 update. Returns (theta_new, p_new) in the
    input dtypes.  Noise bits: jax.random on CPU-validation path; on-chip
    PRNG on TPU (zero HBM noise traffic)."""
    shape, dtype_t, dtype_p = theta.shape, theta.dtype, p.dtype
    t2, n = _pad_flat(theta)
    p2, _ = _pad_flat(p)
    g2, _ = _pad_flat(g.astype(jnp.float32))
    c2, _ = _pad_flat(jnp.broadcast_to(c_tilde, theta.shape))
    onchip = _on_tpu()
    if onchip:
        bits1 = bits2 = jnp.zeros(t2.shape, jnp.uint32)  # unused on TPU
    else:
        k1, k2 = jax.random.split(key)
        bits1 = jax.random.bits(k1, t2.shape, jnp.uint32)
        bits2 = jax.random.bits(k2, t2.shape, jnp.uint32)
    t_new, p_new = _fe.fused_ec_update_flat(
        t2, p2, g2, c2, bits1, bits2,
        eps=eps, friction=friction, mass=mass, alpha=alpha, sigma_p=sigma_p,
        stochastic_round=stochastic_round, onchip_prng=onchip,
        interpret=not onchip,
    )
    t_new = t_new.reshape(-1)[:n].reshape(shape).astype(dtype_t)
    p_new = p_new.reshape(-1)[:n].reshape(shape).astype(dtype_p)
    return t_new, p_new


def fused_ec_update_tree(params, momentum, grads, center_stale, key, **hyper):
    """Pytree-level fused update (one kernel launch per leaf)."""
    leaves_t, treedef = jax.tree.flatten(params)
    leaves_p = treedef.flatten_up_to(momentum)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_c = treedef.flatten_up_to(center_stale)
    keys = jax.random.split(key, len(leaves_t))
    outs = [
        fused_ec_update(t, p, g, c, k, **hyper)
        for t, p, g, c, k in zip(leaves_t, leaves_p, leaves_g, leaves_c, keys)
    ]
    new_t = treedef.unflatten([o[0] for o in outs])
    new_p = treedef.unflatten([o[1] for o in outs])
    return new_t, new_p


@functools.partial(jax.jit, static_argnames=("stochastic_round",))
def fused_precond_ec_update(
    theta, p, g, c_tilde, minv, key,
    *, eps, friction, alpha, sigma_p, stochastic_round=True,
):
    """Single-leaf preconditioned fused Eq. 6 update: the scalar mass is
    replaced by an elementwise (frozen) diagonal M^-1 streamed as a tensor.
    Same noise/rounding conventions as ``fused_ec_update``."""
    shape, dtype_t, dtype_p = theta.shape, theta.dtype, p.dtype
    t2, n = _pad_flat(theta)
    p2, _ = _pad_flat(p)
    g2, _ = _pad_flat(g.astype(jnp.float32))
    c2, _ = _pad_flat(jnp.broadcast_to(c_tilde, theta.shape))
    m2, _ = _pad_flat(jnp.broadcast_to(minv, theta.shape).astype(jnp.float32))
    onchip = _on_tpu()
    if onchip:
        bits1 = bits2 = jnp.zeros(t2.shape, jnp.uint32)  # unused on TPU
    else:
        k1, k2 = jax.random.split(key)
        bits1 = jax.random.bits(k1, t2.shape, jnp.uint32)
        bits2 = jax.random.bits(k2, t2.shape, jnp.uint32)
    t_new, p_new = _fe.fused_precond_ec_update_flat(
        t2, p2, g2, c2, m2, bits1, bits2,
        eps=eps, friction=friction, alpha=alpha, sigma_p=sigma_p,
        stochastic_round=stochastic_round, onchip_prng=onchip,
        interpret=not onchip,
    )
    t_new = t_new.reshape(-1)[:n].reshape(shape).astype(dtype_t)
    p_new = p_new.reshape(-1)[:n].reshape(shape).astype(dtype_p)
    return t_new, p_new


def fused_precond_ec_update_tree(params, momentum, grads, center_stale, minv, key, **hyper):
    """Pytree-level preconditioned fused update.  Key-split structure is
    identical to ``fused_ec_update_tree`` so the two dispatch paths see the
    same per-leaf noise streams for a given ``key``."""
    leaves_t, treedef = jax.tree.flatten(params)
    leaves_p = treedef.flatten_up_to(momentum)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_c = treedef.flatten_up_to(center_stale)
    leaves_m = treedef.flatten_up_to(minv)
    keys = jax.random.split(key, len(leaves_t))
    outs = [
        fused_precond_ec_update(t, p, g, c, m, k, **hyper)
        for t, p, g, c, m, k in zip(
            leaves_t, leaves_p, leaves_g, leaves_c, leaves_m, keys
        )
    ]
    new_t = treedef.unflatten([o[0] for o in outs])
    new_p = treedef.unflatten([o[1] for o in outs])
    return new_t, new_p


# --- flash attention ---------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale", "block_q", "block_k")
)
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
                    block_q=128, block_k=128):
    """(B, Hq, S, d) x (B, Hkv, S, d)^2 -> (B, Hq, S, d). Pads d to 128."""
    d = q.shape[-1]
    pad_d = (-d) % 128
    if pad_d:
        padder = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        q, k, v = padder(q), padder(k), padder(v)
        # keep softmax scale defined by the ORIGINAL head dim
        scale = scale if scale is not None else 1.0 / np.sqrt(d)
    out = _fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
    )
    return out[..., :d] if pad_d else out


# --- paged attention (decode) ------------------------------------------------


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap"))
def paged_attention(q, k_pages, v_pages, block_tables, context_lens,
                    *, scale=None, window=None, softcap=None):
    """q (B, Hkv, G, d) vs paged pool (num_pages, bs, Hkv, d) through
    (B, M) block tables -> (B, Hkv, G, d).  Pads d to 128 (softmax scale
    keeps the ORIGINAL head dim); context_lens is the inclusive current
    position."""
    d = q.shape[-1]
    pad_d = (-d) % 128
    if pad_d:
        scale = scale if scale is not None else 1.0 / np.sqrt(d)
        pad = lambda x: jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad_d),))
        q, k_pages, v_pages = pad(q), pad(k_pages), pad(v_pages)
    out = _pa.paged_attention(
        q, k_pages, v_pages,
        block_tables.astype(jnp.int32), context_lens.astype(jnp.int32),
        scale=scale, window=window, softcap=softcap, interpret=not _on_tpu(),
    )
    return out[..., :d] if pad_d else out


# --- fused BMA mixture + selection -------------------------------------------


@functools.partial(jax.jit, static_argnames=("mode", "temperature", "top_k"))
def fused_bma_select(logits, key, *, mode="probs", temperature=0.0, top_k=0):
    """(K, S, V) member logits -> (tokens (S,) int32, mixture logp (S, V)
    f32) in one memory pass.  The Gumbel draw happens OUT here with the
    caller's key so sampled tokens are bit-identical to
    ``jax.random.categorical(key, logp/T)`` on the unfused path."""
    K, S, V = logits.shape
    if temperature > 0.0:
        gumbel = jax.random.gumbel(key, (S, V), jnp.float32)
    else:
        gumbel = jnp.zeros((S, V), jnp.float32)
    return _bs.bma_select(
        logits, gumbel,
        mode=mode, temperature=temperature, top_k=top_k,
        interpret=not _on_tpu(),
    )


# --- RG-LRU scan -------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_r", "block_s"))
def rglru_scan(a, x, h0=None, *, block_r=128, block_s=256):
    B, S, R = a.shape
    pad_r = (-R) % min(block_r, max(R, 1))
    if pad_r:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_r)))
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_r)))
        if h0 is not None:
            h0 = jnp.pad(h0, ((0, 0), (0, pad_r)))
    out = _rg.rglru_scan(
        a, x, h0, block_r=block_r, block_s=block_s, interpret=not _on_tpu()
    )
    return out[..., :R] if pad_r else out
