"""Potential energy U(θ) builders — the bridge between models and samplers.

The paper's target:  p(θ|D) ∝ exp(-U(θ)),
    U(θ)  = - Σ_{x∈D} log p(x|θ) - log p(θ)
    Ũ(θ)  = - (N/|B|) Σ_{x∈B} log p(x|θ) - log p(θ)     (minibatch estimate)

``make_potential`` wraps a model ``apply_fn(params, batch) -> per-example
negative log-likelihood`` together with a prior into value/grad functions
usable by any sampler.  For K-stacked chain params, the caller vmaps.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Prior(NamedTuple):
    # potential contribution (i.e. -log p(θ) up to a constant) and nothing else
    energy: Callable


def gaussian_prior(weight_decay: float = 1e-5) -> Prior:
    """-log p(θ) = λ ||θ||²  (the paper's prior with λ = 1e-5 for MNIST)."""

    def energy(params):
        leaves = jax.tree.leaves(params)
        return weight_decay * sum(
            jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves
        )

    return Prior(energy)


def flat_prior() -> Prior:
    return Prior(lambda params: jnp.float32(0.0))


class Potential(NamedTuple):
    value: Callable  # (params, batch) -> Ũ(θ) scalar
    grad: Callable  # (params, batch) -> ∇Ũ(θ) pytree
    value_and_grad: Callable
    nll: Callable  # (params, batch) -> mean per-example NLL (for eval curves)


def make_potential(
    nll_fn: Callable,  # (params, batch) -> (sum_nll_over_batch, batch_size)
    n_data: int,
    prior: Prior | None = None,
) -> Potential:
    prior = prior or flat_prior()

    def value(params, batch):
        sum_nll, bsz = nll_fn(params, batch)
        scale = jnp.float32(n_data) / jnp.maximum(bsz.astype(jnp.float32), 1.0)
        return scale * sum_nll + prior.energy(params)

    def mean_nll(params, batch):
        sum_nll, bsz = nll_fn(params, batch)
        return sum_nll / jnp.maximum(bsz.astype(jnp.float32), 1.0)

    vag = jax.value_and_grad(value)
    return Potential(
        value=value,
        grad=lambda p, b: vag(p, b)[1],
        value_and_grad=vag,
        nll=mean_nll,
    )


def chainwise(potential: Potential) -> Potential:
    """Lift a Potential over a leading chain axis K on params (batch carries a
    matching leading axis: each chain sees its own minibatch)."""
    return Potential(
        value=jax.vmap(potential.value),
        grad=jax.vmap(potential.grad),
        value_and_grad=jax.vmap(potential.value_and_grad),
        nll=jax.vmap(potential.nll),
    )
