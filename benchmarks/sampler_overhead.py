"""Sampler-update overhead (the paper-technique hot loop, model excluded).

Three measurements:

  (1) HOST-DISPATCH vs DEVICE-RESIDENT at paper Fig.-1 scale (K=4 chains,
      2 dims): the removed one-jitted-step-per-Python-iteration driver —
      kept here, and only here, as the measured baseline — against the
      ``ChainExecutor`` scan program.  At this scale a sampler step is
      sub-microsecond, so the old driver measured dispatch latency, not
      sampler math; the acceptance bar is >= 5x steps/s.
  (2) big-state throughput on a 1M-param state (scan-fused; derived column
      = ns/param) for SGHMC / EC-SGHMC sync 1 and 8.
  (3) a hyperparameter GRID (alpha x step_size, per sync period) as ONE
      vmapped compiled program — the sweep axis the benchmarks' Python
      loops used to iterate.

Plus the fused-kernel interpret-mode check (the TPU win is modeled HBM
streams: 6.5 vs ~9 tensor rounds).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro import diagnostics as diag
from repro.kernels import fused_ec_update
from repro.run import ChainExecutor

from common import QUICK, emit, record, time_fn

N = 1 << 20  # 1M params
K = 4
MU = jnp.array([2.0, -1.0])
FIG1_STEPS = 2000 if QUICK else 20_000


def _fig1_sampler(sync: int):
    return core.ec_sghmc(step_size=1e-2, alpha=1.0, friction=1.0, center_friction=1.0,
                         sync_every=sync, noise_convention="eq6")


def _per_step_baseline(sync: int, steps: int) -> float:
    """The removed driver: one jitted step per Python iteration.  Returns
    steps/s (measured, blocking every step like the old loops did)."""
    sampler = _fig1_sampler(sync)
    params = jnp.broadcast_to(jnp.array([-2.0, 3.0])[None], (K, 2)) + 0.0
    state = sampler.init(params)

    @jax.jit
    def step(p, st, key):
        upd, st = sampler.update(p - MU, st, params=p, rng=key)
        return core.apply_updates(p, upd), st

    key = jax.random.PRNGKey(0)
    step(params, state, key)  # compile
    import time

    t0 = time.perf_counter()
    for t in range(steps):
        params, state = step(params, state, jax.random.fold_in(key, t))
    jax.block_until_ready(params)
    return steps / (time.perf_counter() - t0)


def _executor_fig1(sync: int, steps: int):
    sampler = _fig1_sampler(sync)
    keys = jax.random.split(jax.random.PRNGKey(0), steps)
    # ONE executor for warmup + measurement: its jit cache persists across
    # runs, so the second run's wall time is pure compute (the baseline's
    # compile is excluded the same way)
    ex = ChainExecutor(sampler=sampler, grad_fn=lambda p, _b: p - MU,
                       trace_fn=lambda p: p, chunk_steps=min(steps, 4096),
                       key_mode="keys")

    def go():
        params = jnp.broadcast_to(jnp.array([-2.0, 3.0])[None], (K, 2)) + 0.0
        return ex.run(params, sampler.init(params), num_steps=steps, keys=keys)

    go()  # compile
    return go()


def run():
    key = jax.random.PRNGKey(0)
    perf = {"config": {"quick": QUICK, "fig1_steps": FIG1_STEPS, "chains": K}}

    # --- (1) dispatch-bound vs device-resident, Fig.-1 scale --------------
    base_steps = min(FIG1_STEPS, 2000)  # the slow baseline needs mercy
    for sync in (1, 8):
        sps_loop = _per_step_baseline(sync, base_steps)
        res = _executor_fig1(sync, FIG1_STEPS)
        traj = np.moveaxis(np.asarray(res.trace)[FIG1_STEPS // 4 :], 1, 0)
        ess = float(np.sum(diag.effective_sample_size_nd(traj)))
        speedup = res.steps_per_s / sps_loop
        emit(f"overhead/fig1_scale_s{sync}_per_step_driver", 1e6 / sps_loop,
             f"{sps_loop:.0f}_steps_per_s")
        emit(f"overhead/fig1_scale_s{sync}_executor", 1e6 / res.steps_per_s,
             f"{res.steps_per_s:.0f}_steps_per_s")
        emit(f"overhead/fig1_scale_s{sync}_executor_speedup", 0, f"{speedup:.1f}x")
        perf[f"fig1_scale_s{sync}"] = {
            "per_step_driver_steps_per_s": sps_loop,
            "executor_steps_per_s": res.steps_per_s,
            "speedup": speedup,
            "us_per_step": 1e6 / res.steps_per_s,
            "ess_per_s": ess / max(res.wall_s, 1e-9),
        }

    # --- (2) big-state throughput (1M params), scan-fused -----------------
    big_steps = 50
    g1 = jax.random.normal(key, (N,), jnp.float32)
    big_keys = jax.random.split(key, big_steps)

    def _big(sampler, grad_fn, shape):
        ex = ChainExecutor(sampler=sampler, grad_fn=lambda p, _b: grad_fn(p),
                           trace_fn=None, chunk_steps=big_steps, key_mode="keys")

        def go():
            p = jnp.zeros(shape)
            return ex.run(p, sampler.init(p), num_steps=big_steps, keys=big_keys)

        go()  # compile
        return go()

    res = _big(core.sghmc(step_size=1e-3), lambda p: g1, (N,))
    us = 1e6 / res.steps_per_s
    emit("overhead/sghmc_step", us, f"{1e3 * us / N:.3f}")
    perf["sghmc_1m"] = {"us_per_step": us, "steps_per_s": res.steps_per_s}

    for sync in (1, 8):
        ec = core.ec_sghmc(step_size=1e-3, alpha=1.0, sync_every=sync)
        res = _big(ec, lambda p: jnp.broadcast_to(g1[None], (K, N)), (K, N))
        us = 1e6 / res.steps_per_s
        emit(f"overhead/ec_sghmc_s{sync}_step", us, f"{1e3 * us / (K * N):.3f}")
        perf[f"ec_sghmc_1m_s{sync}"] = {"us_per_step": us, "steps_per_s": res.steps_per_s}

    # --- (3) the (alpha, step_size) grid as ONE vmapped program -----------
    alphas = jnp.array([0.0, 0.5, 1.0])
    epss = jnp.array([5e-3, 1e-2])
    aa, ee = jnp.meshgrid(alphas, epss, indexing="ij")
    hyper = {"alpha": aa.reshape(-1), "eps": ee.reshape(-1)}
    grid = int(hyper["alpha"].shape[0])
    sweep_steps = min(FIG1_STEPS, 4000)
    for sync in (1, 8):  # sync period is structural: one program per s
        factory = lambda h: core.ec_sghmc(
            step_size=h["eps"], alpha=h["alpha"], sync_every=sync,
            friction=1.0, center_friction=1.0, noise_convention="eq6")
        keys = jnp.stack([jax.random.split(jax.random.PRNGKey(7 + i), sweep_steps)
                          for i in range(grid)])
        ex = ChainExecutor(sampler_factory=factory,
                           grad_fn=lambda p, _b: p - MU,
                           trace_fn=None, chunk_steps=sweep_steps, key_mode="keys")

        def go():
            p0 = jnp.broadcast_to(jnp.array([-2.0, 3.0])[None, None], (grid, K, 2)) + 0.0
            st0 = jax.vmap(lambda h, p: factory(h).init(p))(hyper, p0)
            return ex.run(p0, st0, num_steps=sweep_steps, keys=keys, hyper=hyper)

        go()  # compile
        res = go()
        total = res.steps_per_s * grid  # grid members advance in lockstep
        emit(f"overhead/sweep_grid{grid}_s{sync}_steps_per_s", 1e6 / res.steps_per_s,
             f"{total:.0f}_total")
        perf[f"sweep_s{sync}"] = {
            "grid_points": grid, "steps_per_s_per_member": res.steps_per_s,
            "steps_per_s_total": total,
        }

    # --- (4) telemetry overhead: instrumented-vs-off ----------------------
    # Two views, because shared-box wall-clock noise (we observe +-4% run to
    # run) dwarfs the true span cost:
    #   span_ns        -- the primitive cost, measured directly (deterministic)
    #   overhead_pct   -- end-to-end instrumented-vs-off on the executor loop:
    #                     off/on runs back-to-back in alternating order (pairs
    #                     share thermal state), median of paired differences,
    #                     best of 3 independent trials.  ci.sh gates < 3.
    from repro import obs

    # this section toggles the module tracer; hand back whatever was
    # installed (benchmarks/run.py --trace) when done
    prev_tracer = obs.trace.get()

    span_iters = 10_000
    tr = obs.enable_tracing(capacity=1 << 12)
    t0 = time.perf_counter()
    for i in range(span_iters):
        with tr.span("bench.span", cat="bench", i=i):
            pass
    span_ns = 1e9 * (time.perf_counter() - t0) / span_iters
    tr.enabled = False
    t0 = time.perf_counter()
    for i in range(span_iters):
        with tr.span("bench.span", cat="bench", i=i):
            pass
    noop_ns = 1e9 * (time.perf_counter() - t0) / span_iters
    obs.disable_tracing()

    obs_sampler = _fig1_sampler(1)
    obs_steps, obs_chunk = 10_000, 256
    obs_keys = jax.random.split(jax.random.PRNGKey(3), obs_steps)
    obs_ex = ChainExecutor(sampler=obs_sampler, grad_fn=lambda p, _b: p - MU,
                           trace_fn=None, chunk_steps=obs_chunk, key_mode="keys")

    def obs_go():
        p = jnp.broadcast_to(jnp.array([-2.0, 3.0])[None], (K, 2)) + 0.0
        return obs_ex.run(p, obs_sampler.init(p), num_steps=obs_steps, keys=obs_keys)

    obs_go()  # compile
    obs_go()  # one more warm pass before timing
    trials = []
    off_wall = on_wall = None
    try:
        for _ in range(3):
            tr = obs.enable_tracing(capacity=1 << 12)  # one ring per trial
            diffs, offs = [], []
            for i in range(12):
                pair = {}
                for on in ((False, True) if i % 2 == 0 else (True, False)):
                    tr.enabled = on
                    pair[on] = obs_go().wall_s
                diffs.append(pair[True] - pair[False])
                offs.append(pair[False])
            trials.append((100.0 * float(np.median(diffs)) / float(np.median(offs)),
                           float(np.median(offs))))
            obs.disable_tracing()
    finally:
        obs.trace.install(prev_tracer)
    pct, off_wall = min(trials)
    spans_per_run = 2 * (obs_steps // obs_chunk) + 1  # chunk each + final settle
    emit("overhead/obs_span_ns", span_ns / 1e3, f"noop_{noop_ns:.0f}ns")
    emit("overhead/obs_tracer_on_vs_off", 1e4 * pct * off_wall / obs_steps,
         f"{pct:.2f}pct")
    perf["obs_overhead"] = {
        "span_ns": span_ns,
        "noop_span_ns": noop_ns,
        "off_wall_s": off_wall,
        "overhead_pct": pct,
        "trials_pct": [round(t[0], 3) for t in trials],
        "spans_per_run": spans_per_run,
        # deterministic bound: what the spans themselves can possibly cost
        "implied_pct": 100.0 * spans_per_run * span_ns / 1e9 / max(off_wall, 1e-12),
    }

    # --- fused kernel (interpret mode on CPU: correctness path; the TPU
    # win is modeled HBM streams: 6.5 vs ~9 tensor rounds) ---
    theta = jnp.zeros((N,), jnp.float32)
    us = time_fn(
        lambda: fused_ec_update(
            theta, theta, g1, theta, key,
            eps=1e-3, friction=1.0, mass=1.0, alpha=1.0, sigma_p=1e-2,
            stochastic_round=False,
        ),
        iters=2, warmup=1,
    )
    emit("overhead/fused_kernel_interpret", us, f"{1e3 * us / N:.3f}")
    emit("overhead/fused_kernel_modeled_hbm_streams", 0, "6.5_vs_9_xla")

    record("perf", perf)
    return {f"speedup_s{s}": perf[f"fig1_scale_s{s}"]["speedup"] for s in (1, 8)}


if __name__ == "__main__":
    run()
