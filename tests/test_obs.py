"""Observability battery (DESIGN.md §11): metrics registry, tracer ring
buffer, Perfetto export + schema validation, structured logging — and the
two load-bearing pins from the issue:

* **zero-cost when off** — a full engine run with the tracer disabled
  makes ZERO tracer clock reads (``trace._now`` is monkeypatched to
  count), produces bit-identical tokens to an instrumented run, and the
  one-compiled-decode-program pin survives instrumentation;
* **valid timeline when on** — a traced serve run with live refresh
  exports Chrome/Perfetto JSON containing the decode-tick, micro-chunk,
  flip/defer and (EC cadence) sync-collective spans, checked by the same
  validator ``scripts/ci.sh`` runs.
"""
from __future__ import annotations

import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.log import get_logger
from repro.obs.sinks import MANIFEST_KEYS, JsonlSink, run_manifest
from repro.obs.validate import REQUIRED, validate_manifest, validate_trace
from repro.run import ChainExecutor
from repro.serve.engine import (
    RefreshScheduler,
    ServeEngine,
    SnapshotRegistry,
    synthetic_trace,
)

from test_serve_engine import member_stack, tiny_cfg
from util import import_hypothesis

given, settings, st = import_hypothesis()


@pytest.fixture(autouse=True)
def _clean_obs(monkeypatch):
    """Every test starts from the disabled NULL tracer and a fresh default
    registry, and cannot leak REPRO_LOG* into its neighbours."""
    monkeypatch.delenv("REPRO_LOG", raising=False)
    monkeypatch.delenv("REPRO_LOG_FORMAT", raising=False)
    obs_trace.disable()
    obs_metrics.reset_default()
    yield
    obs_trace.disable()
    obs_metrics.reset_default()


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_monotone(self):
        c = obs_metrics.Counter("x_total")
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_last_write_wins(self):
        g = obs_metrics.Gauge("x")
        g.set(3)
        g.set(jnp.asarray(2.5))  # jnp scalars coerce
        assert g.value == 2.5

    def test_histogram_summary_and_quantiles(self):
        h = obs_metrics.Histogram("lat_s", lo=1e-3, hi=1e2, n=50)
        vals = [0.01 * (i + 1) for i in range(100)]  # 0.01 .. 1.0
        for v in vals:
            h.observe(v)
        s = h.summary()
        assert s["count"] == 100
        assert s["min"] == pytest.approx(0.01) and s["max"] == pytest.approx(1.0)
        assert s["mean"] == pytest.approx(float(np.mean(vals)))
        # log-spaced buckets: interpolated quantiles land within a bucket
        # width of the exact order statistic
        assert s["p50"] == pytest.approx(0.5, rel=0.3)
        assert s["p99"] == pytest.approx(1.0, rel=0.3)

    def test_histogram_edge_clamping(self):
        h = obs_metrics.Histogram("x_s", lo=1e-3, hi=1.0, n=8)
        h.observe(1e-9)  # underflow -> first bucket
        h.observe(1e9)  # overflow -> last bucket
        assert h.counts[0] == 1 and h.counts[-1] == 1
        assert math.isnan(obs_metrics.Histogram("y_s").quantile(0.5))

    def test_registry_type_mismatch_raises(self):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("a_total")
        with pytest.raises(TypeError):
            reg.gauge("a_total")

    def test_absorb_renames_and_is_idempotent(self):
        reg = obs_metrics.MetricsRegistry()
        legacy = {"num_slots": 4, "active": 2, "acquired": 17, "device": "cpu:0"}
        reg.absorb("serve.pool", legacy)
        reg.absorb("serve.pool", legacy)  # cumulative source: no double count
        snap = reg.snapshot()
        assert snap["serve.pool.slots"] == 4
        assert snap["serve.pool.slots_active"] == 2
        assert snap["serve.pool.slots_acquired_total"] == 17
        assert not any("device" in k for k in snap)  # non-numeric skipped
        assert reg._metrics["serve.pool.slots_acquired_total"].kind == "counter"
        assert reg._metrics["serve.pool.slots"].kind == "gauge"

    def test_absorb_passthrough_for_canonical_keys(self):
        reg = obs_metrics.MetricsRegistry()
        reg.absorb("serve.alloc", {"blocks_high_water": 7, "prefix_hits": 3})
        snap = reg.snapshot()
        assert snap["serve.alloc.blocks_high_water"] == 7
        assert snap["serve.alloc.prefix_hits_total"] == 3

    def test_dump_jsonl(self, tmp_path):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("a_total").inc(2)
        p = tmp_path / "m.jsonl"
        reg.dump_jsonl(p)
        rec = json.loads(p.read_text().splitlines()[0])
        assert rec == {"kind": "metrics", "a_total": 2}


# ---------------------------------------------------------------------------
# tracer ring buffer
# ---------------------------------------------------------------------------


def _fill(tr, n):
    for i in range(n):
        tr.instant(f"e{i}", cat="serve", i=i)


class TestTracerRing:
    def test_wraparound_keeps_newest_in_order(self):
        tr = obs_trace.Tracer(capacity=8)
        _fill(tr, 20)
        assert len(tr) == 8
        assert tr.dropped == 12
        assert [e[1] for e in tr.events()] == [f"e{i}" for i in range(12, 20)]
        ts = [e[3] for e in tr.events()]
        assert ts == sorted(ts)  # chronological after rotation

    def test_no_wrap_is_plain_prefix(self):
        tr = obs_trace.Tracer(capacity=8)
        _fill(tr, 3)
        assert len(tr) == 3 and tr.dropped == 0
        assert [e[1] for e in tr.events()] == ["e0", "e1", "e2"]

    @given(cap=st.integers(min_value=1, max_value=16),
           n=st.integers(min_value=0, max_value=50))
    @settings(max_examples=50, deadline=None)
    def test_wraparound_property(self, cap, n):
        tr = obs_trace.Tracer(capacity=cap)
        _fill(tr, n)
        assert len(tr) == min(n, cap)
        assert tr.dropped == max(0, n - cap)
        assert [e[1] for e in tr.events()] == [f"e{i}" for i in range(max(0, n - cap), n)]

    def test_wraparound_fallback_grid(self):
        # deterministic stand-in for the property test in bare envs
        for cap in (1, 2, 3, 7, 8):
            for n in (0, 1, cap - 1, cap, cap + 1, 3 * cap + 2):
                if n < 0:
                    continue
                tr = obs_trace.Tracer(capacity=cap)
                _fill(tr, n)
                assert len(tr) == min(n, cap)
                assert tr.dropped == max(0, n - cap)
                assert [e[1] for e in tr.events()] == [
                    f"e{i}" for i in range(max(0, n - cap), n)
                ]

    def test_span_records_duration(self):
        tr = obs_trace.Tracer(capacity=4)
        with tr.span("work", cat="executor", step=3):
            pass
        (ph, name, cat, ts, dur, args) = tr.events()[0]
        assert (ph, name, cat) == ("X", "work", "executor")
        assert dur >= 0 and args == {"step": 3}

    def test_install_restores_a_saved_tracer(self):
        # scoped measurements (the obs-overhead bench) must be able to hand
        # back whatever tracer --trace installed
        outer = obs_trace.enable(capacity=4)
        obs_trace.enable(capacity=4)  # stomps the module tracer
        assert obs_trace.get() is not outer
        assert obs_trace.install(outer) is outer
        assert obs_trace.get() is outer

    def test_disabled_tracer_hands_out_shared_noop(self):
        tr = obs_trace.Tracer(capacity=4, enabled=False)
        s1 = tr.span("a")
        s2 = tr.span("b")
        assert s1 is s2  # one shared object, no allocation per call
        with s1:
            pass
        tr.instant("c")
        assert len(tr) == 0


# ---------------------------------------------------------------------------
# chrome export + validator
# ---------------------------------------------------------------------------


MANIFEST_STUB = {k: (1 if k == "device_count" else "x") for k in MANIFEST_KEYS}


class TestExportAndValidate:
    def test_to_chrome_structure(self):
        tr = obs_trace.Tracer(capacity=16)
        with tr.span("serve.decode_tick", cat="serve", step=0):
            tr.instant("alloc.reserve", cat="alloc", slot=1)
        obj = tr.to_chrome(manifest=MANIFEST_STUB)
        assert obj["displayTimeUnit"] == "ms"
        assert obj["otherData"]["dropped_events"] == 0
        evs = obj["traceEvents"]
        assert evs[0]["ph"] == "M" and evs[0]["name"] == "process_name"
        tracks = {e["args"]["name"]: e["tid"] for e in evs if e.get("name") == "thread_name"}
        assert tracks == {"serve": 0, "alloc": 3}  # one track per category
        assert validate_trace(obj) == []

    def test_export_roundtrip(self, tmp_path):
        tr = obs_trace.Tracer(capacity=4)
        tr.instant("serve.admit", cat="serve")
        path = tmp_path / "trace.json"
        tr.export(path, manifest=MANIFEST_STUB)
        assert validate_trace(str(path)) == []

    def test_validator_catches_malformed_events(self):
        bad = {
            "traceEvents": [
                {"ph": "Z", "name": "a", "pid": 0, "tid": 0},  # bad phase
                {"ph": "X", "name": "b", "pid": 0, "tid": 0, "ts": 0.0},  # no dur
                {"ph": "i", "pid": 0, "tid": 0, "ts": 1.0, "s": "t"},  # no name
            ],
        }
        errs = validate_trace(bad)
        assert any("bad ph" in e for e in errs)
        assert any("non-negative dur" in e for e in errs)
        assert any("missing name" in e for e in errs)
        assert any("manifest" in e for e in errs)

    def test_validator_required_profiles(self):
        tr = obs_trace.Tracer(capacity=8)
        tr.instant("executor.chunk", cat="executor")
        obj = tr.to_chrome(manifest=MANIFEST_STUB)
        assert validate_trace(obj, REQUIRED["executor"]) == []
        errs = validate_trace(obj, REQUIRED["serve"])
        assert any("serve.decode_tick" in e for e in errs)

    def test_run_manifest_complete(self):
        m = run_manifest()
        assert validate_manifest(m) == []
        assert m["device_count"] >= 1
        assert m["backend"] in ("cpu", "gpu", "tpu")

    def test_jsonl_sink_stream(self, tmp_path):
        p = tmp_path / "run.jsonl"
        sink = JsonlSink(p)
        sink.metrics({"a_total": 1}, step=7)
        sink.summary({"a_total": 2}, bench="x")
        lines = [json.loads(line) for line in p.read_text().splitlines()]
        assert [rec["kind"] for rec in lines] == ["manifest", "metrics", "summary"]
        assert validate_manifest({k: lines[0][k] for k in lines[0] if k != "kind"}) == []
        assert lines[1]["step"] == 7 and lines[2]["bench"] == "x"


# ---------------------------------------------------------------------------
# structured logging
# ---------------------------------------------------------------------------


class TestLogging:
    def test_human_format_and_levels(self, capsys, monkeypatch):
        log = get_logger("loop")
        log.info("step 3: nll=1.25", chains=4)
        monkeypatch.setenv("REPRO_LOG", "off")
        log.info("suppressed")
        out = capsys.readouterr().out
        assert out == "[loop] step 3: nll=1.25 chains=4\n"

    def test_warning_goes_to_stderr(self, capsys):
        get_logger("ckpt").warning("skipping bad.ckpt")
        cap = capsys.readouterr()
        assert cap.out == "" and "[ckpt] skipping bad.ckpt" in cap.err

    def test_debug_below_default_threshold(self, capsys, monkeypatch):
        log = get_logger("x")
        log.debug("hidden")
        monkeypatch.setenv("REPRO_LOG", "debug")
        log.debug("shown")
        assert capsys.readouterr().out == "[x] shown\n"

    def test_json_format(self, capsys, monkeypatch):
        monkeypatch.setenv("REPRO_LOG_FORMAT", "json")
        get_logger("serve").info("done", requests=6)
        rec = json.loads(capsys.readouterr().out)
        assert rec == {"level": "info", "logger": "serve", "msg": "done", "requests": 6}


# ---------------------------------------------------------------------------
# the zero-cost-when-off pins (executor + engine)
# ---------------------------------------------------------------------------


def _count_clock(monkeypatch):
    calls = {"n": 0}
    real = obs_trace._now

    def counting():
        calls["n"] += 1
        return real()

    monkeypatch.setattr(obs_trace, "_now", counting)
    return calls


def _executor_samples(steps=64):
    sampler = core.ec_sghmc(step_size=1e-2, alpha=1.0, sync_every=4)
    mu = jnp.array([1.0, -2.0])
    ex = ChainExecutor(sampler=sampler, grad_fn=lambda p, _b: p - mu,
                       trace_fn=lambda p: p, chunk_steps=16, key_mode="keys")
    keys = jax.random.split(jax.random.PRNGKey(0), steps)
    p0 = jnp.zeros((4, 2))
    res = ex.run(p0, sampler.init(p0), num_steps=steps, keys=keys)
    return np.asarray(res.trace)


class TestZeroCostOff:
    def test_executor_off_makes_no_clock_reads_and_is_bit_identical(self, monkeypatch):
        ref = _executor_samples()
        calls = _count_clock(monkeypatch)
        off = _executor_samples()
        assert calls["n"] == 0  # disabled tracer never touched the clock
        np.testing.assert_array_equal(ref, off)
        tr = obs_trace.enable(capacity=1 << 10)
        on = _executor_samples()
        assert calls["n"] > 0
        np.testing.assert_array_equal(ref, on)  # samples don't see the tracer
        assert "executor.chunk" in tr.names() and "executor.settle" in tr.names()

    def test_engine_off_vs_on_bit_identical_and_pin_holds(self, monkeypatch):
        cfg = tiny_cfg()
        from repro.models import get_model

        model = get_model(cfg)
        stack = member_stack(cfg, model, 2)

        def serve():
            engine = ServeEngine(cfg, model, stack, num_slots=2, max_seq=16)
            reqs = synthetic_trace(4, vocab_size=cfg.vocab_size, prompt_lens=(5,),
                                   max_new=6, mean_interarrival=2.0, seed=9)
            report = engine.run(reqs)
            assert report.trace_counts["decode"] == 1, report.trace_counts
            return [np.asarray(r.tokens) for r in sorted(report.results, key=lambda r: r.rid)]

        calls = _count_clock(monkeypatch)
        toks_off = serve()
        assert calls["n"] == 0  # full engine run, zero tracer clock reads
        tr = obs_trace.enable(capacity=1 << 12)
        toks_on = serve()
        for a, b in zip(toks_off, toks_on):
            np.testing.assert_array_equal(a, b)
        assert {"serve.decode_tick", "serve.admit", "serve.retire"} <= tr.names()

    def test_enabled_tracer_records_host_scalars_only(self):
        # recording must never capture device arrays (that would add host
        # syncs at export time); every span/instant arg is a host scalar
        tr = obs_trace.enable(capacity=1 << 12)
        _executor_samples()
        for ev in tr.events():
            for v in ev[5].values():
                assert not isinstance(v, jnp.ndarray), ev


# ---------------------------------------------------------------------------
# traced serve run with live refresh (the enabled-path acceptance)
# ---------------------------------------------------------------------------


def _refresh_engine(sampler, sync_every=None, k=2):
    cfg = tiny_cfg()
    from repro.models import get_model

    model = get_model(cfg)
    stack = member_stack(cfg, model, k)
    center = jax.tree.map(lambda x: x[0], stack)
    grad_fn = lambda p: jax.tree.map(lambda x, c: 2500.0 * (x - c), p, center)
    start = jax.tree.map(lambda x: jnp.broadcast_to(x[0][None], x.shape) + 0.0, stack)
    reg = SnapshotRegistry(stack)
    sched = RefreshScheduler(
        reg, sampler, grad_fn, start, key=jax.random.PRNGKey(8), chunk_steps=4,
        sync_every=sync_every,
    )
    engine = ServeEngine(cfg, model, reg, num_slots=2, max_seq=24,
                         refresher=sched, refresh_every=2)
    reqs = synthetic_trace(6, vocab_size=cfg.vocab_size, prompt_lens=(5,),
                           max_new=8, mean_interarrival=1.5, seed=4)
    return engine, reqs


class TestTracedServe:
    def test_traced_serve_with_live_refresh_exports_valid_profile(self, tmp_path):
        tr = obs_trace.enable(capacity=1 << 14)
        engine, reqs = _refresh_engine(core.sgld(step_size=8e-5))
        report = engine.run(reqs)
        assert report.trace_counts["decode"] == 1
        path = tmp_path / "trace.json"
        tr.export(path)
        assert validate_trace(str(path), REQUIRED["serve"]) == []

    def test_traced_ec_serve_reconstructs_sync_collectives(self, tmp_path):
        tr = obs_trace.enable(capacity=1 << 14)
        engine, reqs = _refresh_engine(
            core.ec_sghmc(step_size=8e-5, alpha=1.0, sync_every=4), sync_every=4
        )
        engine.run(reqs)
        obj = tr.export(tmp_path / "trace.json")
        assert validate_trace(obj, REQUIRED["serve_ec"]) == []
        syncs = [e for e in obj["traceEvents"]
                 if e.get("name") == "sampler.sync_collective"]
        # host-reconstructed at the static cadence: strictly increasing
        # multiples of sync_every
        steps = [e["args"]["step"] for e in syncs]
        assert steps and steps == sorted(steps)
        assert all(s % 4 == 0 for s in steps)

    def test_engine_run_absorbs_canonical_metrics(self):
        engine, reqs = _refresh_engine(core.sgld(step_size=8e-5))
        report = engine.run(reqs)
        snap = obs_metrics.default_registry().snapshot()
        assert snap["serve.engine.decode_steps_total"] == report.decode_steps
        assert snap["serve.engine.tokens_total"] == report.total_tokens
        assert snap["serve.pool.slots"] == 2
        assert snap["serve.refresh.micro_chunks_total"] >= 1
        assert snap["serve.request.latency_s"]["count"] == len(report.results)
