"""Blocked (flash) attention Pallas kernel — the model-side FLOP hot spot.

TPU-native design:
  * grid (B, Hq, Sq/bq, Sk/bk); the k dimension is the innermost
    ("arbitrary") axis with online-softmax state carried in VMEM scratch,
  * blocks sized to the MXU (bq x d and bk x d tiles, d a multiple of 128
    via padding in ops.py),
  * GQA folded into the index map (k/v blocks fetched once per kv-head),
  * sliding-window and causal masking SKIP whole k-blocks via pl.when —
    gemma/danube locality becomes block sparsity, not masked-out FLOPs,
  * optional logit softcap (gemma2/grok) fused into the score tile.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams across jax releases;
# accept either so the kernels track the installed toolchain
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, window, softcap, bq, bk, num_kblocks,
):
    i = pl.program_id(2)  # query block
    j = pl.program_id(3)  # key block (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * bq
    k_start = j * bk
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_start <= q_start + bq - 1
    if window is not None:
        relevant &= k_start + bk - 1 >= q_start - window + 1

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = l_scr[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == num_kblocks - 1)
    def _finish():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *, causal: bool = True, window=None, softcap=None, scale=None,
    block_q: int = 128, block_k: int = 128, interpret: bool = True,
):
    """q: (B, Hq, S, d); k, v: (B, Hkv, S, d) -> (B, Hq, S, d)."""
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    grid = (B, Hq, S // bq, S // bk)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, num_kblocks=S // bk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),  # m (lane-replicated)
            pltpu.VMEM((bq, 128), jnp.float32),  # l
            pltpu.VMEM((bq, d), jnp.float32),  # acc
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
