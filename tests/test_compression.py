"""Unit tests for the int8 sync-compression codec
(``repro.distributed.compression``): round-trip error bounds, shape/pad
handling, wire ratio, and the EC-SGHMC integration path whose soundness
argument (quantization error absorbed into the center-noise covariance C —
DESIGN.md §2) justifies compressing the one collective."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.distributed import Int8Codec, int8_codec
from repro.distributed.compression import BLOCK


@pytest.fixture(scope="module")
def codec():
    return int8_codec()


class TestRoundTrip:
    @pytest.mark.parametrize("shape", [(BLOCK,), (1000,), (3, 7, 11), (1,), (256, 4)])
    def test_error_within_quantization_bound(self, codec, shape):
        """|decode(encode(x)) - x| <= scale/2 per block, scale = max|block|/127."""
        x = jax.random.normal(jax.random.PRNGKey(hash(shape) % 2**31), shape) * 3.0
        dec = codec.decode(codec.encode(x))
        assert dec.shape == shape and dec.dtype == jnp.float32

        flat = np.asarray(x, np.float32).reshape(-1)
        err = np.abs(np.asarray(dec).reshape(-1) - flat)
        pad = (-flat.size) % BLOCK
        blocks = np.pad(flat, (0, pad)).reshape(-1, BLOCK)
        scale = np.abs(blocks).max(axis=1, keepdims=True) / 127.0
        bound = np.repeat(scale, BLOCK, axis=1).reshape(-1)[: flat.size]
        assert np.all(err <= 0.5 * bound + 1e-7), float((err - 0.5 * bound).max())

    def test_zeros_exact(self, codec):
        x = jnp.zeros((513,))
        np.testing.assert_array_equal(np.asarray(codec.decode(codec.encode(x))), 0.0)

    def test_extremes_exact(self, codec):
        """Block maxima map to ±127 exactly and decode losslessly."""
        x = jnp.concatenate([jnp.full((BLOCK,), 2.0), jnp.full((BLOCK,), -5.0)])
        dec = np.asarray(codec.decode(codec.encode(x)))
        np.testing.assert_allclose(dec[:BLOCK], 2.0, rtol=1e-6)
        np.testing.assert_allclose(dec[BLOCK:], -5.0, rtol=1e-6)

    def test_per_block_scales_isolate_outliers(self, codec):
        """A huge value in one block must not destroy the resolution of the
        others — the point of per-block scaling."""
        x = jnp.concatenate([jnp.full((BLOCK,), 1e4), 0.01 * jnp.arange(BLOCK, dtype=jnp.float32)])
        dec = np.asarray(codec.decode(codec.encode(x)))
        small = np.asarray(x)[BLOCK:]
        assert np.abs(dec[BLOCK:] - small).max() <= (small.max() / 127.0) * 0.5 + 1e-7

    def test_wire_format(self, codec):
        enc = codec.encode(jnp.ones((1000,)))
        assert enc["q"].dtype == jnp.int8
        assert enc["q"].shape == (4, BLOCK)  # 1000 padded to 4 blocks
        assert enc["n"] == 1000 and enc["shape"] == (1000,)
        # int8 payload + one f32 scale per block, vs f32
        assert codec.ratio == pytest.approx((1 + 4 / BLOCK) / 4)
        assert codec.ratio < 0.26

    def test_reexport(self):
        """Satellite: the codec is part of the public distributed API."""
        import repro.distributed as dist

        assert dist.int8_codec is int8_codec
        assert isinstance(int8_codec(), Int8Codec)


class TestECSGHMCIntegration:
    def test_compressed_sync_stays_close(self):
        """One sync step with the codec wrapping the exchanged mean: the
        resulting center snapshot differs from the uncompressed run by at
        most the quantization bound, and the dynamics stay finite."""
        kw = dict(step_size=1e-2, alpha=1.0, sync_every=1, noise_convention="eq6")
        plain = core.ec_sghmc(**kw)
        comp = core.ec_sghmc(compression=int8_codec(), **kw)
        params = jax.random.normal(jax.random.PRNGKey(0), (4, 600))
        rng = jax.random.PRNGKey(1)

        def step(sampler, p):
            st = sampler.init(p)
            upd, st = sampler.update(0.1 * p, st, params=p, rng=rng)
            return core.apply_updates(p, upd), st

        p1, st1 = step(plain, params)
        p2, st2 = step(comp, params)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))  # params untouched by codec
        m1 = np.asarray(st1.mean_theta_stale)
        m2 = np.asarray(st2.mean_theta_stale)
        bound = np.abs(m1).max() / 127.0
        assert np.abs(m1 - m2).max() <= bound + 1e-7
        assert np.all(np.isfinite(m2))
