from .loop import (
    collect_ensemble,
    ensemble_diagnostics,
    generate,
    make_decode_step,
    make_prefill_step,
)
from .sampling import GREEDY, SamplingParams, mask_after_eos, select_tokens

__all__ = [
    "GREEDY",
    "SamplingParams",
    "collect_ensemble",
    "ensemble_diagnostics",
    "generate",
    "make_decode_step",
    "make_prefill_step",
    "mask_after_eos",
    "select_tokens",
]
