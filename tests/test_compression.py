"""Unit tests for the int8 sync-compression codec
(``repro.distributed.compression``): round-trip error bounds, shape/pad
handling, wire ratio, and the EC-SGHMC integration path whose soundness
argument (quantization error absorbed into the center-noise covariance C —
DESIGN.md §2) justifies compressing the one collective."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from util import import_hypothesis

from repro import core
from repro.distributed import Int8Codec, int8_codec
from repro.distributed.compression import (
    BLOCK,
    decode_packed,
    encode_packed,
    packed_nbytes,
    sync_wire_bytes,
)

given, settings, st = import_hypothesis()


@pytest.fixture(scope="module")
def codec():
    return int8_codec()


def _quant_bound(flat):
    """Elementwise round-trip bound: scale/2 per block, scale=max|block|/127."""
    pad = (-flat.size) % BLOCK
    blocks = np.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = np.abs(blocks).max(axis=1, keepdims=True) / 127.0
    return np.repeat(scale, BLOCK, axis=1).reshape(-1)[: flat.size]


class TestRoundTrip:
    @pytest.mark.parametrize("shape", [(BLOCK,), (1000,), (3, 7, 11), (1,), (256, 4)])
    def test_error_within_quantization_bound(self, codec, shape):
        """|decode(encode(x)) - x| <= scale/2 per block, scale = max|block|/127."""
        x = jax.random.normal(jax.random.PRNGKey(hash(shape) % 2**31), shape) * 3.0
        dec = codec.decode(codec.encode(x))
        assert dec.shape == shape and dec.dtype == jnp.float32

        flat = np.asarray(x, np.float32).reshape(-1)
        err = np.abs(np.asarray(dec).reshape(-1) - flat)
        pad = (-flat.size) % BLOCK
        blocks = np.pad(flat, (0, pad)).reshape(-1, BLOCK)
        scale = np.abs(blocks).max(axis=1, keepdims=True) / 127.0
        bound = np.repeat(scale, BLOCK, axis=1).reshape(-1)[: flat.size]
        assert np.all(err <= 0.5 * bound + 1e-7), float((err - 0.5 * bound).max())

    def test_zeros_exact(self, codec):
        x = jnp.zeros((513,))
        np.testing.assert_array_equal(np.asarray(codec.decode(codec.encode(x))), 0.0)

    def test_extremes_exact(self, codec):
        """Block maxima map to ±127 exactly and decode losslessly."""
        x = jnp.concatenate([jnp.full((BLOCK,), 2.0), jnp.full((BLOCK,), -5.0)])
        dec = np.asarray(codec.decode(codec.encode(x)))
        np.testing.assert_allclose(dec[:BLOCK], 2.0, rtol=1e-6)
        np.testing.assert_allclose(dec[BLOCK:], -5.0, rtol=1e-6)

    def test_per_block_scales_isolate_outliers(self, codec):
        """A huge value in one block must not destroy the resolution of the
        others — the point of per-block scaling."""
        x = jnp.concatenate([jnp.full((BLOCK,), 1e4), 0.01 * jnp.arange(BLOCK, dtype=jnp.float32)])
        dec = np.asarray(codec.decode(codec.encode(x)))
        small = np.asarray(x)[BLOCK:]
        assert np.abs(dec[BLOCK:] - small).max() <= (small.max() / 127.0) * 0.5 + 1e-7

    def test_wire_format(self, codec):
        enc = codec.encode(jnp.ones((1000,)))
        assert enc["q"].dtype == jnp.int8
        assert enc["q"].shape == (4, BLOCK)  # 1000 padded to 4 blocks
        assert enc["n"] == 1000 and enc["shape"] == (1000,)
        # int8 payload + one f32 scale per block, vs f32
        assert codec.ratio == pytest.approx((1 + 4 / BLOCK) / 4)
        assert codec.ratio < 0.26

    def test_reexport(self):
        """Satellite: the codec is part of the public distributed API."""
        import repro.distributed as dist

        assert dist.int8_codec is int8_codec
        assert isinstance(int8_codec(), Int8Codec)


class TestPaddingEdges:
    """The pad-to-BLOCK boundary cases: n % BLOCK in {0, 1, 255} — full
    blocks, a lone element in the last block, and one-short-of-full."""

    @pytest.mark.parametrize("rem", [0, 1, BLOCK - 1])
    @pytest.mark.parametrize("nblocks", [1, 3])
    def test_roundtrip_at_block_remainders(self, codec, rem, nblocks):
        n = nblocks * BLOCK + rem
        x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 2.0
        dec = np.asarray(codec.decode(codec.encode(x)))
        assert dec.shape == (n,)
        flat = np.asarray(x, np.float32)
        assert np.all(np.abs(dec - flat) <= 0.5 * _quant_bound(flat) + 1e-7)

    @pytest.mark.parametrize("rem", [0, 1, BLOCK - 1])
    def test_packed_roundtrip_at_block_remainders(self, rem):
        n = 2 * BLOCK + rem
        x = jax.random.normal(jax.random.PRNGKey(1000 + n), (n,), jnp.float32)
        packed = encode_packed(x)
        assert packed.dtype == jnp.int8 and packed.shape == (packed_nbytes(n),)
        dec = np.asarray(decode_packed(packed, (n,), n))
        flat = np.asarray(x, np.float32)
        assert np.all(np.abs(dec - flat) <= 0.5 * _quant_bound(flat) + 1e-7)

    def test_zero_blocks_exact(self, codec):
        """All-zero blocks (scale 0) must decode to exact zeros, not NaN
        from a 0/0 — including mixed zero/non-zero block layouts."""
        x = jnp.concatenate([jnp.zeros((BLOCK,)), jnp.ones((BLOCK,)), jnp.zeros((5,))])
        dec = np.asarray(codec.decode(codec.encode(x)))
        np.testing.assert_array_equal(dec[:BLOCK], 0.0)
        np.testing.assert_array_equal(dec[2 * BLOCK :], 0.0)
        np.testing.assert_allclose(dec[BLOCK : 2 * BLOCK], 1.0, rtol=1e-6)
        packed = np.asarray(decode_packed(encode_packed(x), x.shape, x.size))
        np.testing.assert_array_equal(packed[:BLOCK], 0.0)

    def test_denormal_inputs_finite(self, codec):
        """Subnormal f32 magnitudes produce subnormal scales; the decode
        must stay finite with no 1/scale overflow.  The scale/2 bound does
        NOT survive subnormal rounding — the contract degrades to 'error
        never exceeds the block magnitude', which is what keeps the sync
        sound (errors this size vanish into the center-noise covariance)."""
        tiny = np.float32(1e-42)  # subnormal
        x = jnp.asarray(np.array([tiny, -tiny, 0.0, tiny / 2] * 64, np.float32))
        dec = np.asarray(codec.decode(codec.encode(x)))
        assert np.all(np.isfinite(dec))
        flat = np.asarray(x, np.float32)
        assert np.abs(dec - flat).max() <= 2 * np.abs(flat).max()


class TestProperties:
    """Hypothesis round-trip properties (skip cleanly without hypothesis —
    the deterministic edge tests above keep running regardless)."""

    @given(
        data=st.lists(
            st.floats(min_value=-1e6, max_value=1e6, width=32),
            min_size=1,
            max_size=3 * BLOCK,
        )
    )
    @settings(max_examples=60, deadline=None)
    def test_roundtrip_error_within_half_scale(self, data):
        flat = np.asarray(data, np.float32)
        codec = int8_codec()
        dec = np.asarray(codec.decode(codec.encode(jnp.asarray(flat))))
        assert dec.shape == flat.shape
        assert np.all(np.isfinite(dec))
        assert np.all(np.abs(dec - flat) <= 0.5 * _quant_bound(flat) + 1e-6)

    @given(
        data=st.lists(
            st.floats(min_value=-1e4, max_value=1e4, width=32),
            min_size=1,
            max_size=2 * BLOCK + 1,
        )
    )
    @settings(max_examples=40, deadline=None)
    def test_packed_agrees_with_codec(self, data):
        """The flat wire format (one int8 buffer: q payload ++ bitcast
        scales) must decode to EXACTLY what the dict codec decodes to —
        same quantizer, different framing."""
        flat = jnp.asarray(np.asarray(data, np.float32))
        codec = int8_codec()
        via_dict = np.asarray(codec.decode(codec.encode(flat)))
        via_packed = np.asarray(decode_packed(encode_packed(flat), flat.shape, flat.size))
        np.testing.assert_array_equal(via_packed, via_dict)


class TestWireBytes:
    def test_packed_nbytes_layout(self):
        # per block: BLOCK int8 lanes + one f32 scale bitcast to 4 int8
        assert packed_nbytes(BLOCK) == BLOCK + 4
        assert packed_nbytes(BLOCK + 1) == 2 * (BLOCK + 4)
        assert packed_nbytes(1) == BLOCK + 4

    def test_sync_wire_bytes_ratio(self):
        n = 40 * BLOCK  # block-aligned; padding only ever adds < 1 block
        raw = sync_wire_bytes(n, compressed=False)
        comp = sync_wire_bytes(n, compressed=True)
        assert raw == 4 * n
        assert comp == packed_nbytes(n)
        assert comp / raw < 0.26  # the ~4x wire saving the bench records
        assert sync_wire_bytes(n + 1, compressed=True) == comp + BLOCK + 4


class TestECSGHMCIntegration:
    def test_compressed_sync_stays_close(self):
        """One sync step with the codec wrapping the exchanged mean: the
        resulting center snapshot differs from the uncompressed run by at
        most the quantization bound, and the dynamics stay finite."""
        kw = dict(step_size=1e-2, alpha=1.0, sync_every=1, noise_convention="eq6")
        plain = core.ec_sghmc(**kw)
        comp = core.ec_sghmc(compression=int8_codec(), **kw)
        params = jax.random.normal(jax.random.PRNGKey(0), (4, 600))
        rng = jax.random.PRNGKey(1)

        def step(sampler, p):
            st = sampler.init(p)
            upd, st = sampler.update(0.1 * p, st, params=p, rng=rng)
            return core.apply_updates(p, upd), st

        p1, st1 = step(plain, params)
        p2, st2 = step(comp, params)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))  # params untouched by codec
        m1 = np.asarray(st1.mean_theta_stale)
        m2 = np.asarray(st2.mean_theta_stale)
        bound = np.abs(m1).max() / 127.0
        assert np.abs(m1 - m2).max() <= bound + 1e-7
        assert np.all(np.isfinite(m2))
