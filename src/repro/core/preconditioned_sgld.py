"""Preconditioned SGLD (Li et al., 2016 — pSGLD) with burn-in-frozen
diagonal RMSProp/Adam preconditioning:

    theta_{t+1} = theta_t − ε M⁻¹ ∇Ũ(theta_t) + N(0, 2 ε T M⁻¹)

The Γ(θ) = ∇·M⁻¹ curvature-drift term of the full pSGLD update is omitted:
while M⁻¹ adapts it is O((1−decay)) and standard practice drops it; once
adaptation FREEZES (step ≥ burnin, see ``repro.core.preconditioner``) it is
exactly zero, so the post-freeze chain targets exp(−U/T) with no bias beyond
the usual O(ε) discretization — certified exactly per dimension by
``repro.diagnostics.oracle.preconditioned_sgld_stationary`` (frozen pSGLD is
AR(1) with ρ_d = 1 − ε m_d λ_d on a Gaussian target).

With identity preconditioning (``decay=1.0, precond_eps=0.0`` → M⁻¹ ≡ 1.0)
the trajectory is bit-for-bit plain ``sgld``: same single-rng noise draw,
same term grouping (``tests/test_adaptive_equivalence.py``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .preconditioner import PrecondState, get_preconditioner
from .schedules import as_schedule
from .tree_util import tree_random_normal
from .types import Sampler


class PSGLDState(NamedTuple):
    precond: PrecondState


def preconditioned_sgld(
    step_size,
    temperature: float = 1.0,
    burnin: int = 1000,
    decay: float = 0.99,
    precond_eps: float = 1e-8,
    precond: str = "rmsprop",
) -> Sampler:
    """``precond``: "rmsprop" (pSGLD's choice) or "adam" (bias-corrected
    second moment; ``decay`` is β₂ there).  Both freeze at ``burnin``."""
    schedule = as_schedule(step_size)
    p_init, p_update = get_preconditioner(
        precond, burnin=burnin, decay=decay, eps=precond_eps
    )

    def init(params):
        return PSGLDState(precond=p_init(params))

    def update(grads, state, params=None, rng=None):
        del params
        eps = schedule(state.precond.step)
        minv, new_precond = p_update(state.precond, grads)
        noise = tree_random_normal(rng, grads, jnp.float32)
        # grouping mirrors sgld: (-eps · m) · g and sqrt((2 eps T) · m) · n so
        # that m ≡ 1.0 reproduces the plain-SGLD arithmetic bit-for-bit
        updates = jax.tree.map(
            lambda g, m, n: -eps * m * g.astype(jnp.float32)
            + jnp.sqrt(2.0 * eps * temperature * m) * n,
            grads,
            minv,
            noise,
        )
        return updates, PSGLDState(precond=new_precond)

    def stats(state, params):
        del params
        v_leaves = jax.tree.leaves(state.precond.v)
        v_mean = sum(jnp.mean(v) for v in v_leaves) / max(len(v_leaves), 1)
        return {"step": state.precond.step, "precond_v_mean": v_mean}

    return Sampler(init, update, stats=stats)
