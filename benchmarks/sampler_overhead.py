"""Sampler-update overhead (the paper-technique hot loop, model excluded):
wall time and modeled HBM traffic per parameter for SGHMC / EC-SGHMC /
fused-kernel EC-SGHMC, on a 1M-param state. Derived column = ns/param."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import core
from repro.kernels import fused_ec_update

from common import emit, time_fn

N = 1 << 20  # 1M params
K = 4


def run():
    key = jax.random.PRNGKey(0)
    g1 = jax.random.normal(key, (N,), jnp.float32)
    gK = jax.random.normal(key, (K, N), jnp.float32)

    # --- SGHMC (single chain) ---
    s = core.sghmc(step_size=1e-3)
    p1 = jnp.zeros((N,))
    st = s.init(p1)

    @jax.jit
    def sg_step(p, st, key):
        upd, st = s.update(g1, st, params=p, rng=key)
        return core.apply_updates(p, upd), st

    us = time_fn(lambda: sg_step(p1, st, key), iters=10)
    emit("overhead/sghmc_step", us, f"{1e3 * us / N:.3f}")

    # --- EC-SGHMC (K=4 chains, sync every step vs every 8) ---
    for sync in (1, 8):
        ec = core.ec_sghmc(step_size=1e-3, alpha=1.0, sync_every=sync)
        pK = jnp.zeros((K, N))
        stK = ec.init(pK)

        @jax.jit
        def ec_step(p, st, key):
            upd, st = ec.update(gK, st, params=p, rng=key)
            return core.apply_updates(p, upd), st

        us = time_fn(lambda: ec_step(pK, stK, key), iters=10)
        emit(f"overhead/ec_sghmc_s{sync}_step", us, f"{1e3 * us / (K * N):.3f}")

    # --- fused kernel (interpret mode on CPU: correctness path; the TPU
    # win is modeled HBM streams: 6.5 vs ~9 tensor rounds) ---
    theta = jnp.zeros((N,), jnp.float32)
    us = time_fn(
        lambda: fused_ec_update(
            theta, theta, g1, theta, key,
            eps=1e-3, friction=1.0, mass=1.0, alpha=1.0, sigma_p=1e-2,
            stochastic_round=False,
        ),
        iters=2, warmup=1,
    )
    emit("overhead/fused_kernel_interpret", us, f"{1e3 * us / N:.3f}")
    emit("overhead/fused_kernel_modeled_hbm_streams", 0, "6.5_vs_9_xla")


if __name__ == "__main__":
    run()
