"""End-to-end driver: posterior-sample the weights of an LM with EC-SGHMC,
with checkpointing + auto-resume (kill it mid-run and re-run: it resumes).

Uses the reduced qwen3 config so a few hundred steps run on CPU in minutes;
pass --arch/--no-smoke for the real configs on a TPU pod.

    PYTHONPATH=src python examples/train_lm.py            # ~200 steps
    PYTHONPATH=src python examples/train_lm.py --preempt  # simulate a kill,
                                                          # then resume
"""
import argparse
import sys

from repro.launch.train import main as train_main
from repro.train.loop import Preempted


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preempt", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = [
        "--arch", args.arch, "--smoke", "--steps", str(args.steps),
        "--chains", "4", "--sync-every", "4", "--batch", "2", "--seq", "64",
        "--step-size", "5e-5", "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25",
    ]
    if args.preempt:
        try:
            train_main(base + ["--preempt-at", str(args.steps // 2)])
        except Preempted as e:
            print(f"!! {e} — restarting, expecting auto-resume...")
        history = train_main(base)
    else:
        history = train_main(base)
    print(f"done: {len(history)} log points")


if __name__ == "__main__":
    main()
