"""Dry-run machinery smoke test: every arch's SMOKE config must lower +
compile for train/prefill/decode on a multi-device mini-mesh (8 host
devices via a subprocess env), and the collective parser must see the EC
sync.  This is the CI guard for the full 512-device dry-run."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro import configs

ROOT = Path(__file__).resolve().parent.parent

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys, json
import jax
from repro.launch.specs import build_cell
from repro.launch import mesh as mesh_lib
from repro.launch.dryrun import parse_collectives
import repro.configs as configs

configs.SHAPES["train_4k"] = configs.ShapeCell("train_4k", "train", 64, 8)
configs.SHAPES["prefill_32k"] = configs.ShapeCell("prefill_32k", "prefill", 64, 8)
configs.SHAPES["decode_32k"] = configs.ShapeCell("decode_32k", "decode", 64, 8)
configs.SHAPES["long_500k"] = configs.ShapeCell("long_500k", "decode", 256, 1)

arch = sys.argv[1]
out = {}
for shape in [c.name for c in configs.cells(arch)]:
    kind = configs.SHAPES[shape].kind
    mesh = (mesh_lib.make_train_mesh(2, size=4) if kind == "train"
            else mesh_lib.make_production_mesh(size=4))
    cell = build_cell(arch, shape, mesh, smoke=True,
                      num_chains=2 if kind == "train" else None)
    with mesh:
        j = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                    out_shardings=cell.out_shardings,
                    donate_argnums=cell.donate_argnums)
        compiled = j.lower(*cell.args).compile()
        coll = parse_collectives(compiled.as_text())
    out[shape] = {k: v["count"] for k, v in coll.items()}
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.parametrize("arch", list(configs.ARCH_IDS))
def test_smoke_dryrun_all_shapes(arch):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, arch],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, f"{arch}: {proc.stderr[-3000:]}"
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    expected = {c.name for c in configs.cells(arch)}
    assert set(out) == expected
    # the EC sync collective must exist in the train program
    assert any(k in out["train_4k"] for k in ("all-reduce", "reduce-scatter")), out["train_4k"]
