"""Device-resident run executor (scan-fused sampling drivers)."""
from .executor import (
    ChainExecutor,
    ChunkSnapshot,
    RunResult,
    ess_feedback_adapter,
    rollout,
)

__all__ = [
    "ChainExecutor",
    "ChunkSnapshot",
    "RunResult",
    "ess_feedback_adapter",
    "rollout",
]
