"""Shared benchmark utilities.

``emit`` keeps printing the historical ``name,us_per_call,derived`` CSV to
stdout AND records every row in-process; ``benchmarks/run.py`` dumps the
rows (plus whatever structured payloads benches ``record``) as
``BENCH_<name>.json`` so the perf trajectory is machine-readable across
PRs.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

QUICK = os.environ.get("REPRO_BENCH_QUICK", "1") == "1"

# rows/payloads accumulated since the last reset (one bench module's worth)
ROWS: list[dict] = []
EXTRAS: dict = {}


def reset_records() -> None:
    ROWS.clear()
    EXTRAS.clear()


def record(key: str, payload) -> None:
    """Attach a structured payload (steps/s, ESS/s, config, ...) to the
    current bench's JSON artifact."""
    EXTRAS[key] = payload


def time_fn(fn, *args, iters: int = 20, warmup: int = 3):
    """Median wall time per call in microseconds (blocking)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return 1e6 * float(np.median(ts))


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
    ROWS.append({"name": name, "us_per_call": round(float(us_per_call), 3), "derived": derived})


def manifest() -> dict:
    """The shared run manifest (git sha, jax/backend, device kind/count)
    stamped into every BENCH_*.json — so any artifact can be matched back to
    the exact code + backend state that produced it."""
    import sys as _sys
    from pathlib import Path as _Path

    _sys.path.insert(0, str(_Path(__file__).resolve().parent.parent / "src"))
    from repro.obs import run_manifest

    return run_manifest()
