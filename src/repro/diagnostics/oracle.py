"""Closed-form stationary moments of the coupled samplers on a Gaussian
target — exact ground truth for the discrete-time recursions, including
discretization bias and s-step staleness.

For an isotropic Gaussian target U(θ) = (λ/2)||θ − μ||², every update in
this repo is an *affine* recursion z' = A z + b + B w (w ~ N(0, I)): each
scalar parameter dimension evolves independently through the augmented
state

    z = (θ¹..θᴷ, p¹..pᴷ, c, r, c̃, m̃θ)  ∈ R^{2K+4}

built verbatim from Eq. 6 + the s-periodic stale exchange in
``repro.core.ec_sghmc`` (c̃/m̃θ refresh with the POST-update center/chain
mean on steps t with (t+1) % s == 0).  The chain is therefore
cyclostationary with period s; the moments a trajectory average converges
to are the PHASE-AVERAGED stationary moments, which we compute exactly:

  1. compose the period map  Φ = A_sync · A_base^{s-1}  and its
     accumulated process noise Q_Φ,
  2. solve the discrete Lyapunov equation  Σ₀ = Φ Σ₀ Φᵀ + Q_Φ  (phase-0 =
     just after a sync),
  3. roll Σ forward one step at a time through the period and average the
     θ/p/c marginals over phases.

No small-ε expansion anywhere: what the sampler iterates is what is
solved, so empirical moments must match to pure Monte-Carlo error.  This
is the acceptance gate ``tests/test_stationary.py`` checks every sampler
against.

The fixed point of the noise-free dynamics is θⁱ = c = c̃ = m̃θ = μ,
p = r = 0, so stationary means are exactly μ (θ, centers) and 0
(momenta); only covariances need the Lyapunov solve.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import numpy as np

from repro.core.sghmc import _noise_scale


class GaussianOracle(NamedTuple):
    """Stationary moments per scalar parameter dimension."""

    theta_mean: float  # == mu
    theta_var: float  # Var θⁱ_d, phase- and chain-averaged
    theta_cross_cov: float  # Cov(θⁱ_d, θʲ_d), i != j (0.0 when K == 1)
    center_var: float  # Var c_d (0.0 for uncentered samplers)
    momentum_var: float  # Var pⁱ_d
    spectral_radius: float  # of the period map; < 1 iff ergodic
    phase_theta_vars: np.ndarray  # (s,) chain-averaged Var θ at each phase


def noise_sigmas(
    eps: float,
    friction: float,
    center_friction: float,
    temperature: float,
    noise_convention: str,
    center_noise_in_p: bool,
) -> tuple[float, float]:
    """(σ_p, σ_r) exactly as ``repro.core.ec_sghmc`` computes them — single
    source of truth via the sampler's own ``_noise_scale``."""
    t = temperature**0.5
    sigma_p = t * float(
        _noise_scale(eps, friction, center_friction if center_noise_in_p else 0.0, noise_convention)
    )
    sigma_r = t * float(_noise_scale(eps, center_friction, 0.0, noise_convention))
    return sigma_p, sigma_r


def lyapunov_stationary(A: np.ndarray, Q: np.ndarray) -> np.ndarray:
    """Solve Σ = A Σ Aᵀ + Q by vectorization (exact for these tiny systems)."""
    n = A.shape[0]
    eye = np.eye(n * n)
    vec = np.linalg.solve(eye - np.kron(A, A), Q.reshape(-1))
    sigma = vec.reshape(n, n)
    return 0.5 * (sigma + sigma.T)  # symmetrize away roundoff


def ec_sghmc_stationary(
    *,
    step_size: float,
    alpha: float,
    num_chains: int,
    friction: float = 1.0,
    center_friction: float = 1.0,
    mass: float = 1.0,
    sync_every: int = 1,
    temperature: float = 1.0,
    noise_convention: str = "eq6",
    center_noise_in_p: bool = True,
    precision: float = 1.0,
    mu: float = 0.0,
) -> GaussianOracle:
    """Exact stationary moments of ``core.ec_sghmc`` on N(μ, λ⁻¹I) with
    exact gradients.  α = 0 decouples the chains and reproduces
    ``sghmc_stationary`` with the matching noise scale."""
    eps, lam, k, s = float(step_size), float(precision), int(num_chains), int(sync_every)
    a = eps / mass
    d_p = 1.0 - eps * friction / mass
    d_r = 1.0 - eps * center_friction / mass
    sigma_p, sigma_r = noise_sigmas(
        eps, friction, center_friction, temperature, noise_convention, center_noise_in_p
    )

    if alpha == 0.0:
        # Chains decouple from the center entirely; the center (c, r) becomes
        # an undamped random walk (no restoring force), so only the θ/p
        # marginal is stationary — exactly K independent SGHMC chains driven
        # with the EC noise scale σ_p.
        A2 = np.array([[1.0, a], [-eps * lam, d_p]])
        Q2 = np.diag([0.0, sigma_p**2])
        rad = float(np.max(np.abs(np.linalg.eigvals(A2))))
        if rad >= 1.0 - 1e-9:
            raise ValueError(f"chain recursion not contractive (spectral radius {rad:.6f})")
        sg = lyapunov_stationary(A2, Q2)
        return GaussianOracle(
            theta_mean=float(mu),
            theta_var=float(sg[0, 0]),
            theta_cross_cov=0.0,
            center_var=float("inf"),
            momentum_var=float(sg[1, 1]),
            spectral_radius=rad,
            phase_theta_vars=np.full(s, sg[0, 0]),
        )

    n = 2 * k + 4
    i_c, i_r, i_cs, i_mt = 2 * k, 2 * k + 1, 2 * k + 2, 2 * k + 3

    A = np.zeros((n, n))
    for i in range(k):
        A[i, i] = 1.0  # θⁱ' = θⁱ + a pⁱ
        A[i, k + i] = a
        A[k + i, i] = -eps * (lam + alpha)  # pⁱ' = d_p pⁱ - ελθⁱ - εα(θⁱ - c̃)
        A[k + i, k + i] = d_p
        A[k + i, i_cs] = eps * alpha
    A[i_c, i_c] = 1.0  # c' = c + a r
    A[i_c, i_r] = a
    A[i_r, i_c] = -eps * alpha  # r' = d_r r - εα(c - m̃θ)
    A[i_r, i_r] = d_r
    A[i_r, i_mt] = eps * alpha
    A_base = A.copy()
    A_base[i_cs, i_cs] = 1.0  # stale buffers held
    A_base[i_mt, i_mt] = 1.0

    A_sync = A.copy()
    A_sync[i_cs, i_c] = 1.0  # c̃' = c' (post-update center)
    A_sync[i_cs, i_r] = a
    for i in range(k):  # m̃θ' = mean_i θⁱ' (post-update chains)
        A_sync[i_mt, i] = 1.0 / k
        A_sync[i_mt, k + i] = a / k

    Q = np.zeros((n, n))
    for i in range(k):
        Q[k + i, k + i] = sigma_p**2
    Q[i_r, i_r] = sigma_r**2

    # period map and its accumulated noise (steps 1..s; step s syncs)
    steps = [A_base] * (s - 1) + [A_sync]
    M = np.eye(n)
    Q_phi = np.zeros((n, n))
    for A_j in reversed(steps):
        Q_phi += M @ Q @ M.T
        M = M @ A_j
    phi = M

    rad = float(np.max(np.abs(np.linalg.eigvals(phi))))
    if rad >= 1.0 - 1e-9:
        raise ValueError(
            f"period map not contractive (spectral radius {rad:.6f}) — "
            "no stationary distribution for these hyperparameters"
        )

    sigma0 = lyapunov_stationary(phi, Q_phi)
    phase_sigmas = [sigma0]
    for A_j in steps[:-1]:
        prev = phase_sigmas[-1]
        phase_sigmas.append(A_j @ prev @ A_j.T + Q)

    th = slice(0, k)
    pp = slice(k, 2 * k)
    phase_theta_vars = np.array([np.mean(np.diag(sg[th, th])) for sg in phase_sigmas])
    theta_var = float(phase_theta_vars.mean())
    if k > 1:
        off = [
            (np.sum(sg[th, th]) - np.trace(sg[th, th])) / (k * (k - 1)) for sg in phase_sigmas
        ]
        theta_cross_cov = float(np.mean(off))
    else:
        theta_cross_cov = 0.0
    center_var = float(np.mean([sg[i_c, i_c] for sg in phase_sigmas]))
    momentum_var = float(np.mean([np.mean(np.diag(sg[pp, pp])) for sg in phase_sigmas]))
    return GaussianOracle(
        theta_mean=float(mu),
        theta_var=theta_var,
        theta_cross_cov=theta_cross_cov,
        center_var=center_var,
        momentum_var=momentum_var,
        spectral_radius=rad,
        phase_theta_vars=phase_theta_vars,
    )


def sghmc_stationary(
    *,
    step_size: float,
    friction: float = 1.0,
    mass: float = 1.0,
    temperature: float = 1.0,
    noise_convention: str = "eq4",
    grad_noise_estimate: float = 0.0,
    precision: float = 1.0,
    mu: float = 0.0,
) -> GaussianOracle:
    """Exact stationary moments of ``core.sghmc`` (Eq. 4 discretized) on
    N(μ, λ⁻¹I).  As ε → 0 with eq4 noise, θ-variance → 1/λ; the exact
    discrete value (what a test must compare against) differs at O(ε)."""
    eps, lam = float(step_size), float(precision)
    a = eps / mass
    d_p = 1.0 - eps * friction / mass
    sigma = temperature**0.5 * float(
        _noise_scale(eps, friction - grad_noise_estimate, 0.0, noise_convention)
    )
    A = np.array([[1.0, a], [-eps * lam, d_p]])
    Q = np.diag([0.0, sigma**2])
    rad = float(np.max(np.abs(np.linalg.eigvals(A))))
    if rad >= 1.0 - 1e-9:
        raise ValueError(f"SGHMC recursion not contractive (spectral radius {rad:.6f})")
    sg = lyapunov_stationary(A, Q)
    return GaussianOracle(
        theta_mean=float(mu),
        theta_var=float(sg[0, 0]),
        theta_cross_cov=0.0,
        center_var=0.0,
        momentum_var=float(sg[1, 1]),
        spectral_radius=rad,
        phase_theta_vars=np.array([sg[0, 0]]),
    )


def async_sghmc_stationary(
    *,
    step_size: float,
    friction: float = 1.0,
    mass: float = 1.0,
    sync_every: int = 1,
    temperature: float = 1.0,
    noise_convention: str = "eq4",
    precision: float = 1.0,
    mu: float = 0.0,
) -> GaussianOracle:
    """Exact stationary moments of ``core.async_sghmc`` — the paper's naive
    approach-I baseline — on N(μ, λ⁻¹I) with exact gradients.

    The server advances Eq. 4 with gradients evaluated at the round-robin
    workers' stale snapshots.  A worker arriving at step t pulled its
    snapshot at its previous arrival t−s, where it received the POST-update
    server params θ_{t−s+1}; with exact gradients every worker arriving at
    the same step holds the same snapshot, so

        ĝ_t = λ (θ_{t−s+1} − μ)            (a pure s−1 step delay)

    and the recursion is linear with delay — exact via the companion-form
    augmentation z = (θ_t, θ_{t−1}, …, θ_{t−s+1}, p_t) and a Lyapunov
    solve.  s = 1 is synchronous-parallel SGHMC and reproduces
    ``sghmc_stationary`` identically; s > 1 inflates θ-variance (the stale
    gradient acts as a destabilizing feedback lag), which is exactly the
    degradation Fig. 2 shows and EC-SGHMC avoids.  Assumes every phase is
    covered (num_workers ≥ sync_every): no idle-server identity steps."""
    eps, lam, s = float(step_size), float(precision), int(sync_every)
    a = eps / mass
    d_p = 1.0 - eps * friction / mass
    sigma = temperature**0.5 * float(_noise_scale(eps, friction, 0.0, noise_convention))

    n = s + 1
    A = np.zeros((n, n))
    A[0, 0] = 1.0  # θ' = θ + a p
    A[0, s] = a
    for i in range(1, s):  # delay line θ_{t−i}
        A[i, i - 1] = 1.0
    A[s, s - 1] = -eps * lam  # p' = d_p p − ελ θ_{t−s+1}
    A[s, s] = d_p
    Q = np.zeros((n, n))
    Q[s, s] = sigma**2

    rad = float(np.max(np.abs(np.linalg.eigvals(A))))
    if rad >= 1.0 - 1e-9:
        raise ValueError(
            f"async-SGHMC delay recursion not contractive (spectral radius {rad:.6f}) — "
            "staleness too large for this step size"
        )
    sg = lyapunov_stationary(A, Q)
    return GaussianOracle(
        theta_mean=float(mu),
        theta_var=float(sg[0, 0]),
        theta_cross_cov=0.0,
        center_var=0.0,
        momentum_var=float(sg[s, s]),
        spectral_radius=rad,
        phase_theta_vars=np.array([sg[0, 0]]),
    )


def sgld_stationary(
    *,
    step_size: float,
    temperature: float = 1.0,
    precision: float = 1.0,
    mu: float = 0.0,
) -> GaussianOracle:
    """Exact stationary variance of the SGLD recursion θ' = (1-ελ)θ + ελμ
    + N(0, 2εT): an AR(1) with Var = 2εT / (1 - (1-ελ)²) = T/λ · 1/(1-ελ/2)."""
    eps, lam = float(step_size), float(precision)
    rho = 1.0 - eps * lam
    if abs(rho) >= 1.0:
        raise ValueError(f"SGLD recursion not contractive (|1-ελ| = {abs(rho):.6f})")
    var = 2.0 * eps * temperature / (1.0 - rho * rho)
    return GaussianOracle(
        theta_mean=float(mu),
        theta_var=float(var),
        theta_cross_cov=0.0,
        center_var=0.0,
        momentum_var=0.0,
        spectral_radius=abs(rho),
        phase_theta_vars=np.array([var]),
    )


# --- frozen-preconditioner regime -------------------------------------------
#
# Once a diagonal preconditioner freezes (step ≥ burnin — the contract of
# ``repro.core.preconditioner``), the adaptive samplers iterate LINEAR
# recursions again: per scalar dimension d the frozen M⁻¹ entry m_d is just
# a constant mass 1/m_d, so the same period-map/Lyapunov machinery certifies
# the preconditioned update rules exactly.  Assumptions (DESIGN.md §6,
# ROADMAP Testing & diagnostics):
#   * M⁻¹ is bit-frozen for every post-burn-in step (no residual adaptation),
#   * injected noise is MASS-INDEPENDENT (the fluctuation–dissipation pairing
#     of ``scale_adapted_*``; σ_p/σ_r identical to the unpreconditioned
#     samplers), and
#   * the coupling force −εα(θ − c̃) is NOT M-scaled (potential-gradient
#     placement), matching ``scale_adapted_ec_sghmc``.
# Under these, `preconditioned_*_stationary(mass_inv=1)` must reproduce the
# corresponding scalar oracle exactly — asserted by the battery.


class DiagGaussianOracle(NamedTuple):
    """Per-dimension stationary moments under a frozen diagonal M⁻¹ on a
    diagonal Gaussian target N(μ, diag(λ)⁻¹).  Arrays are (D,)."""

    theta_mean: np.ndarray
    theta_var: np.ndarray  # chain-averaged Var θ_d
    theta_cross_cov: np.ndarray  # Cov(θⁱ_d, θʲ_d), i ≠ j
    center_var: np.ndarray
    momentum_var: np.ndarray
    spectral_radius: float  # max over dimensions
    phase_theta_vars: np.ndarray  # (s, D)


def _as_1d(x, d: int, name: str) -> np.ndarray:
    out = np.broadcast_to(np.asarray(x, np.float64), (d,)).copy()
    if not np.all(np.isfinite(out)):
        raise ValueError(f"{name} must be finite, got {out}")
    return out


def preconditioned_sghmc_stationary(
    *,
    step_size: float,
    mass_inv,
    friction: float = 1.0,
    temperature: float = 1.0,
    noise_convention: str = "eq4",
    precision=1.0,
    mu=0.0,
) -> DiagGaussianOracle:
    """Exact stationary moments of ``core.scale_adapted_sghmc`` AFTER the
    burn-in freeze, on N(μ, diag(λ)⁻¹): per dimension the frozen m_d is a
    constant mass 1/m_d and the noise is mass-independent, so each dim is
    exactly ``sghmc_stationary(mass=1/m_d, precision=λ_d)``."""
    minv = np.atleast_1d(np.asarray(mass_inv, np.float64)).reshape(-1)
    d = minv.size
    lam = _as_1d(precision, d, "precision")
    mus = _as_1d(mu, d, "mu")
    if np.any(minv <= 0.0):
        raise ValueError(f"mass_inv must be > 0, got {minv}")
    per = [
        sghmc_stationary(
            step_size=step_size, friction=friction, mass=1.0 / m,
            temperature=temperature, noise_convention=noise_convention,
            precision=l, mu=u,
        )
        for m, l, u in zip(minv, lam, mus)
    ]
    return DiagGaussianOracle(
        theta_mean=mus,
        theta_var=np.array([o.theta_var for o in per]),
        theta_cross_cov=np.zeros(d),
        center_var=np.zeros(d),
        momentum_var=np.array([o.momentum_var for o in per]),
        spectral_radius=max(o.spectral_radius for o in per),
        phase_theta_vars=np.array([[o.theta_var for o in per]]),
    )


def preconditioned_sgld_stationary(
    *,
    step_size: float,
    mass_inv,
    temperature: float = 1.0,
    precision=1.0,
    mu=0.0,
) -> DiagGaussianOracle:
    """Exact stationary variance of frozen ``core.preconditioned_sgld``:
    per dimension θ' = (1 − ε m_d λ_d) θ + ε m_d λ_d μ + N(0, 2 ε T m_d) —
    an AR(1) identical to ``sgld_stationary(step_size=ε·m_d)``."""
    minv = np.atleast_1d(np.asarray(mass_inv, np.float64)).reshape(-1)
    d = minv.size
    lam = _as_1d(precision, d, "precision")
    mus = _as_1d(mu, d, "mu")
    if np.any(minv <= 0.0):
        raise ValueError(f"mass_inv must be > 0, got {minv}")
    per = [
        sgld_stationary(step_size=float(step_size) * m, temperature=temperature,
                        precision=l, mu=u)
        for m, l, u in zip(minv, lam, mus)
    ]
    return DiagGaussianOracle(
        theta_mean=mus,
        theta_var=np.array([o.theta_var for o in per]),
        theta_cross_cov=np.zeros(d),
        center_var=np.zeros(d),
        momentum_var=np.zeros(d),
        spectral_radius=max(o.spectral_radius for o in per),
        phase_theta_vars=np.array([[o.theta_var for o in per]]),
    )


def preconditioned_ec_sghmc_stationary(
    *,
    step_size: float,
    alpha: float,
    num_chains: int,
    mass_inv,
    center_mass_inv=None,
    friction: float = 1.0,
    center_friction: float = 1.0,
    sync_every: int = 1,
    temperature: float = 1.0,
    noise_convention: str = "eq6",
    center_noise_in_p: bool = True,
    precision=1.0,
    mu=0.0,
) -> DiagGaussianOracle:
    """Exact stationary moments of ``core.scale_adapted_ec_sghmc`` after the
    freeze, on N(μ, diag(λ)⁻¹) with exact gradients.

    ``mass_inv``: frozen per-chain diagonal M⁻¹ — shape (K,), (D,), or
    (K, D).  ``center_mass_inv``: M_c⁻¹, default the chain mean (what the
    sampler computes).  Per dimension the augmented recursion is the
    2K+4 system of ``ec_sghmc_stationary`` with per-chain masses:

        θⁱ' = θⁱ + ε mᵢ pⁱ                        c' = c + ε m_c r
        pⁱ' = (1 − εVmᵢ) pⁱ − ε(λ+α)θⁱ + εα c̃ + σ_p w
        r'  = (1 − εCm_c) r − εα c + εα m̃θ + σ_r w

    with the identical s-periodic stale exchange and MASS-INDEPENDENT noise
    scales — the coupling force is not M-scaled (see module comment)."""
    eps, s, k = float(step_size), int(sync_every), int(num_chains)
    minv = np.asarray(mass_inv, np.float64)
    if minv.ndim == 0:
        minv = np.full((k, 1), float(minv))
    elif minv.ndim == 1:
        # (K,) = per-chain scalar masses; a 1-D per-dim array of length != K
        # is ambiguous — pass (1, D) explicitly for chain-shared dims.
        if minv.size != k:
            raise ValueError(
                f"1-D mass_inv must have length num_chains={k}; "
                f"got {minv.size} (pass shape (1, D) for chain-shared values)"
            )
        minv = minv.reshape(k, 1)
    minv = np.broadcast_to(minv, (k, minv.shape[1]))
    d = minv.shape[1]
    lam = _as_1d(precision, d, "precision")
    mus = _as_1d(mu, d, "mu")
    if np.any(minv <= 0.0):
        raise ValueError("mass_inv must be > 0")
    if center_mass_inv is None:
        mc = minv.mean(axis=0)
    else:
        mc = _as_1d(center_mass_inv, d, "center_mass_inv")
    sigma_p, sigma_r = noise_sigmas(
        eps, friction, center_friction, temperature, noise_convention, center_noise_in_p
    )

    if alpha == 0.0:
        # decoupled: chain i of dim d is SGHMC with mass 1/m_{i,d} driven at
        # the EC noise scale σ_p; report the chain average (what a pooled
        # empirical variance estimates, since all chain means equal μ)
        tv = np.zeros(d)
        mv = np.zeros(d)
        rad = 0.0
        for j in range(d):
            for i in range(k):
                a = eps * minv[i, j]
                A2 = np.array([[1.0, a], [-eps * lam[j], 1.0 - eps * friction * minv[i, j]]])
                r2 = float(np.max(np.abs(np.linalg.eigvals(A2))))
                if r2 >= 1.0 - 1e-9:
                    raise ValueError(
                        f"chain {i} dim {j} not contractive (spectral radius {r2:.6f})"
                    )
                sg = lyapunov_stationary(A2, np.diag([0.0, sigma_p**2]))
                tv[j] += sg[0, 0] / k
                mv[j] += sg[1, 1] / k
                rad = max(rad, r2)
        return DiagGaussianOracle(
            theta_mean=mus,
            theta_var=tv,
            theta_cross_cov=np.zeros(d),
            center_var=np.full(d, float("inf")),
            momentum_var=mv,
            spectral_radius=rad,
            phase_theta_vars=np.broadcast_to(tv, (s, d)).copy(),
        )

    n = 2 * k + 4
    i_c, i_r, i_cs, i_mt = 2 * k, 2 * k + 1, 2 * k + 2, 2 * k + 3
    th = slice(0, k)
    pp = slice(k, 2 * k)

    tv = np.zeros(d)
    xc = np.zeros(d)
    cv = np.zeros(d)
    mv = np.zeros(d)
    ptv = np.zeros((s, d))
    rad = 0.0
    for j in range(d):
        A = np.zeros((n, n))
        for i in range(k):
            a_i = eps * minv[i, j]
            A[i, i] = 1.0
            A[i, k + i] = a_i
            A[k + i, i] = -eps * (lam[j] + alpha)
            A[k + i, k + i] = 1.0 - eps * friction * minv[i, j]
            A[k + i, i_cs] = eps * alpha
        a_c = eps * mc[j]
        A[i_c, i_c] = 1.0
        A[i_c, i_r] = a_c
        A[i_r, i_c] = -eps * alpha
        A[i_r, i_r] = 1.0 - eps * center_friction * mc[j]
        A[i_r, i_mt] = eps * alpha
        A_base = A.copy()
        A_base[i_cs, i_cs] = 1.0
        A_base[i_mt, i_mt] = 1.0
        A_sync = A.copy()
        A_sync[i_cs, i_c] = 1.0
        A_sync[i_cs, i_r] = a_c
        for i in range(k):
            A_sync[i_mt, i] = 1.0 / k
            A_sync[i_mt, k + i] = eps * minv[i, j] / k

        Q = np.zeros((n, n))
        for i in range(k):
            Q[k + i, k + i] = sigma_p**2
        Q[i_r, i_r] = sigma_r**2

        steps = [A_base] * (s - 1) + [A_sync]
        M = np.eye(n)
        Q_phi = np.zeros((n, n))
        for A_j in reversed(steps):
            Q_phi += M @ Q @ M.T
            M = M @ A_j
        r_j = float(np.max(np.abs(np.linalg.eigvals(M))))
        if r_j >= 1.0 - 1e-9:
            raise ValueError(
                f"dim {j}: period map not contractive (spectral radius {r_j:.6f})"
            )
        rad = max(rad, r_j)
        sigma0 = lyapunov_stationary(M, Q_phi)
        phase_sigmas = [sigma0]
        for A_j in steps[:-1]:
            phase_sigmas.append(A_j @ phase_sigmas[-1] @ A_j.T + Q)

        ptv[:, j] = [np.mean(np.diag(sg[th, th])) for sg in phase_sigmas]
        tv[j] = ptv[:, j].mean()
        if k > 1:
            xc[j] = np.mean(
                [(np.sum(sg[th, th]) - np.trace(sg[th, th])) / (k * (k - 1))
                 for sg in phase_sigmas]
            )
        cv[j] = np.mean([sg[i_c, i_c] for sg in phase_sigmas])
        mv[j] = np.mean([np.mean(np.diag(sg[pp, pp])) for sg in phase_sigmas])

    return DiagGaussianOracle(
        theta_mean=mus,
        theta_var=tv,
        theta_cross_cov=xc,
        center_var=cv,
        momentum_var=mv,
        spectral_radius=rad,
        phase_theta_vars=ptv,
    )


def monte_carlo_tolerance(var: float, ess: float, nsigma: float = 3.0) -> float:
    """Half-width of an nσ acceptance band for an empirical variance with
    ``ess`` effectively-independent Gaussian samples: SD(s²) ≈ var·√(2/ess).
    Shared by the stationary battery so every test states its tolerance the
    same way."""
    ess = max(float(ess), 4.0)
    return nsigma * var * math.sqrt(2.0 / ess)
