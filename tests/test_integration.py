"""End-to-end integration tests through the public launchers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, core
from repro.launch.serve import ensemble_decode
from repro.launch.train import build_batch_fn
from repro.models import get_model, init_params
from repro.serve.loop import generate
from repro.train.loop import LoopConfig, run
from repro.train.step import make_train_step


class TestTrainIntegration:
    @pytest.mark.parametrize("arch", ["qwen3-0.6b", "recurrentgemma-2b"])
    def test_ec_training_reduces_nll(self, arch, tmp_path):
        cfg = configs.get_config(arch, smoke=True)
        model = get_model(cfg)
        K = 2
        sampler = core.ec_sghmc(step_size=5e-5, alpha=1.0, sync_every=4)
        # n_data sets the N/|B| potential scale; keep it commensurate with
        # the tiny smoke batches or gradients explode (batch 2x2x32 tokens)
        step = make_train_step(cfg, model, sampler, n_data=10_000)
        params = core.tree_broadcast_axis0(
            init_params(model.param_specs(cfg), jax.random.PRNGKey(0)), K
        )
        state = sampler.init(params)
        batch_fn = build_batch_fn(cfg, K, per_chain=2, seq_len=32)
        cfg_loop = LoopConfig(num_steps=30, ckpt_dir=str(tmp_path), ckpt_every=10, log_every=5)
        params, state, history = run(step, params, state, batch_fn, cfg_loop, num_chains=K)
        assert len(history) >= 2
        # sampling at tiny step size should not diverge and should descend
        first, last = history[0]["nll_per_token"], history[-1]["nll_per_token"]
        assert np.isfinite(last)
        assert last < first * 1.05
        assert (tmp_path / "step_00000030").exists()

    def test_vlm_batch_fn_shapes(self):
        from repro.launch.specs import vlm_patches

        cfg = configs.get_config("qwen2-vl-7b", smoke=True)
        fn = build_batch_fn(cfg, num_chains=2, per_chain=2, seq_len=96)
        b = fn(0)
        n_patch = vlm_patches(96)
        assert b["patch_embeds"].shape == (2, 2, n_patch, cfg.d_model)
        assert b["tokens"].shape == (2, 2, 96 - n_patch)
        # full-size shapes keep the standard 64-patch prefix
        assert vlm_patches(4096) == 64


class TestServeIntegration:
    def test_generate_roundtrip(self):
        cfg = configs.get_config("h2o-danube-1.8b", smoke=True)
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)}
        toks = generate(cfg, model, params, batch, max_seq=24, num_tokens=6)
        assert toks.shape == (2, 6)
        assert int(toks.min()) >= 0 and int(toks.max()) < cfg.vocab_size

    def test_ensemble_decode_matches_single_when_k1(self):
        cfg = configs.get_config("qwen3-0.6b", smoke=True)
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)}
        single = generate(cfg, model, params, batch, max_seq=20, num_tokens=4)
        stacked = jax.tree.map(lambda x: x[None], params)
        ens = ensemble_decode(cfg, model, stacked, batch, max_seq=20, num_tokens=4)
        np.testing.assert_array_equal(np.asarray(single), np.asarray(ens))

    def test_ensemble_averages_distinct_models(self):
        cfg = configs.get_config("qwen3-0.6b", smoke=True)
        model = get_model(cfg)
        keys = jax.random.split(jax.random.PRNGKey(3), 3)
        params = jax.vmap(lambda k: init_params(model.param_specs(cfg), k))(keys)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab_size)}
        toks = ensemble_decode(cfg, model, params, batch, max_seq=20, num_tokens=4)
        assert toks.shape == (2, 4)
