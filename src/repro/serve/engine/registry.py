"""Snapshot registry: the serving engine's source of ensemble members,
refreshed live from a background coupled-sampler run.

The paper's robustness argument is exactly what makes this sound: EC-SGHMC
is *designed* to tolerate a noisy/stale center (the staleness and
quantization perturbations are absorbed into the center-noise covariance C
of Eq. 6), so serving from members that lag the live chains by up to one
executor chunk — and swapping them mid-flight — is a controlled
perturbation of the same kind, unlike naive async whose stale gradients
enter the dynamics directly (Chen et al., stale-gradient SG-MCMC).

Promotion is GATED: a candidate stack must pass the ensemble-spread check
(``repro.diagnostics.ensemble_spread_device``) — a collapsed ensemble
(spread below ``min_rel_spread``) silently degrades Bayesian model
averaging to one model's predictions, and the registry is where that must
be caught, before the stack ever serves.  Stale members keep serving until
a candidate passes.  The gate has two surfaces:

* ``propose(candidate)`` — synchronous: runs the jitted spread reduction
  and fetches the verdict immediately (one host round-trip);
* ``stage(candidate)`` + ``flip_staged()`` — the OVERLAPPED path
  (DESIGN.md §9): ``stage`` only *dispatches* the jitted reduction and
  parks the candidate in the second member buffer; the scalar verdict is
  fetched lazily at flip time (``staged_ready`` says whether that fetch
  would block).  ``members`` never changes until a flip, and a flip is a
  pointer swap — same pytree structure, same avals, so the engine's one
  compiled decode program is untouched.

``ChainRefresher`` drives the background run cooperatively through
``ChainExecutor.stream`` (the chunk-boundary snapshot hook): each
``refresh()`` advances the sampler one chunk and proposes the live chain
stack.  Bound to an engine (``bind``), it instead amortizes that chunk over
``pump(step)`` calls — one micro-chunk at a time — so no single decode tick
(and hence no single request) eats a whole chunk's cost; the cadence of
full chunk+proposal cycles still matches the engine's ``refresh_every``.
Cooperative (caller-paced) rather than threaded keeps the whole engine
deterministic — the serving loop decides how often it pays the refresh
cost, and a given (trace, seed, cadence) always reproduces.  The fully
overlapped variant (async dispatch, lazy gate, pre-staged flips, spare-
device placement) is ``repro.serve.engine.refresh.RefreshScheduler``.
"""
from __future__ import annotations

import time
from typing import Any

import jax

from repro.diagnostics import ensemble_spread_device
from repro.obs import trace as obs_trace
from repro.run import ChainExecutor

_health_jit = jax.jit(ensemble_spread_device)


def _micro_split(chunk_steps: int, refresh_every: int) -> int:
    """Largest divisor of ``chunk_steps`` not exceeding
    ``ceil(chunk_steps / refresh_every)`` — the micro-chunk size that spreads
    one chunk over a ``refresh_every``-tick cadence window while keeping
    chunk boundaries (and hence proposal steps) exactly where they were."""
    micro = max(1, -(-chunk_steps // max(refresh_every, 1)))
    while chunk_steps % micro:
        micro -= 1
    return micro


class SnapshotRegistry:
    """Holds the currently-serving (K, ...)-stacked ensemble; ``propose``
    swaps it atomically iff the candidate passes the spread gate, and the
    ``stage``/``flip_staged`` pair does the same with the gate's host
    round-trip deferred off the decode critical path."""

    def __init__(self, members, *, min_rel_spread: float = 1e-6, validate: bool = False):
        self.min_rel_spread = float(min_rel_spread)
        self.members = members
        self.num_members = int(jax.tree.leaves(members)[0].shape[0])
        self.version = 0
        self.promoted = 0
        self.rejected = 0
        self.staged_total = 0
        self.last_health: dict | None = None
        self._staged: tuple[Any, dict] | None = None
        if validate:
            health = self._fetch_health(_health_jit(members))
            self.last_health = health
            if health["collapsed"]:
                raise ValueError(
                    f"initial ensemble is collapsed (rel_spread={health['rel_spread']:.3e})"
                )

    # -- gate ---------------------------------------------------------------

    def health_device(self, candidate) -> dict:
        """Dispatch the jitted spread reduction on ``candidate``; returns a
        dict of scalar DEVICE arrays (no host sync)."""
        return _health_jit(candidate)

    def _fetch_health(self, health_dev: dict) -> dict:
        health = {k: float(v) for k, v in health_dev.items()}
        health["num_chains"] = self.num_members
        health["collapsed"] = bool(health["rel_spread"] < self.min_rel_spread)
        return health

    def _check_k(self, candidate) -> None:
        k = int(jax.tree.leaves(candidate)[0].shape[0])
        if k != self.num_members:
            raise ValueError(f"candidate has K={k}, registry serves K={self.num_members}")

    # -- synchronous promotion ----------------------------------------------

    def propose(self, candidate) -> bool:
        """Gate + swap.  Returns True iff ``candidate`` was promoted; on
        rejection the previous members keep serving unchanged."""
        self.stage(candidate)
        # the overlapped scheduler traces its own flip (with defer context);
        # this span covers the synchronous gate-and-fetch path
        with obs_trace.get().span("refresh.flip", cat="refresh", sync=True):
            return self.flip_staged()

    # -- overlapped promotion (stage now, flip later) ------------------------

    @property
    def staged(self):
        """The parked (candidate, device-health) pair, or None."""
        return self._staged

    def stage(self, candidate, health=None) -> None:
        """Park ``candidate`` in the second member buffer and dispatch its
        spread verdict; replaces any previously staged candidate.  Nothing
        here blocks: ``health`` (optional, from :meth:`health_device`) and
        the candidate stay device-side until :meth:`flip_staged`."""
        self._check_k(candidate)
        if health is None:
            health = self.health_device(candidate)
        self._staged = (candidate, health)
        self.staged_total += 1
        obs_trace.get().instant("refresh.stage", cat="refresh", staged=self.staged_total)

    def staged_ready(self) -> bool:
        """True iff the staged verdict has been computed — i.e. a flip would
        not block the host on the device stream."""
        if self._staged is None:
            return False
        return all(
            getattr(v, "is_ready", lambda: True)() for v in self._staged[1].values()
        )

    def flip_staged(self, place=None) -> bool:
        """Fetch the staged verdict (tiny scalar transfer; already computed
        when ``staged_ready``) and promote or reject.  Promotion rebinds
        ``members`` — same pytree structure, same avals, no shape change.
        ``place`` (optional) maps the candidate into its serving placement
        at promotion time; since the verdict being ready implies the
        candidate's buffers are ready (the reduction consumed them), that
        is a bounded device-to-device copy, never a wait on sampler
        compute."""
        if self._staged is None:
            return False
        candidate, health_dev = self._staged
        self._staged = None
        health = self._fetch_health(health_dev)
        self.last_health = health
        if health["collapsed"]:
            self.rejected += 1
            return False
        self.members = candidate if place is None else place(candidate)
        self.version += 1
        self.promoted += 1
        return True

    def stats(self) -> dict:
        return {
            "version": self.version,
            "promoted": self.promoted,
            "rejected": self.rejected,
            "staged_total": self.staged_total,
            "staged_pending": self._staged is not None,
            "num_members": self.num_members,
            "last_health": self.last_health,
        }


class ChainRefresher:
    """Cooperative background sampler feeding a :class:`SnapshotRegistry`.

    ``params`` must be the (K, ...)-stacked chain state of a chain-parallel
    sampler (EC-SGLD / EC-SGHMC / chainwise SGLD) whose live stack IS the
    candidate ensemble.  Each ``refresh()`` advances exactly one executor
    chunk (``chunk_steps`` sampler steps) and proposes the resulting stack;
    after ``total_steps`` the run is exhausted and ``refresh()`` returns
    False forever.  ``members_of`` maps the raw chain stack to the served
    parameter stack (default: identity).

    Bound to a :class:`ServeEngine` (``bind``; the engine does this at
    construction), the engine pumps it EVERY decode tick and the chunk is
    advanced in micro-chunks of ``chunk_steps / refresh_every`` sampler
    steps — bit-identical dynamics (DESIGN.md §3: chunking is invisible),
    same proposal cadence, but the cost is spread evenly across ticks
    instead of being charged to whichever request triggers the cadence."""

    def __init__(
        self,
        registry: SnapshotRegistry,
        sampler,
        grad_fn,
        params,
        *,
        key,
        state=None,
        chunk_steps: int = 64,
        total_steps: int = 1 << 30,
        members_of=None,
    ):
        self.registry = registry
        self.members_of = members_of or (lambda p: p)
        self._sampler = sampler
        self._grad_fn = grad_fn
        self._params = params
        self._state = sampler.init(params) if state is None else state
        self._key = key
        self._total_steps = int(total_steps)
        self._stream = None
        self.chunk_steps = int(chunk_steps)
        self.micro_steps = int(chunk_steps)  # bind() shrinks this
        self._credit = 0.0
        self._rate = 1.0  # micro-chunks accrued per pump; bind() sets
        self.steps_done = 0
        self.refreshes = 0
        self.micro_chunks = 0
        self.refresh_wall_s = 0.0
        self.exhausted = False

    # -- engine binding ------------------------------------------------------

    def bind(self, engine) -> None:
        """Called by ``ServeEngine.__init__``: amortize each chunk over the
        engine's ``refresh_every``-tick cadence window."""
        cadence = max(int(getattr(engine, "refresh_every", 0)), 1)
        if self._stream is None:  # already-started streams keep their chunking
            self.micro_steps = _micro_split(self.chunk_steps, cadence)
        self._rate = (self.chunk_steps // self.micro_steps) / cadence

    def _ensure_stream(self):
        if self._stream is None:
            ex = ChainExecutor(
                sampler=self._sampler,
                grad_fn=lambda targets, _batch: self._grad_fn(targets),
                chunk_steps=self.micro_steps,
                key_mode="fold",
            )
            self._stream = ex.stream(
                self._params,
                self._state,
                num_steps=self._total_steps,
                key=self._key,
                snapshot_every=self.chunk_steps // self.micro_steps,
            )
            self._params = self._state = None  # donated into the stream
        return self._stream

    # -- advancement ---------------------------------------------------------

    def _advance_micro(self) -> tuple[bool, bool]:
        """Advance one micro-chunk; returns (hit a proposal boundary,
        promoted)."""
        t0 = time.perf_counter()
        with obs_trace.get().span("refresh.micro_chunk", cat="refresh",
                                  from_step=self.steps_done, sync=True):
            try:
                snap = next(self._ensure_stream())
            except StopIteration:
                self.exhausted = True
                return False, False
        self.micro_chunks += 1
        self.steps_done = snap.step
        promoted = False
        boundary = snap.params is not None
        if boundary:
            self.refreshes += 1
            promoted = self.registry.propose(self.members_of(snap.params))
        self.refresh_wall_s += time.perf_counter() - t0
        return boundary, promoted

    def refresh(self) -> bool:
        """Advance one full chunk, propose the live stack.  Returns True iff
        a new snapshot was promoted."""
        while not self.exhausted:
            boundary, promoted = self._advance_micro()
            if boundary:
                return promoted
        return False

    def pump(self, step: int) -> bool:
        """Amortized advancement: accrue ``rate`` micro-chunks of credit and
        run whole ones; proposals still land exactly at chunk boundaries.
        Returns True iff a promotion happened this call."""
        del step  # pacing is credit-based, robust to per-run step resets
        if self.exhausted:
            return False
        self._credit += self._rate
        promoted = False
        while self._credit >= 1.0 and not self.exhausted:
            self._credit -= 1.0
            _, p = self._advance_micro()
            promoted |= p
        return promoted

    def stats(self) -> dict:
        return {
            "refreshes": self.refreshes,
            "micro_chunks": self.micro_chunks,
            "micro_steps": self.micro_steps,
            "steps_done": self.steps_done,
            "refresh_wall_s": round(self.refresh_wall_s, 4),
            "decode_steps_stalled": self.micro_chunks,  # sync path: every micro-chunk rides the decode thread
            "exhausted": self.exhausted,
        }
