"""Production meshes.

``make_production_mesh`` is the contract mesh for the dry-run: a 16x16
single-pod (256 chips, TPU v5e) or 2x16x16 multi-pod (512 chips) device
grid.  ``make_train_mesh`` derives the EC-SGHMC training mesh from the same
device set by carving a ``chain`` axis out of the data axis (single-pod) —
multi-pod keeps the ``pod`` axis, and chains map onto (pod, chain): the
cross-pod link only carries the s-periodic elastic-coupling exchange, which
is the paper's deployment story.

``initialize_distributed`` / ``force_host_device_count`` /
``forced_device_env`` are the multi-process launch path (DESIGN.md §7):
real multi-host meshes go through ``jax.distributed.initialize``; a single
host can still exercise every collective by forcing N CPU devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the fallback the
multi-device test harness and ``benchmarks/shard_sweep.py`` run on.

Everything here is a FUNCTION (no module-level jax device state) so imports
never lock the device count before dryrun.py sets XLA_FLAGS.
"""
from __future__ import annotations

import os

import jax
import numpy as np

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int) -> str:
    """Single-host forced-multi-device fallback: rewrite ``XLA_FLAGS`` in
    THIS process's environment to force ``n`` host (CPU) devices.  Must run
    before jax initializes its backends — raises if the backend is already
    locked to a different device count (the flag would silently not apply).
    Returns the new ``XLA_FLAGS`` value."""
    flags = [
        f for f in os.environ.get("XLA_FLAGS", "").split() if not f.startswith(_FORCE_FLAG)
    ]
    flags.append(f"{_FORCE_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(flags)
    import jax._src.xla_bridge as xb

    if getattr(xb, "_backends", None):  # backends already initialized
        if jax.device_count() != n:
            raise RuntimeError(
                f"jax already initialized with {jax.device_count()} devices; "
                f"force_host_device_count({n}) must run before first device use "
                "(launch a subprocess with forced_device_env instead)"
            )
    return os.environ["XLA_FLAGS"]


def forced_device_env(n: int, base_env: dict | None = None) -> dict:
    """Environment for a SUBPROCESS with ``n`` forced host devices — the
    safe way to get a multi-device mesh when the current process already
    holds an initialized single-device backend (pytest, benchmarks)."""
    env = dict(os.environ if base_env is None else base_env)
    flags = [
        f for f in env.get("XLA_FLAGS", "").split() if not f.startswith(_FORCE_FLAG)
    ]
    flags.append(f"{_FORCE_FLAG}={n}")
    env["XLA_FLAGS"] = " ".join(flags)
    # force the CPU plugin: the flag only exists on the host platform
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def initialize_distributed(
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
    local_device_ids=None,
) -> tuple[int, int]:
    """``jax.distributed.initialize`` wiring for the multi-process launch
    path.  No-op (returns ``(0, 1)``) when nothing identifies a
    multi-process job — neither arguments nor the standard environment
    (``JAX_COORDINATOR_ADDRESS`` or a cluster auto-detect env jax knows) —
    so single-process entry points can call it unconditionally.  Returns
    ``(process_index, process_count)``."""
    if (
        coordinator_address is None
        and num_processes is None
        and process_id is None
        and not os.environ.get("JAX_COORDINATOR_ADDRESS")
    ):
        return 0, 1
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
        local_device_ids=local_device_ids,
    )
    return jax.process_index(), jax.process_count()


def make_chain_mesh(num_devices: int | None = None, *, axis: str = "chain"):
    """1-D ``(chain,)`` mesh over the first ``num_devices`` devices
    (default: all) — the sampler scale-out mesh ``ChainExecutor.run_sharded``
    consumes.  Works identically on real accelerators, multi-process
    device sets, and the forced-host-device fallback."""
    devs = jax.devices()
    n = len(devs) if num_devices is None else int(num_devices)
    if n > len(devs):
        raise ValueError(f"requested {n} devices, only {len(devs)} available")
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))


def make_engine_mesh(num_member_shards: int, num_slot_shards: int | None = None,
                     *, axes: tuple[str, str] = ("member", "slot")):
    """(member, slot) mesh for the sharded ``ServeEngine``: the K ensemble
    axis shards over ``axes[0]``, the decode-slot axis over ``axes[1]``.
    Defaults to spreading all remaining devices over slots."""
    devs = jax.devices()
    m = int(num_member_shards)
    s = len(devs) // m if num_slot_shards is None else int(num_slot_shards)
    if m * s > len(devs):
        raise ValueError(f"mesh {m}x{s} needs {m*s} devices, have {len(devs)}")
    return jax.sharding.Mesh(np.asarray(devs[: m * s]).reshape(m, s), axes)


def make_production_mesh(*, multi_pod: bool = False, size: int = 16):
    shape = (2, size, size) if multi_pod else (size, size)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_train_mesh(num_chains: int = 1, *, multi_pod: bool = False, size: int = 16,
                    tp: int | None = None):
    """Same devices as the production mesh, with a chain axis of size
    ``num_chains`` factored out of the per-pod data axis.

    ``tp`` re-balances the TP:DP ratio within the fixed chip count (the
    §Perf lever for activation-allreduce-bound cells): the per-pod grid is
    (chain, (size*size)/(chain*tp), tp) instead of (chain, size/chain, size).
    """
    chips = size * size
    tp = size if tp is None else tp
    assert chips % (num_chains * tp) == 0, (num_chains, tp)
    data = chips // (num_chains * tp)
    if multi_pod:
        return jax.make_mesh((2, num_chains, data, tp), ("pod", "chain", "data", "model"))
    return jax.make_mesh((num_chains, data, tp), ("chain", "data", "model"))


def make_serve_mesh(*, multi_pod: bool = False, size: int = 16, tp: int | None = None):
    """Production-mesh devices with a re-balanced (data, model) split for
    serving hillclimbs; tp=None returns the contract production mesh."""
    if tp is None:
        return make_production_mesh(multi_pod=multi_pod, size=size)
    chips = size * size
    assert chips % tp == 0
    shape = (2, chips // tp, tp) if multi_pod else (chips // tp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def total_chains(mesh, num_chains: int) -> int:
    """Total K across pods (multi-pod meshes double the chain count)."""
    return num_chains * mesh.shape.get("pod", 1)


HARDWARE = {
    # TPU v5e per-chip constants used by the roofline analysis
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link
}
