"""Overlapped snapshot refresh: the background sampler never stalls decode.

``ChainRefresher`` (registry.py) made live refresh *correct* but paid for it
synchronously — each cadence tick ran a full executor chunk plus a
host-synced spread gate inline with the decode loop (BENCH_serve.json:
tokens/s collapsing ~30 → 11.8).  The paper's point is that this
serialization is unnecessary: elastic coupling absorbs stale/perturbed
center information into the center-noise covariance (Eq. 6), so the
sampler and the decode stream have no ordering constraint between
promotions — they only need to agree at the flip.

``RefreshScheduler`` exploits that with four mechanisms (DESIGN.md §9):

1. **Micro-chunk async dispatch with backpressure.**  The background
   ``ChainExecutor.stream`` chunk is split into micro-chunks budgeted
   against decode ticks (``chunk_steps / refresh_every`` sampler steps per
   tick, credit-paced).  The scheduler's executor is built ``donate=False``:
   dispatching a DONATED program blocks the host until the in-flight
   computation releases the aliased buffer (measurably the whole chunk on
   the CPU client), while a donate-free dispatch only enqueues — the
   double-buffered carry is the price of a non-blocking pump.  A micro is
   dispatched only when the previous one's ``ChunkSnapshot.probe`` reports
   ``is_ready()`` (unspent credit banks, capped at two chunks), so a slow
   sampler backs up on ITS device, never in the host queue.  With
   ``key_mode='fold'`` the split is bit-identical to the unsplit chunk
   (§3: chunking is invisible).
2. **Lazy device-side gate.**  At a chunk boundary the candidate's spread
   verdict (``ensemble_spread_device`` under jit) is *dispatched*, not
   fetched; the registry parks (candidate, device-verdict) in its second
   buffer.  The scalar fetch happens at flip time, and only once
   ``jax.Array.is_ready()`` says it would not block.
3. **Pointer-flip promotion.**  Candidates are staged raw on the sampler's
   device (placing them at stage time would block on in-flight sampler
   compute); the copy into the engine's pinned placement (mesh
   ``NamedSharding``s, or the unsharded home device, via
   ``ServeEngine._place_members``) happens inside ``flip_staged`` — on a
   buffer the ready verdict proves is itself ready, so it is a bounded d2d
   memcpy.  Promotion rebinds a reference to buffers that carry the decode
   program's layouts — provably no retrace (the compile-count pin in
   tests/test_serve_engine.py and tests/test_sharding.py).
4. **Spare-device parking.**  When the engine's ``(member, slot)`` mesh
   does not consume every local device, the background run's carry is
   committed to a spare device — jit runs computation where its inputs
   live, so sampler micro-chunks execute off the serving devices entirely.
   Single-device hosts still benefit from 1–3 (overlap degrades to
   interleaving, but the host thread never blocks).

Warm-up (``bind``): the micro-chunk program and the gate reduction are
compiled at engine construction against throwaway copies of the real
avals, so first-promotion compile cost never lands on a serving request —
the other half of the bimodal first-token p99 the synchronous path showed.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.obs import trace as obs_trace
from repro.run import ChainExecutor

from .registry import SnapshotRegistry, _micro_split


def _pick_device(engine, request):
    """Placement policy for the background run.  ``request``: a concrete
    ``jax.Device``, ``None`` (no placement — default device), or ``"auto"``:
    prefer a local device the engine's mesh does not consume; without a
    mesh, the last local device when more than one exists."""
    if request != "auto":
        return request
    devs = list(jax.devices())
    mesh = getattr(engine, "mesh", None) if engine is not None else None
    if mesh is not None:
        used = {d for d in mesh.devices.flat}
        spare = [d for d in devs if d not in used]
        return spare[-1] if spare else None
    return devs[-1] if len(devs) > 1 else None


class RefreshScheduler:
    """Overlapped drop-in for :class:`~.registry.ChainRefresher`.

    Same constructor surface (registry, sampler, grad_fn, stacked params,
    fold key) plus placement/pacing knobs; the engine binds it at
    construction and calls ``pump(step)`` every decode tick.  A pump does
    at most three non-blocking things: flip a staged candidate whose
    verdict is ready, accrue micro-chunk credit, and dispatch whole
    credits' worth of micro-chunks (staging a candidate at each chunk
    boundary).  The only way the host ever waits on the sampler is a
    *forced* flip (``max_flip_deferrals`` exceeded, or draining the last
    candidate after exhaustion) — counted in ``decode_steps_stalled`` /
    ``stall_wall_s`` so BENCH rows can attribute latency to refresh.
    """

    def __init__(
        self,
        registry: SnapshotRegistry,
        sampler,
        grad_fn,
        params,
        *,
        key,
        state=None,
        chunk_steps: int = 64,
        micro_steps: int | None = None,
        total_steps: int = 1 << 30,
        members_of=None,
        device="auto",
        max_flip_deferrals: int | None = None,
        sync_every: int | None = None,
    ):
        self.registry = registry
        self.members_of = members_of or (lambda p: p)
        self._sampler = sampler
        self._grad_fn = grad_fn
        self._params = params
        self._state = sampler.init(params) if state is None else state
        self._key = key
        self._total_steps = int(total_steps)
        self.chunk_steps = int(chunk_steps)
        self._explicit_micro = micro_steps is not None
        self.micro_steps = int(micro_steps) if micro_steps else self.chunk_steps
        if self.chunk_steps % self.micro_steps:
            raise ValueError("micro_steps must divide chunk_steps")
        self._device_req = device
        self.device = None
        self._max_flip_deferrals = max_flip_deferrals
        # Static sync-collective cadence of the bound sampler (EC s), used
        # to host-RECONSTRUCT `sampler.sync_collective` trace instants at
        # micro-chunk dispatch: the collective fires inside the compiled
        # scan and cannot be observed from the host, but its step indices
        # are determined by this cadence (DESIGN.md §11).  None = the
        # sampler has no cross-chain collective (e.g. chainwise SGLD).
        self.sync_every = int(sync_every) if sync_every else None
        self._engine = None
        self._ex = None
        self._stream = None
        self._credit = 0.0
        self._rate = 1.0  # micro-chunks per pump; bind() paces to the cadence
        self._deferrals = 0
        self._probe = None  # last micro's ChunkSnapshot.probe (readiness gate)
        self._cycle_t0: float | None = None
        self.steps_done = 0
        self.micro_chunks = 0
        self.backpressure_ticks = 0
        self.proposals = 0
        self.refreshes = 0  # flips resolved (promoted or rejected)
        self.promotions = 0
        self.flips_deferred = 0
        self.decode_steps_stalled = 0
        self.stall_wall_s = 0.0
        self.pump_wall_s = 0.0
        self.refresh_walls: list[float] = []
        self.exhausted = False

    # -- engine binding / warm-up --------------------------------------------

    def bind(self, engine) -> None:
        """Attach to a ``ServeEngine``: pace micro-chunks to its
        ``refresh_every`` cadence, park the background carry on a spare
        device, and pre-compile the micro-chunk + gate programs."""
        if self._stream is not None:
            raise RuntimeError("bind() must precede the first pump/refresh")
        self._engine = engine
        cadence = max(int(getattr(engine, "refresh_every", 0)), 1)
        if not self._explicit_micro:
            self.micro_steps = _micro_split(self.chunk_steps, cadence)
        self._rate = (self.chunk_steps // self.micro_steps) / cadence
        self.device = _pick_device(engine, self._device_req)
        if self.device is not None:
            self._params = jax.device_put(self._params, self.device)
            self._state = jax.device_put(self._state, self.device)
            self._key = jax.device_put(self._key, self.device)
        self._warmup()

    def _make_executor(self) -> ChainExecutor:
        # donate=False: a donated dispatch blocks the host until the
        # in-flight chunk releases the aliased carry buffer — the opposite
        # of overlap.  Double-buffering the carry keeps pump() non-blocking.
        return ChainExecutor(
            sampler=self._sampler,
            grad_fn=lambda targets, _batch: self._grad_fn(targets),
            chunk_steps=self.micro_steps,
            key_mode="fold",
            donate=False,
        )

    def _warmup(self) -> None:
        """Compile the micro-chunk scan and the gate reduction before any
        request is in flight.  The throwaway carry copies are consumed by
        donation; with fold keying the warm-up run cannot perturb the real
        stream's RNG (per-step keys come from the absolute step index)."""
        copy = lambda tr: jax.tree.map(lambda x: jnp.asarray(x).copy(), tr)
        self._ex = self._make_executor()
        n = min(self.micro_steps, self._total_steps)
        fn, _n_outer, thin = self._ex._compile(n, False, None)
        carry = self._ex._init_carry(copy(self._params), copy(self._state), 0, self._key, False)
        xs = self._ex._chunk_xs(0, 0, n, thin, None, False)
        carry, _ = fn(None, self._key, carry, xs)
        # second call on the PRODUCED carry: its scalars are now committed to
        # the sampler device, a different jit signature than the fresh carry
        # above — without this, micro #1 recompiles inside a served pump
        out = fn(None, self._key, carry, xs)
        # gate reduction, on the exact candidate avals a stage will see
        # (the gate runs where the sampler lives; placement is flip-time)
        health = self.registry.health_device(self.members_of(copy(self._params)))
        # bind-time blocking is fine (no request in flight yet) and leaves
        # the device queues empty when serving starts
        jax.block_until_ready((out, health))

    def _place(self, tree):
        return self._engine._place_members(tree) if self._engine is not None else tree

    def _ensure_stream(self):
        if self._stream is None:
            if self._ex is None:
                self._ex = self._make_executor()
            # copy_snapshots=False is safe ONLY because the executor is
            # donate=False (nothing ever mutates or deletes the yielded
            # buffers); it removes a per-boundary tree of copy dispatches
            # from the pump — host time the decode loop would otherwise pay
            self._stream = self._ex.stream(
                self._params,
                self._state,
                num_steps=self._total_steps,
                key=self._key,
                snapshot_every=self.chunk_steps // self.micro_steps,
                copy_snapshots=False,
            )
            self._params = self._state = None  # donated into the stream
        return self._stream

    # -- overlapped advancement ----------------------------------------------

    def _dispatch_micro(self) -> None:
        """Enqueue one micro-chunk; at a chunk boundary, pre-place the
        candidate and stage it with a dispatched (unfetched) verdict.
        Nothing here blocks the host."""
        if self._cycle_t0 is None:
            self._cycle_t0 = time.perf_counter()
        tr = obs_trace.get()
        prev_step = self.steps_done
        with tr.span("refresh.micro_chunk", cat="refresh", from_step=prev_step):
            try:
                snap = next(self._ensure_stream())
            except StopIteration:
                self.exhausted = True
                return
        self.micro_chunks += 1
        self.steps_done = snap.step
        self._probe = snap.probe
        if tr.enabled and self.sync_every:
            # reconstructed, not observed: every sync boundary the dispatched
            # micro covered, at known step indices (see __init__)
            s = self.sync_every
            first = (prev_step // s + 1) * s  # next multiple of s after prev
            for step in range(first, snap.step + 1, s):
                tr.instant("sampler.sync_collective", cat="sampler", step=step)
        if snap.params is not None:
            # stage raw (sampler-device) — the gate reduction runs where the
            # candidate lives; a device_put here would block the pump on
            # in-flight sampler compute.  Placement happens at the flip.
            self.registry.stage(self.members_of(snap.params))
            self.proposals += 1

    def _sampler_idle(self) -> bool:
        """True when the last dispatched micro-chunk has retired (its device
        probe is ready) — the gate that keeps the dispatch queue depth at
        one and a slow sampler from accumulating unbounded in-flight work."""
        return self._probe is None or bool(
            getattr(self._probe, "is_ready", lambda: True)()
        )

    def _maybe_flip(self, *, force: bool) -> bool:
        """Resolve the staged candidate if its verdict is ready (or we are
        forced to wait for it); returns True iff promoted."""
        if self.registry.staged is None:
            return False
        ready = self.registry.staged_ready()
        may_defer = self._max_flip_deferrals is None or self._deferrals < self._max_flip_deferrals
        if not ready and not force and may_defer:
            self._deferrals += 1
            self.flips_deferred += 1
            obs_trace.get().instant(
                "refresh.flip_deferred", cat="refresh", deferrals=self._deferrals
            )
            return False
        t0 = time.perf_counter()
        # blocks only when not ready; placement of the ready candidate into
        # the engine's pinned layout is a bounded d2d copy
        with obs_trace.get().span("refresh.flip", cat="refresh",
                                  forced=force, verdict_ready=ready):
            promoted = self.registry.flip_staged(place=self._place)
        if not ready:
            self.stall_wall_s += time.perf_counter() - t0
            self.decode_steps_stalled += 1
        self._deferrals = 0
        self.refreshes += 1
        if self._cycle_t0 is not None:
            self.refresh_walls.append(time.perf_counter() - self._cycle_t0)
            self._cycle_t0 = None
        if promoted:
            self.promotions += 1
            if self._engine is not None:
                # candidate was placed at the flip: _members() must not re-put it
                self._engine.mark_members_placed()
        return promoted

    def pump(self, step: int) -> bool:
        """One decode tick's worth of refresh work.  Returns True iff a
        promotion flipped in this call."""
        del step  # pacing is credit-based, robust to per-run step resets
        t0 = time.perf_counter()
        promoted = self._maybe_flip(force=False)
        if not self.exhausted:
            micros_per_chunk = self.chunk_steps // self.micro_steps
            self._credit = min(self._credit + self._rate, 2.0 * micros_per_chunk)
            if self._credit >= 1.0 and not self._sampler_idle():
                self.backpressure_ticks += 1
                obs_trace.get().instant(
                    "refresh.backpressure", cat="refresh", credit=self._credit
                )
            while self._credit >= 1.0 and not self.exhausted and self._sampler_idle():
                self._credit -= 1.0
                self._dispatch_micro()
        if self.exhausted and self.registry.staged is not None:
            # nothing further will be staged — don't strand the last candidate
            promoted = self._maybe_flip(force=True) or promoted
        self.pump_wall_s += time.perf_counter() - t0
        return promoted

    def refresh(self) -> bool:
        """Synchronous parity surface (``ChainRefresher`` semantics):
        advance to the next proposal boundary and resolve it, blocking on
        the verdict.  Returns True iff promoted; False once exhausted."""
        while not self.exhausted and self.registry.staged is None:
            self._dispatch_micro()
        return self._maybe_flip(force=True)

    def stats(self) -> dict:
        walls = self.refresh_walls
        return {
            "refreshes": self.refreshes,
            "proposals": self.proposals,
            "promotions": self.promotions,
            "rejections": self.refreshes - self.promotions,
            "micro_chunks": self.micro_chunks,
            "micro_steps": self.micro_steps,
            "steps_done": self.steps_done,
            "backpressure_ticks": self.backpressure_ticks,
            "flips_deferred": self.flips_deferred,
            "decode_steps_stalled": self.decode_steps_stalled,
            "stall_wall_s": round(self.stall_wall_s, 4),
            "pump_wall_s": round(self.pump_wall_s, 4),
            "refresh_wall_s": round(sum(walls), 4),
            "per_refresh_wall_s": round(sum(walls) / len(walls), 4) if walls else 0.0,
            "device": str(self.device) if self.device is not None else None,
            "exhausted": self.exhausted,
        }
