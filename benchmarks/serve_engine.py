"""Serving-engine latency/throughput bench (``BENCH_serve.json``).

Drives the continuous-batching posterior-predictive engine
(``repro.serve.engine``) with open-loop synthetic request traces on the
smoke-sized qwen3 config and records, per (slots, K, offered-load)
configuration: p50/p99 request latency, p50/p99 first-token latency, and
aggregate tokens/s — the serving tier's perf trajectory across PRs.  One
configuration additionally runs with live snapshot refresh enabled to price
the refresh cost in-band, and a dense-vs-paged sweep (with and without
prefix sharing, on a prompt-pool trace) records the DESIGN.md §8 memory
axes: KV bytes per request (high-water for paged, static footprint for
dense) and the prefix-cache hit rate.

CSV rows keep the historical ``name,us_per_call,derived`` shape:
us_per_call = mean decode-step wall time, derived = tokens/s.
"""
from __future__ import annotations

import jax

from repro import configs
from repro.models import get_model, init_params
from repro.launch.serve import _live_refresher
from repro.serve.engine import ServeEngine, SnapshotRegistry, synthetic_trace

from common import QUICK, emit, record

ARCH = "qwen3-0.6b"
# (slots, K, mean_interarrival decode-steps): two slot widths x two ensemble
# sizes, light and heavy offered load on the wider one
GRID_QUICK = [
    (2, 1, 2.0),
    (4, 2, 2.0),
    (4, 2, 0.5),
]
GRID_FULL = GRID_QUICK + [
    (8, 4, 2.0),
    (8, 4, 0.5),
]


def _members(cfg, model, k: int, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    return jax.vmap(lambda kk: init_params(model.param_specs(cfg), kk))(keys)


PROMPT_LENS = (8, 16)


def _one_config(cfg, model, slots, k, interarrival, *, num_requests, max_new,
                refresh=False, prompt_pool=0, **engine_kw):
    registry = SnapshotRegistry(_members(cfg, model, k))
    refresher = None
    if refresh:
        refresher = _live_refresher(model.param_specs(cfg), jax.random.PRNGKey(7), registry)
    engine = ServeEngine(
        cfg, model, registry,
        num_slots=slots, max_seq=max(PROMPT_LENS) + max_new,
        refresher=refresher, refresh_every=8 if refresh else 0,
        **engine_kw,
    )
    trace = synthetic_trace(
        num_requests,
        vocab_size=cfg.vocab_size,
        prompt_lens=PROMPT_LENS,
        max_new=max_new,
        mean_interarrival=interarrival,
        seed=1,
        prompt_pool=prompt_pool,
    )
    report = engine.run(trace)
    assert report.trace_counts.get("decode") == 1, report.trace_counts
    pct = report.latency_percentiles()
    return engine, report, pct


def _kv_bytes(engine):
    """Dense: the static pool footprint (every slot pays max_seq up front).
    Paged: high-water page bytes actually touched over the run."""
    if engine.paged:
        return engine.pool.stats()["bytes_high_water"]
    return sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(engine.pool.caches)
    )


def run():
    cfg = configs.get_config(ARCH, smoke=True)
    model = get_model(cfg)
    grid = GRID_QUICK if QUICK else GRID_FULL
    num_requests = 8 if QUICK else 32
    max_new = 8 if QUICK else 24
    configs_out = []
    for slots, k, inter in grid:
        _, report, pct = _one_config(
            cfg, model, slots, k, inter, num_requests=num_requests, max_new=max_new
        )
        name = f"serve_s{slots}_k{k}_ia{inter:g}"
        step_us = 1e6 * report.wall_s / max(report.decode_steps, 1)
        emit(name, step_us, f"{report.tokens_per_s:.1f}tok/s")
        configs_out.append(
            {
                "slots": slots,
                "ensemble": k,
                "mean_interarrival": inter,
                "requests": len(report.results),
                "total_tokens": report.total_tokens,
                "decode_steps": report.decode_steps,
                "wall_s": round(report.wall_s, 4),
                "tokens_per_s": round(report.tokens_per_s, 2),
                "decode_traces": report.trace_counts.get("decode"),
                **{kk: round(v, 6) for kk, v in pct.items()},
            }
        )
    # dense vs paged (± prefix sharing) on the middle configuration, over a
    # prompt-pool trace so sharing has something to hit
    slots, k, inter = grid[1]
    pool_size = 3
    for variant, kw in (
        ("dense", {}),
        ("paged", {"paged": True, "block_size": 8}),
        ("paged_noshare", {"paged": True, "block_size": 8, "prefix_sharing": False}),
    ):
        engine, report, pct = _one_config(
            cfg, model, slots, k, inter, num_requests=num_requests,
            max_new=max_new, prompt_pool=pool_size, **kw,
        )
        kv = _kv_bytes(engine)
        per_req = kv / max(len(report.results), 1)
        st = engine.pool.stats()
        hit_rate = st.get("prefix_hit_rate", 0.0)
        emit(
            f"serve_s{slots}_k{k}_{variant}",
            1e6 * report.wall_s / max(report.decode_steps, 1),
            f"{per_req / 1024:.1f}KiB/req",
        )
        configs_out.append(
            {
                "slots": slots,
                "ensemble": k,
                "mean_interarrival": inter,
                "variant": variant,
                "prompt_pool": pool_size,
                "requests": len(report.results),
                "total_tokens": report.total_tokens,
                "tokens_per_s": round(report.tokens_per_s, 2),
                "wall_s": round(report.wall_s, 4),
                "kv_bytes": int(kv),
                "kv_bytes_per_request": round(per_req, 1),
                "prefix_hit_rate": round(float(hit_rate), 4),
                "prefix_hits": st.get("prefix_hits", 0),
                "blocks_high_water": st.get("blocks_high_water"),
                "decode_traces": report.trace_counts.get("decode"),
                **{kk: round(v, 6) for kk, v in pct.items()},
            }
        )
    # price the live-refresh path on the middle configuration
    _, report, pct = _one_config(
        cfg, model, slots, k, inter, num_requests=num_requests, max_new=max_new, refresh=True
    )
    emit(
        f"serve_s{slots}_k{k}_refresh",
        1e6 * report.wall_s / max(report.decode_steps, 1),
        f"{report.tokens_per_s:.1f}tok/s",
    )
    configs_out.append(
        {
            "slots": slots,
            "ensemble": k,
            "mean_interarrival": inter,
            "refresh_every": 8,
            "snapshots_promoted": report.registry["promoted"],
            "snapshots_rejected": report.registry["rejected"],
            "refresh_wall_s": report.refresher["refresh_wall_s"],
            "tokens_per_s": round(report.tokens_per_s, 2),
            "wall_s": round(report.wall_s, 4),
            **{kk: round(v, 6) for kk, v in pct.items()},
        }
    )
    record("serve", {"arch": ARCH, "configs": configs_out})
    return {"num_configs": len(configs_out)}
