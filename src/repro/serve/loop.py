"""Serving steps: prefill (prompt -> cache) and greedy decode.

``decode_step``/``serve_step`` is what the decode_* and long_* dry-run cells
lower: one new token against a KV/recurrent cache of seq_len."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ModelDef
from repro.models.common import ModelConfig


def make_prefill_step(cfg: ModelConfig, model: ModelDef, max_seq: int, cache_dtype=None):
    def prefill_step(params, batch):
        logits, cache = model.prefill(cfg, params, batch, max_seq, cache_dtype)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, model: ModelDef):
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(cfg, params, cache, tokens)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, new_cache

    return serve_step


def generate(cfg: ModelConfig, model: ModelDef, params, batch, max_seq: int, num_tokens: int):
    """Host-side greedy generation loop (examples / integration tests)."""
    prefill = jax.jit(make_prefill_step(cfg, model, max_seq))
    step = jax.jit(make_decode_step(cfg, model))
    tok, cache = prefill(params, batch)
    out = [tok]
    for _ in range(num_tokens - 1):
        tok, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
