"""Kernel-level differential gate for the paged decode tier.

Two kernels, each pinned against an unfused dense oracle:

* ``kernels.paged_attention`` (Pallas, scalar-prefetched block tables) vs
  ``kernels.ref.paged_attention`` (gather-everything masked softmax) over
  ragged context lengths, block sizes, GQA group sizes, sliding windows
  and logit softcaps — plus an end-to-end check against the model's jnp
  paged-decode attention path;
* ``kernels.fused_bma_select`` vs ``kernels.ref.bma_select`` AND the
  engine's unfused ``mixture_logprobs`` + ``select_tokens`` composition —
  token draws must be BIT-identical (Gumbel-argmax identity, same key).

Everything runs in interpret mode on CPU; the same code compiles on TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels
from repro.kernels import ref
from repro.serve.engine.bma import mixture_logprobs
from repro.serve.sampling import SamplingParams, select_tokens


def _paged_case(key, *, B, Hkv, G, d, bs, M, ragged=True):
    """Random pool + tables: each sequence owns its first rows' pages."""
    kq, kk, kv, kc = jax.random.split(key, 4)
    num_pages = B * M + 1
    q = jax.random.normal(kq, (B, Hkv, G, d), jnp.float32)
    k_pages = jax.random.normal(kk, (num_pages, bs, Hkv, d), jnp.float32)
    v_pages = jax.random.normal(kv, (num_pages, bs, Hkv, d), jnp.float32)
    tables = (1 + jnp.arange(B * M, dtype=jnp.int32)).reshape(B, M)
    if ragged:
        ctx = jax.random.randint(kc, (B,), 0, M * bs)
    else:
        ctx = jnp.full((B,), M * bs - 1, jnp.int32)
    return q, k_pages, v_pages, tables, ctx


class TestPagedAttentionKernel:
    @pytest.mark.parametrize("bs", [8, 16, 64])
    def test_matches_dense_reference_across_block_sizes(self, bs):
        q, k, v, tab, ctx = _paged_case(
            jax.random.PRNGKey(bs), B=3, Hkv=2, G=2, d=16, bs=bs, M=3
        )
        got = kernels.paged_attention(q, k, v, tab, ctx)
        want = ref.paged_attention(q, k, v, tab, ctx)
        np.testing.assert_allclose(got, want, atol=2e-6)

    @pytest.mark.parametrize(
        "B,Hkv,G,d,bs,M",
        [(1, 1, 1, 16, 8, 1), (2, 1, 4, 32, 16, 3), (4, 2, 1, 16, 8, 6),
         (2, 2, 2, 64, 8, 4)],
    )
    def test_shapes_grid(self, B, Hkv, G, d, bs, M):
        q, k, v, tab, ctx = _paged_case(
            jax.random.PRNGKey(B * 100 + d), B=B, Hkv=Hkv, G=G, d=d, bs=bs, M=M
        )
        got = kernels.paged_attention(q, k, v, tab, ctx)
        want = ref.paged_attention(q, k, v, tab, ctx)
        np.testing.assert_allclose(got, want, atol=2e-6)

    def test_window_and_softcap(self):
        q, k, v, tab, ctx = _paged_case(
            jax.random.PRNGKey(7), B=4, Hkv=2, G=1, d=16, bs=8, M=6
        )
        for kw in ({"window": 12}, {"softcap": 20.0}, {"window": 5, "softcap": 8.0}):
            got = kernels.paged_attention(q, k, v, tab, ctx, **kw)
            want = ref.paged_attention(q, k, v, tab, ctx, **kw)
            np.testing.assert_allclose(got, want, atol=2e-6, err_msg=str(kw))

    def test_custom_scale(self):
        q, k, v, tab, ctx = _paged_case(
            jax.random.PRNGKey(9), B=2, Hkv=1, G=2, d=16, bs=8, M=2
        )
        got = kernels.paged_attention(q, k, v, tab, ctx, scale=0.5)
        want = ref.paged_attention(q, k, v, tab, ctx, scale=0.5)
        np.testing.assert_allclose(got, want, atol=2e-6)

    def test_ctx_zero_attends_only_position_zero(self):
        """Inclusive-position convention: ctx = 0 means exactly one valid
        key — the reference degenerates to v[page0, 0]."""
        q, k, v, tab, _ = _paged_case(
            jax.random.PRNGKey(3), B=2, Hkv=1, G=1, d=16, bs=8, M=2
        )
        ctx = jnp.zeros((2,), jnp.int32)
        got = kernels.paged_attention(q, k, v, tab, ctx)
        want = v[tab[:, 0], 0][:, :, None, :]  # softmax over one key
        np.testing.assert_allclose(got, want, atol=2e-6)

    def test_table_permutation_invariance(self):
        """Physical page placement is immaterial: permuting the pool and
        rewriting tables to match leaves the output unchanged."""
        q, k, v, tab, ctx = _paged_case(
            jax.random.PRNGKey(5), B=2, Hkv=1, G=2, d=16, bs=8, M=3
        )
        base = kernels.paged_attention(q, k, v, tab, ctx)
        perm = np.r_[0, 1 + np.random.default_rng(0).permutation(k.shape[0] - 1)]
        inv = np.argsort(perm)
        got = kernels.paged_attention(
            q, k[perm], v[perm], jnp.asarray(inv)[tab], ctx
        )
        np.testing.assert_allclose(got, base, atol=1e-6)

    def test_matches_model_jnp_paged_path(self):
        """The kernel and the model's pure-jnp gather path (what CPU serving
        uses) agree — the same pin the engine differential relies on."""
        from repro import configs
        from repro.models import get_model, init_params
        from repro.models import layers as L

        cfg = configs.get_config("qwen3-0.6b", smoke=True).replace(
            vocab_size=32, d_model=32, num_layers=1, num_heads=2,
            num_kv_heads=1, head_dim=16, d_ff=32,
        )
        model = get_model(cfg)
        params = init_params(model.param_specs(cfg), jax.random.PRNGKey(0))
        prompt = jnp.arange(1, 7, dtype=jnp.int32)[None]
        _, cache = model.prefill(cfg, params, {"tokens": prompt}, 16, None)
        pools = model.paged.make_pools(cfg, 5, 8, cfg.compute_dtype)
        tab = jnp.asarray([[1, 2]], jnp.int32)
        pools = model.paged.prefill_write(cfg, pools, cache, tab[0], 8)
        tok = jnp.asarray([[3]], jnp.int32)
        ctx = jnp.asarray([6], jnp.int32)
        wb = tab[:, 0]
        jnp_logits, _ = model.paged.decode_step(
            cfg, params, pools, tok, tab, ctx, wb
        )
        kcfg = cfg.replace(use_flash_kernel=True)
        k_logits, _ = model.paged.decode_step(
            kcfg, params, pools, tok, tab, ctx, wb
        )
        np.testing.assert_allclose(k_logits, jnp_logits, atol=2e-5)


class TestFusedBmaSelect:
    def _logits(self, key, K=3, S=4, V=40):
        return 4.0 * jax.random.normal(key, (K, S, V), jnp.float32)

    @pytest.mark.parametrize("mode", ["probs", "logprobs"])
    @pytest.mark.parametrize("temperature,top_k",
                             [(0.0, 0), (1.3, 0), (0.7, 5), (2.0, 1)])
    def test_matches_ref_oracle(self, mode, temperature, top_k):
        logits = self._logits(jax.random.PRNGKey(1))
        key = jax.random.PRNGKey(2)
        S, V = logits.shape[1:]
        gumbel = (jax.random.gumbel(key, (S, V), jnp.float32)
                  if temperature > 0 else jnp.zeros((S, V), jnp.float32))
        tok, logp = kernels.fused_bma_select(
            logits, key, mode=mode, temperature=temperature, top_k=top_k
        )
        rtok, rlogp = ref.bma_select(
            logits, gumbel, mode=mode, temperature=temperature, top_k=top_k
        )
        np.testing.assert_array_equal(tok, rtok)
        np.testing.assert_allclose(logp, rlogp, atol=2e-6)

    @pytest.mark.parametrize("mode", ["probs", "logprobs"])
    @pytest.mark.parametrize("temperature,top_k",
                             [(0.0, 0), (1.3, 0), (0.7, 5)])
    def test_tokens_bit_equal_to_engine_path(self, mode, temperature, top_k):
        """The exact composition the engine would otherwise run — including
        jax.random.categorical with the SAME key — must pick the SAME
        tokens (Gumbel-argmax identity)."""
        logits = self._logits(jax.random.PRNGKey(3))
        key = jax.random.PRNGKey(4)
        tok, logp = kernels.fused_bma_select(
            logits, key, mode=mode, temperature=temperature, top_k=top_k
        )
        want_logp = mixture_logprobs(logits, mode)
        want_tok = select_tokens(
            want_logp, key, SamplingParams(temperature=temperature, top_k=top_k)
        )
        np.testing.assert_array_equal(tok, want_tok)
        np.testing.assert_allclose(logp, want_logp, atol=2e-6)

    def test_top_k_tie_handling_matches_mask(self):
        """Ties at the k-th value keep every tied candidate, exactly like
        sampling._top_k_mask (strictly-less threshold)."""
        row = jnp.asarray([[2.0, 2.0, 1.0, 0.0, 2.0, -1.0]], jnp.float32)
        logits = jnp.log(jax.nn.softmax(row))[None]  # K=1: mixture == row
        gumbel = jnp.zeros((1, 6), jnp.float32)
        tok, _ = ref.bma_select(logits, gumbel, mode="probs",
                                temperature=1.0, top_k=2)
        ftok, _ = kernels.fused_bma_select(
            logits, jax.random.PRNGKey(0), mode="probs",
            temperature=1e9, top_k=2,  # huge T: selection ~ mask + zero noise
        )
        assert int(tok[0]) == 0  # first of the tied maxima
        assert int(ftok[0]) in (0, 1, 4)  # any tied-survivor is admissible

    def test_greedy_single_member_is_argmax(self):
        logits = self._logits(jax.random.PRNGKey(6), K=1)
        tok, _ = kernels.fused_bma_select(logits, jax.random.PRNGKey(0))
        np.testing.assert_array_equal(tok, jnp.argmax(logits[0], axis=-1))
