"""Streaming (in-carry) effective-sample-size estimation via batch means.

The FFT estimators in ``repro.diagnostics.ess`` need the whole trajectory
on the host.  Device-resident runs (``repro.run.ChainExecutor``) cannot
afford that: the accumulator below rides the ``lax.scan`` carry next to the
Welford moments and yields an ESS estimate with ZERO host syncs and O(1)
memory.

Method — non-overlapping batch means (Glynn & Whitt):  split the series
into batches of length ``b``; the variance of the batch means times ``b``
estimates the spectral density at zero, sigma^2 = lim n Var(mean_n); then

    ESS = n * Var(x) / sigma^2_bm ,    sigma^2_bm = b * Var_m(batch means).

Consistent as b -> inf with m = n/b -> inf; b ~ sqrt(n) is the usual
compromise, so pick ``batch_len`` near sqrt(total steps).  The estimate is
elementwise over the probe array — chains/dims stay separate, matching the
``*_nd`` convention of the FFT estimators.

Moment arithmetic is f32; COUNTERS are int32 — an f32 counter freezes at
2^24 ≈ 16.7M steps (x + 1 == x), exactly the run lengths this module
exists for.  The state is a flat NamedTuple of arrays, so it jits,
donates, and vmaps like any other carry.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp


class BatchMeansState(NamedTuple):
    """Running batch-means ESS accumulator for one probe array."""

    batch_len: jnp.ndarray  # scalar i32 (carried, not static: keeps the carry pure)
    count: jnp.ndarray  # scalar i32: samples seen
    batch_sum: Any  # (probe shape) f32: sum within the open batch
    # Welford over completed batch means
    m_count: jnp.ndarray  # scalar i32: completed batches
    m_mean: Any
    m_m2: Any
    # Welford over raw samples (for Var(x))
    x_mean: Any
    x_m2: Any


def batch_ess_init(template, batch_len: int) -> BatchMeansState:
    # distinct zero buffers per field — aliasing would break XLA donation
    z = lambda: jnp.zeros(jnp.shape(template), jnp.float32)
    return BatchMeansState(
        batch_len=jnp.asarray(int(batch_len), jnp.int32),
        count=jnp.zeros((), jnp.int32),
        batch_sum=z(),
        m_count=jnp.zeros((), jnp.int32),
        m_mean=z(),
        m_m2=z(),
        x_mean=z(),
        x_m2=z(),
    )


def batch_ess_add(state: BatchMeansState, x) -> BatchMeansState:
    """One streaming update (branch-free: batch closure via select masks)."""
    x = jnp.asarray(x, jnp.float32)
    n = state.count + 1

    # raw-sample Welford
    d = x - state.x_mean
    x_mean = state.x_mean + d / n.astype(jnp.float32)
    x_m2 = state.x_m2 + d * (x - x_mean)

    batch_sum = state.batch_sum + x
    complete = jnp.mod(n, state.batch_len) == 0

    # close the batch: fold its mean into the batch-mean Welford
    bm = batch_sum / state.batch_len.astype(jnp.float32)
    mc = state.m_count + 1
    dm = bm - state.m_mean
    m_mean_new = state.m_mean + dm / mc.astype(jnp.float32)
    m_m2_new = state.m_m2 + dm * (bm - m_mean_new)

    sel = lambda a, b: jnp.where(complete, a, b)
    return BatchMeansState(
        batch_len=state.batch_len,
        count=n,
        batch_sum=sel(jnp.zeros_like(batch_sum), batch_sum),
        m_count=sel(mc, state.m_count),
        m_mean=sel(m_mean_new, state.m_mean),
        m_m2=sel(m_m2_new, state.m_m2),
        x_mean=x_mean,
        x_m2=x_m2,
    )


def batch_ess_estimate(state: BatchMeansState):
    """Elementwise ESS estimate (same shape as the probe).  Returns the raw
    sample count until at least two batches have closed (no estimate yet), and
    clips to [1, n] — batch-means can overshoot on anticorrelated series.
    jit-safe: no host syncs, no branching."""
    n = state.count.astype(jnp.float32)
    m = state.m_count.astype(jnp.float32)
    var_x = state.x_m2 / jnp.maximum(n - 1.0, 1.0)
    var_bm = state.m_m2 / jnp.maximum(m - 1.0, 1.0)
    sigma2 = state.batch_len.astype(jnp.float32) * var_bm
    ess = n * var_x / jnp.maximum(sigma2, 1e-30)
    ess = jnp.clip(ess, 1.0, jnp.maximum(n, 1.0))
    ready = (m >= 2.0) & (var_x > 0.0).astype(jnp.bool_)
    return jnp.where(ready, ess, jnp.maximum(n, 1.0))
