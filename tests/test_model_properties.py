"""Property-based tests (hypothesis) for model-layer invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from util import import_hypothesis

given, settings, st = import_hypothesis()  # deterministic tests run bare

from repro import configs
from repro.models import get_model, init_params, layers as L
from repro.models.common import LayerKind


def _cfg():
    return configs.get_config("h2o-danube-1.8b", smoke=True)


class TestAttentionInvariants:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_causality(self, seed):
        """Changing future tokens must not change past outputs."""
        cfg = _cfg()
        params = init_params(L.attn_specs(cfg), jax.random.PRNGKey(0))
        B, S = 1, 16
        key = jax.random.PRNGKey(seed)
        x1 = jax.random.normal(key, (B, S, cfg.d_model))
        x2 = x1.at[:, S // 2 :].set(jax.random.normal(jax.random.fold_in(key, 1), (B, S // 2, cfg.d_model)))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        o1 = L.attention(cfg, params, x1, pos, window=None)
        o2 = L.attention(cfg, params, x2, pos, window=None)
        np.testing.assert_allclose(
            np.asarray(o1[:, : S // 2]), np.asarray(o2[:, : S // 2]), rtol=1e-5, atol=1e-5
        )

    @settings(max_examples=8, deadline=None)
    @given(window=st.sampled_from([2, 4, 8]))
    def test_window_locality(self, window):
        """With window w, tokens further than w back must not influence."""
        cfg = _cfg()
        params = init_params(L.attn_specs(cfg), jax.random.PRNGKey(0))
        B, S = 1, 16
        x1 = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
        # perturb ONLY position 0; outputs at t >= window must be unchanged
        x2 = x1.at[:, 0].add(1.0)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        o1 = L.attention(cfg, params, x1, pos, window=window)
        o2 = L.attention(cfg, params, x2, pos, window=window)
        np.testing.assert_allclose(
            np.asarray(o1[:, window:]), np.asarray(o2[:, window:]), rtol=1e-5, atol=1e-5
        )

    def test_rope_relative_shift_invariance(self):
        """RoPE attention scores depend on relative positions only: shifting
        all positions by a constant must leave outputs unchanged."""
        cfg = _cfg()
        params = init_params(L.attn_specs(cfg), jax.random.PRNGKey(0))
        B, S = 1, 8
        x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model))
        p0 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        o1 = L.attention(cfg, params, x, p0, window=None)
        o2 = L.attention(cfg, params, x, p0 + 37, window=None)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)


class TestXentInvariants:
    @settings(max_examples=10, deadline=None)
    @given(s=st.integers(3, 40), chunk=st.sampled_from([4, 16, 64]))
    def test_chunking_invariance(self, s, chunk):
        """Chunked xent == full xent for any (S, chunk) incl. remainders."""
        cfg = _cfg().replace(xent_chunk=chunk)
        params = init_params(L.embed_specs(cfg), jax.random.PRNGKey(0))
        B = 2
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(s), (B, s, cfg.d_model))
        labels = jax.random.randint(jax.random.PRNGKey(s + 1), (B, s), 0, cfg.vocab_size)
        nll, cnt = L.chunked_xent(cfg, params, x, labels)
        # reference: dense logits (danube is untied -> unembed matrix)
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"]).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        ref = jnp.sum(lse - gold)
        assert int(cnt) == B * s
        np.testing.assert_allclose(float(nll), float(ref), rtol=1e-4)


class TestMoEInvariants:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_moe_output_finite_and_shaped(self, seed):
        from repro.models import moe

        cfg = configs.get_config("olmoe-1b-7b", smoke=True)
        params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, cfg.d_model))
        y = moe.moe_ffn(cfg, params, x)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_capacity_drops_are_bounded(self):
        """With capacity_factor >= E/topk every token fits (no drops):
        uniform routing must preserve ~all tokens' outputs vs huge capacity."""
        from repro.models import moe

        cfg = configs.get_config("olmoe-1b-7b", smoke=True).replace(capacity_factor=8.0)
        params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
        y1 = moe.moe_ffn(cfg, params, x)
        y2 = moe.moe_ffn(cfg.replace(capacity_factor=64.0), params, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)
