"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, T_enc, d_model).  The transformer
backbone (bidirectional encoder, causal decoder with cross-attention) is
real.  Norms are RMS (documented deviation: parameter-count and roofline
neutral vs. LayerNorm); positions are absolute embeddings (no RoPE).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .common import ModelConfig, ParamSpec
from .transformer import _norm, stack_specs


def _xattn_specs(cfg: ModelConfig) -> dict:
    D, Hq, Hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = cfg.param_dtype
    return {
        "wq": ParamSpec((D, Hq, dh), ("embed", "heads", None), dtype=pd),
        "wk": ParamSpec((D, Hkv, dh), ("embed", "kv_heads", None), dtype=pd),
        "wv": ParamSpec((D, Hkv, dh), ("embed", "kv_heads", None), dtype=pd),
        "wo": ParamSpec((Hq, dh, D), ("heads", None, "embed"), dtype=pd),
    }


def _enc_block_specs(cfg):
    return {
        "ln1": L.norm_spec(cfg),
        "attn": L.attn_specs(cfg),
        "ln2": L.norm_spec(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def _dec_block_specs(cfg):
    return {
        "ln1": L.norm_spec(cfg),
        "attn": L.attn_specs(cfg),
        "ln_x": L.norm_spec(cfg),
        "xattn": _xattn_specs(cfg),
        "ln2": L.norm_spec(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def param_specs(cfg: ModelConfig) -> dict:
    pd = cfg.param_dtype
    return {
        "embed": L.embed_specs(cfg),
        # learned decoder positions; sized for the largest decode cell (32k+1)
        "dec_pos": ParamSpec((36864, cfg.d_model), (None, "embed"), scale=0.02, dtype=pd),
        "enc_layers": stack_specs(_enc_block_specs(cfg), cfg.enc_layers),
        "enc_norm": L.norm_spec(cfg),
        "dec_layers": stack_specs(_dec_block_specs(cfg), cfg.num_layers),
        "final_norm": L.norm_spec(cfg),
    }


def _sinusoid(T: int, D: int):
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None]
    ang = pos / (10000.0 ** (2 * dim / D))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(cfg: ModelConfig, params, frame_embeds):
    """frame_embeds: (B, T_enc, D) from the stubbed frontend."""
    cd = cfg.compute_dtype
    B, T, D = frame_embeds.shape
    x = frame_embeds.astype(cd) + _sinusoid(T, D).astype(cd)[None]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(x, p):
        h = L.attention(cfg, p["attn"], _norm(cfg, x, p["ln1"]), positions, None, causal=False)
        x = x + h
        return x + L.mlp(cfg, p["mlp"], _norm(cfg, x, p["ln2"])), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
    return _norm(cfg, x, params["enc_norm"])


def _cross_attention(cfg, p, x, enc_kv):
    """x: (B, S, D) decoder side; enc_kv: (k, v) each (B, T, Hkv, dh)."""
    cd = cfg.compute_dtype
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    q = q.reshape(B, S, cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim)
    k, v = enc_kv
    s = jnp.einsum("bqhgk,bthk->bhgqt", q, k.astype(cd)) * L._scale(cfg)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(cd)
    out = jnp.einsum("bhgqt,bthk->bqhgk", w, v.astype(cd))
    out = out.reshape(B, S, cfg.num_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))


def _enc_kv(cfg, p, enc_out):
    cd = cfg.compute_dtype
    k = jnp.einsum("btd,dhk->bthk", enc_out.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("btd,dhk->bthk", enc_out.astype(cd), p["wv"].astype(cd))
    return k, v


def _decoder(cfg, params, tokens, enc_out, start_pos=0):
    cd = cfg.compute_dtype
    B, S = tokens.shape
    x = L.embed(cfg, params["embed"], tokens)
    pos_ids = start_pos + jnp.arange(S, dtype=jnp.int32)
    x = x + params["dec_pos"][pos_ids].astype(cd)[None]
    positions = jnp.broadcast_to(pos_ids[None], (B, S))

    def body(x, p):
        x = x + L.attention(cfg, p["attn"], _norm(cfg, x, p["ln1"]), positions, None)
        kv = _enc_kv(cfg, p["xattn"], enc_out)
        x = x + _cross_attention(cfg, p["xattn"], _norm(cfg, x, p["ln_x"]), kv)
        return x + L.mlp(cfg, p["mlp"], _norm(cfg, x, p["ln2"])), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
    return _norm(cfg, x, params["final_norm"])


def train_nll(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["frame_embeds"])
    x = _decoder(cfg, params, batch["tokens"], enc_out)
    return L.chunked_xent(cfg, params["embed"], x, batch["labels"], batch.get("mask"))


def make_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype, abstract: bool = False):
    Hkv, dh, T = cfg.num_kv_heads, cfg.head_dim, cfg.enc_seq
    Ld = cfg.num_layers
    self_shape = (Ld, batch, max_seq, Hkv, dh)
    cross_shape = (Ld, batch, T, Hkv, dh)
    mk = (lambda s: jax.ShapeDtypeStruct(s, dtype)) if abstract else (lambda s: jnp.zeros(s, dtype))
    return {
        "self_k": mk(self_shape),
        "self_v": mk(self_shape),
        "cross_k": mk(cross_shape),
        "cross_v": mk(cross_shape),
        "t": jax.ShapeDtypeStruct((), jnp.int32) if abstract else jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg: ModelConfig):
    kv = (None, "batch", "kvseq", "kv_heads", None)
    return {"self_k": kv, "self_v": kv, "cross_k": kv, "cross_v": kv, "t": ()}


def prefill(cfg: ModelConfig, params, batch, max_seq: int, cache_dtype=None):
    """Encode audio + run the decoder prompt; build self+cross caches."""
    dt = cache_dtype or cfg.compute_dtype
    enc_out = encode(cfg, params, batch["frame_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = make_cache(cfg, B, max_seq, dt)

    cd = cfg.compute_dtype
    x = L.embed(cfg, params["embed"], tokens)
    pos_ids = jnp.arange(S, dtype=jnp.int32)
    x = x + params["dec_pos"][pos_ids].astype(cd)[None]
    positions = jnp.broadcast_to(pos_ids[None], (B, S))

    def body(carry, p):
        x = carry
        xin = _norm(cfg, x, p["ln1"])
        _, k, v = L._qk(cfg, p["attn"], xin, positions)
        x = x + L.attention(cfg, p["attn"], xin, positions, None)
        ck, cv = _enc_kv(cfg, p["xattn"], enc_out)
        x = x + _cross_attention(cfg, p["xattn"], _norm(cfg, x, p["ln_x"]), (ck, cv))
        x = x + L.mlp(cfg, p["mlp"], _norm(cfg, x, p["ln2"]))
        return x, (k.astype(dt), v.astype(dt), ck.astype(dt), cv.astype(dt))

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"])
    cache["self_k"] = jax.lax.dynamic_update_slice_in_dim(cache["self_k"], ks, 0, axis=2)
    cache["self_v"] = jax.lax.dynamic_update_slice_in_dim(cache["self_v"], vs, 0, axis=2)
    cache["cross_k"], cache["cross_v"] = cks, cvs
    cache["t"] = jnp.asarray(S, jnp.int32)
    x = _norm(cfg, x, params["final_norm"])
    return L.final_logits(cfg, params["embed"], x[:, -1:]), cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    cd = cfg.compute_dtype
    t = cache["t"]
    B = tokens.shape[0]
    x = L.embed(cfg, params["embed"], tokens)
    x = x + params["dec_pos"][t][None, None].astype(cd)
    Hkv, G, dh = cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    S_max = cache["self_k"].shape[2]
    valid = jnp.arange(S_max) <= t

    def body(carry, xs):
        x = carry
        p, sk, sv, ck, cv = xs
        xin = _norm(cfg, x, p["ln1"])
        pos = jnp.full((B, 1), t, jnp.int32)
        q, k, v = L._qk(cfg, p["attn"], xin, pos)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k.astype(sk.dtype), t, axis=1)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v.astype(sv.dtype), t, axis=1)
        s = jnp.einsum("bqhgk,bthk->bhgqt", q.astype(cd), sk.astype(cd)) * L._scale(cfg)
        s = jnp.where(valid[None, None, None, None, :], s.astype(jnp.float32), -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(cd)
        out = jnp.einsum("bhgqt,bthk->bqhgk", w, sv.astype(cd)).reshape(B, 1, cfg.num_heads, dh)
        x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(cd))
        x = x + _cross_attention(cfg, p["xattn"], _norm(cfg, x, p["ln_x"]), (ck, cv))
        x = x + L.mlp(cfg, p["mlp"], _norm(cfg, x, p["ln2"]))
        return x, (sk, sv)

    x, (new_sk, new_sv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self_k"], cache["self_v"], cache["cross_k"], cache["cross_v"])
    )
    new_cache = dict(cache, self_k=new_sk, self_v=new_sv, t=t + 1)
    x = _norm(cfg, x, params["final_norm"])
    return L.final_logits(cfg, params["embed"], x), new_cache
