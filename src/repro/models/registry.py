"""Model registry: family -> ModelDef (the uniform model interface)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from . import encdec, transformer
from .common import ModelConfig


class ModelDef(NamedTuple):
    param_specs: Callable  # (cfg) -> spec tree
    train_nll: Callable  # (cfg, params, batch) -> (sum_nll, count)
    prefill: Callable  # (cfg, params, batch, max_seq, cache_dtype) -> (logits, cache)
    decode_step: Callable  # (cfg, params, cache, tokens) -> (logits, cache)
    make_cache: Callable  # (cfg, batch, max_seq, dtype, abstract) -> cache
    cache_axes: Callable  # (cfg) -> logical-axis tree matching make_cache


_LM = ModelDef(
    param_specs=transformer.param_specs,
    train_nll=transformer.train_nll,
    prefill=transformer.prefill,
    decode_step=transformer.decode_step,
    make_cache=transformer.make_cache,
    cache_axes=transformer.cache_axes,
)

_ENCDEC = ModelDef(
    param_specs=encdec.param_specs,
    train_nll=encdec.train_nll,
    prefill=encdec.prefill,
    decode_step=encdec.decode_step,
    make_cache=encdec.make_cache,
    cache_axes=encdec.cache_axes,
)


def get_model(cfg: ModelConfig) -> ModelDef:
    return _ENCDEC if cfg.family == "audio" else _LM
