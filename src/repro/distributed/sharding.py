"""Logical-axis sharding: ParamSpec axes -> PartitionSpec via rule tables.

A *rule table* maps logical axis names ("embed", "heads", "vocab", ...) to a
mesh axis (or tuple of mesh axes).  ``build_spec`` resolves one tensor:
mesh axes are granted in PRIORITY order (so e.g. "kv_heads" gets "model"
before a sequence dim can claim it), each mesh axis is used at most once per
tensor, and any assignment that does not divide the dim evenly is dropped
(falls back to replication) — this is what makes one rule table work across
all 10 architectures (whisper's 8 kv-heads simply refuse a 16-way axis).
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

# resolution priority: parameter-ish dims first, then batch, then sequence
_PRIORITY = (
    "chain",
    "expert",
    "kv_heads",
    "heads",
    "vocab",
    "mlp",
    "mlp2",
    "rnn",
    "embed",
    "batch",
    "kvseq",
    "seq",
)


def _axes_tuple(rule) -> tuple:
    if rule is None:
        return ()
    return tuple(rule) if isinstance(rule, (tuple, list)) else (rule,)


def build_spec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: Mapping[str, object],
    mesh: jax.sharding.Mesh,
) -> PartitionSpec:
    assert len(shape) == len(axes), (shape, axes)
    entries: list = [None] * len(shape)
    used: set = set()
    order = sorted(
        range(len(axes)),
        key=lambda i: _PRIORITY.index(axes[i]) if axes[i] in _PRIORITY else len(_PRIORITY),
    )
    for i in order:
        name = axes[i]
        if name is None or name not in rules:
            continue
        grant = []
        size = 1
        for mx in _axes_tuple(rules[name]):
            if mx in used or mx not in mesh.shape:
                continue
            if shape[i] % (size * mesh.shape[mx]) != 0:
                continue
            grant.append(mx)
            size *= mesh.shape[mx]
        if grant:
            entries[i] = tuple(grant) if len(grant) > 1 else grant[0]
            used.update(grant)
    return PartitionSpec(*entries)


def tree_specs(axes_tree, shapes_tree, rules, mesh):
    """PartitionSpec pytree for matching (axes, shapes) trees."""
    return jax.tree.map(
        lambda ax, sh: build_spec(sh.shape, ax, rules, mesh),
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def tree_shardings(axes_tree, shapes_tree, rules, mesh):
    specs = tree_specs(axes_tree, shapes_tree, rules, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def chain_specs(tree, num_chains: int, axis_name: str = "chain"):
    """PartitionSpec pytree for the executor's shard_map chain routing
    (DESIGN.md §2): leaves whose LEADING dim equals ``num_chains`` shard
    that dim over ``axis_name``; everything else (center variables, step
    counters, scalars) is replicated.

    This shape heuristic is exactly the repo's SPMD layout contract: chain
    state mirrors params with a leading K axis, center state carries none.
    Callers with a K-sized non-chain leading dim must pass explicit specs
    instead."""
    def spec(x):
        shape = tuple(getattr(x, "shape", ()))
        if len(shape) >= 1 and shape[0] == num_chains:
            return PartitionSpec(axis_name)
        return PartitionSpec()

    return jax.tree.map(spec, tree)


def leading_axes_specs(tree, axes: Sequence[Optional[str]], mesh):
    """PartitionSpec pytree granting ``axes[i]`` to every leaf's i-th
    LEADING dim when the axis exists on the mesh and divides the dim evenly
    (else that dim replicates).  This is the serving engine's layout rule
    (DESIGN.md §7): pooled caches are (member, slot, ...), slot masks are
    (slot, ...), member stacks are (member, ...) — the leading dims ARE the
    parallel axes, no logical-axis table needed."""

    def spec(x):
        shape = tuple(getattr(x, "shape", ()))
        entries = []
        for i, name in enumerate(axes):
            if i >= len(shape):
                break
            ok = name is not None and name in mesh.shape and shape[i] % mesh.shape[name] == 0
            entries.append(name if ok else None)
        return PartitionSpec(*entries)

    return jax.tree.map(spec, tree)


def leading_axes_shardings(tree, axes, mesh):
    """:func:`leading_axes_specs` as NamedSharding (device_put-ready)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        leading_axes_specs(tree, axes, mesh),
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------


def train_param_rules(mesh, pure_dp: bool = False, fsdp: bool = True, style: str = "tp_fsdp"):
    """Chain-stacked params.

    styles:
      tp_fsdp — TP over `model` + FSDP over `data` (megatron-style; baseline)
      fsdp2d  — params sharded over (data, model) on the embed dim, NO tensor
                parallelism: weights all-gather per layer, activations never
                all-reduce (MaxText-style; activations shard batch over both
                axes). The §Perf hillclimb winner for activation-AR-bound
                cells.
      dp      — pure data parallel (params replicated).
    """
    chain_axes = tuple(a for a in ("pod", "chain") if a in mesh.shape)
    if pure_dp or style == "dp":
        return {"chain": chain_axes}
    if style == "fsdp2d":
        return {"chain": chain_axes, "embed": ("data", "model")}
    rules = {
        "chain": chain_axes,
        "vocab": "model",
        "mlp": "model",
        "mlp2": "model",
        "heads": "model",
        "kv_heads": "model",
        "expert": "model",
        "rnn": "model",
    }
    if fsdp:
        rules["embed"] = "data"
    return rules


def center_rules(mesh, pure_dp: bool = False):
    """Center variables (c, r, c̃, m̃θ) have no chain axis — they shard over
    the ENTIRE mesh (chain/pod axes fold into the FSDP axis)."""
    full_data = tuple(a for a in ("pod", "chain", "data") if a in mesh.shape)
    if pure_dp:
        return {"vocab": full_data, "embed": "model", "mlp": "model"}
    return {
        "vocab": "model",
        "mlp": "model",
        "mlp2": "model",
        "heads": "model",
        "kv_heads": "model",
        "expert": "model",
        "rnn": "model",
        "embed": full_data,
    }


def serve_param_rules(mesh, fsdp: bool = False, pure_dp: bool = False, style: str = "tp_fsdp"):
    if pure_dp or style == "dp":
        return {}
    if style == "fsdp2d":
        # weights sharded across the whole mesh on the embed dim; gathered
        # per layer at use; no tensor-parallel activation all-reduces.
        return {"embed": tuple(a for a in ("pod", "data", "model") if a in mesh.shape)}
    rules = {
        "vocab": "model",
        "mlp": "model",
        "mlp2": "model",
        "heads": "model",
        "kv_heads": "model",
        "expert": "model",
        "rnn": "model",
    }
    if fsdp:
        rules["embed"] = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return rules


def batch_rules(mesh, pure_dp: bool = False, style: str = "tp_fsdp"):
    chain_axes = tuple(a for a in ("pod", "chain") if a in mesh.shape)
    # without tensor parallelism the model axis is free for batch rows
    wide = pure_dp or style in ("fsdp2d", "dp")
    data_axes = ("data", "model") if wide else ("data",)
    return {
        "chain": chain_axes,
        "batch": data_axes,
        # sequence dims pick up whatever is left (long_500k: B=1)
        "kvseq": ("data", "model") if not wide else ("data",),
        "seq": (),
    }


def serve_batch_rules(mesh):
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return {
        "batch": data_axes,
        "kv_heads": "model",
        "heads": "model",
        "rnn": "model",
        "kvseq": data_axes + ("model",),  # claims leftovers (B=1 long-context)
        "embed": (),
        "vocab": "model",
        "mlp": "model",
    }
