"""whisper-base [audio]: 6L (enc+dec) d_model=512 8H d_ff=2048 vocab=51865 —
encoder-decoder; conv/mel frontend STUBBED (input_specs provides frame
embeddings). [arXiv:2212.04356; unverified]"""
import jax.numpy as jnp

from repro.models.common import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    vocab_size=51865,
    d_model=512,
    num_layers=6,  # decoder layers
    enc_layers=6,
    enc_seq=1500,  # mel frames after the (stubbed) conv frontend
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    pattern=(LayerKind("attn"),),
    act="gelu",
    mlp_gated=False,
    use_rope=False,  # absolute position embeddings
    tie_embeddings=True,
    param_dtype=jnp.float32,
    compute_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(
    vocab_size=512,
    d_model=64,
    num_layers=2,
    enc_layers=2,
    enc_seq=32,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    compute_dtype=jnp.float32,
    xent_chunk=16,
)
