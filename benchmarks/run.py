"""Benchmark harness — one module per paper figure/table + system benches.
Prints ``name,us_per_call,derived`` CSV and writes a machine-readable
``BENCH_<name>.json`` per bench (rows + structured extras + config) so the
perf trajectory is tracked across PRs.  REPRO_BENCH_QUICK=0 for the full
paper-scale configurations (QUICK keeps the CPU-only run in minutes).

  PYTHONPATH=src python -m benchmarks.run [--bench fig1_toy ...]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

ROOT = Path(__file__).resolve().parent.parent

BENCHES = {
    "fig1_toy": "fig1_toy_gaussian",  # paper Fig. 1
    "fig2_mlp": "fig2_mnist_mlp",  # paper Fig. 2 left
    "fig2_resnet": "fig2_cifar_resnet",  # paper Fig. 2 right
    "staleness": "staleness_sweep",  # paper §2 analysis
    "overhead": "sampler_overhead",  # sampler hot-loop + executor + fused kernel
    "roofline": "roofline",  # deliverable (g), reads dry-run artifacts
    "serve": "serve_engine",  # continuous-batching BMA engine latency/throughput
    "adaptive": "adaptive_tier",  # preconditioned vs plain ESS/sec + FeedbackESS demo
    "shard": "shard_sweep",  # multi-device scale-out: steps/s + sync wire-bytes
}

# historical artifact names (ISSUE 4): fig1_toy -> BENCH_fig1.json
JSON_NAMES = {"fig1_toy": "fig1"}


def _config() -> dict:
    import jax

    import common

    return {
        "quick": common.QUICK,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax": jax.__version__,
        "python": platform.python_version(),
    }


def _write_json(name: str, extra, seconds: float) -> None:
    import common

    payload = {
        "bench": name,
        "config": _config(),
        "manifest": common.manifest(),
        "wall_s": round(seconds, 2),
        "rows": list(common.ROWS),
        **{k: v for k, v in common.EXTRAS.items()},
    }
    if isinstance(extra, dict):
        payload["summary"] = {
            k: v for k, v in extra.items() if isinstance(v, (int, float, str, bool))
        }
    path = ROOT / f"BENCH_{JSON_NAMES.get(name, name)}.json"
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    print(f"# wrote {path.name}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_names", nargs="*", metavar="bench",
                    help=f"positional bench names (same set as --bench): {', '.join(BENCHES)}")
    ap.add_argument("--bench", nargs="*", default=None, choices=list(BENCHES))
    ap.add_argument("--no-json", action="store_true", help="skip BENCH_*.json artifacts")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Perfetto trace.json covering every bench run")
    args = ap.parse_args(argv)
    tracer = None
    if args.trace:
        sys.path.insert(0, str(ROOT / "src"))
        from repro import obs

        tracer = obs.enable_tracing()
    unknown = [b for b in args.bench_names if b not in BENCHES]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; choose from {list(BENCHES)}")
    benches = ((args.bench or []) + args.bench_names) or list(BENCHES)
    print("name,us_per_call,derived")
    failures = []
    for name in benches:
        mod_name = BENCHES[name]
        t0 = time.time()
        try:
            import common

            common.reset_records()
            mod = __import__(mod_name)
            extra = mod.run()
            dt = time.time() - t0
            if not args.no_json:
                _write_json(name, extra, dt)
            print(f"# {name} done in {dt:.1f}s", flush=True)
        except Exception as e:
            failures.append(name)
            print(f"# {name} FAILED: {e!r}", flush=True)
            traceback.print_exc()
    if tracer is not None:
        tracer.export(args.trace)
        print(f"# trace written to {args.trace} ({len(tracer)} events)", flush=True)
    if failures:
        sys.exit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
