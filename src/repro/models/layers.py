"""Shared neural-net layers: norms, RoPE/M-RoPE, GQA attention (full /
sliding-window / softcap / qk-norm) with KV-cache decode, gated MLPs,
embeddings, and seq-chunked cross-entropy.

All functions are pure; params are plain dicts built from ParamSpecs.
Compute happens in ``cfg.compute_dtype``; reductions in f32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .common import LayerKind, ModelConfig, ParamSpec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float, offset: float = 0.0):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return ((offset + w.astype(jnp.float32)) * x32 * inv).astype(dt)


def norm_spec(cfg: ModelConfig, dim=None) -> ParamSpec:
    init = "zeros" if cfg.norm_scale_offset else "ones"
    return ParamSpec((dim or cfg.d_model,), ("embed",), init=init, dtype=cfg.param_dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)  # (half,)


def apply_rope(x, positions, theta: float, mrope_sections=None):
    """x: (..., S, H, dh). positions: (B, S) int or (3, B, S) for M-RoPE."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    if mrope_sections is None:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,dh/2)
    else:
        # M-RoPE: frequency dims split into sections, each driven by its own
        # position stream (temporal, height, width).
        assert positions.ndim == 3, "M-RoPE needs positions (3, B, S)"
        parts = []
        start = 0
        for i, sec in enumerate(mrope_sections):
            f = freqs[start : start + sec]
            parts.append(positions[i][..., None].astype(jnp.float32) * f)
            start += sec
        angles = jnp.concatenate(parts, axis=-1)  # (B,S,dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (B,S,1,dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; full / sliding-window; softcap; qk-norm; cache decode)
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> dict:
    D, Hq, Hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = cfg.param_dtype
    specs = {
        "wq": ParamSpec((D, Hq, dh), ("embed", "heads", None), dtype=pd),
        "wk": ParamSpec((D, Hkv, dh), ("embed", "kv_heads", None), dtype=pd),
        "wv": ParamSpec((D, Hkv, dh), ("embed", "kv_heads", None), dtype=pd),
        "wo": ParamSpec((Hq, dh, D), ("heads", None, "embed"), dtype=pd),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((dh,), (None,), init="ones", dtype=pd)
        specs["k_norm"] = ParamSpec((dh,), (None,), init="ones", dtype=pd)
    return specs


def _softcap(scores, cap: Optional[float]):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _qk(cfg: ModelConfig, p, x, positions):
    """Project + rope; returns q (B,S,Hkv,G,dh), k/v (B,S,Hkv,dh)."""
    cd = cfg.compute_dtype
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wv"].astype(cd))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    q = q.reshape(B, S, cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim)
    return q, k, v


def _scale(cfg: ModelConfig):
    return cfg.query_scale if cfg.query_scale is not None else 1.0 / math.sqrt(cfg.head_dim)


def attention(
    cfg: ModelConfig,
    p,
    x,
    positions,
    window: Optional[int],
    q_chunk: int = 1024,
    causal: bool = True,
):
    """Training/prefill attention, chunked over query blocks so the (S, S)
    score matrix is never materialized (peak: (B, q_chunk, Hq, S)).
    Causal by default; optionally sliding-window (q_pos - k_pos < window)."""
    cd = cfg.compute_dtype
    B, S, _ = x.shape
    q, k, v = _qk(cfg, p, x, positions)
    scale = _scale(cfg)
    # flash path assumes contiguous arange positions (block-index masking):
    # M-RoPE / custom-position batches stay on the chunked path.
    if cfg.use_flash_kernel and causal and cfg.mrope_sections is None and S % min(128, S) == 0:
        from repro.kernels.ops import flash_attention as _flash

        qf = jnp.moveaxis(q.reshape(B, S, cfg.num_heads, cfg.head_dim), 1, 2)
        out = _flash(
            qf, jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
            causal=True, window=window, softcap=cfg.attn_logit_softcap,
            scale=scale, block_q=min(128, S), block_k=min(128, S),
        )
        out = jnp.moveaxis(out, 1, 2)
        return jnp.einsum("bshk,hkd->bsd", out.astype(cd), p["wo"].astype(cd))
    q_chunk = min(q_chunk, S)
    while S % q_chunk:  # largest divisor of S (e.g. whisper's 1500 frames)
        q_chunk -= 1
    n_chunks = S // q_chunk
    kpos = positions if positions.ndim == 2 else positions[0]  # (B,S)

    def one_chunk(c):
        qs = jax.lax.dynamic_slice_in_dim(q, c * q_chunk, q_chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(kpos, c * q_chunk, q_chunk, axis=1)
        s = jnp.einsum("bqhgk,bthk->bhgqt", qs.astype(cd), k.astype(cd)) * scale
        s = _softcap(s.astype(jnp.float32), cfg.attn_logit_softcap)
        mask = jnp.ones((B, q_chunk, S), bool)
        if causal:
            mask &= qp[:, :, None] >= kpos[:, None, :]  # (B,q,t)
        if window is not None:
            mask &= (qp[:, :, None] - kpos[:, None, :]) < window
        s = jnp.where(mask[:, None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(cd)
        return jnp.einsum("bhgqt,bthk->bqhgk", w, v.astype(cd))

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        outs = jax.lax.map(one_chunk, jnp.arange(n_chunks))  # (n,B,q,Hkv,G,dh)
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim)
    out = out.reshape(B, S, cfg.num_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out.astype(cd), p["wo"].astype(cd))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, window: Optional[int], dtype):
    """KV cache for one attention layer. Windowed layers use a ring buffer of
    length `window` — decisive for long_500k memory."""
    L = min(window, max_seq) if window else max_seq
    shape = (batch, L, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int, window: Optional[int], dtype):
    L = min(window, max_seq) if window else max_seq
    shape = (batch, L, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def decode_attention(cfg: ModelConfig, p, x, cache, t, window: Optional[int]):
    """Single-token decode. x: (B, 1, D); t: scalar current position.
    Returns (out (B,1,D), new_cache)."""
    cd = cfg.compute_dtype
    B = x.shape[0]
    pos = jnp.full((B, 1), t, jnp.int32)
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    q, k, v = _qk(cfg, p, x, pos)
    L = cache["k"].shape[1]
    slot = (t % L).astype(jnp.int32) if window else t.astype(jnp.int32)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    # slot j holds absolute position: full cache -> j; ring -> t - ((t - j) mod L)
    j = jnp.arange(L)
    if window:
        kpos = t - ((t - j) % L)
    else:
        kpos = j
    valid = (kpos >= 0) & (kpos <= t)
    s = jnp.einsum("bqhgk,bthk->bhgqt", q.astype(cd), new_k.astype(cd)) * _scale(cfg)
    s = _softcap(s.astype(jnp.float32), cfg.attn_logit_softcap)
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(cd)
    out = jnp.einsum("bhgqt,bthk->bqhgk", w, new_v.astype(cd))
    out = out.reshape(B, 1, cfg.num_heads, cfg.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(cd), p["wo"].astype(cd))
    return out, {"k": new_k, "v": new_v}


def paged_decode_attention(cfg: ModelConfig, p, x, pool, block_tables, context_lens, write_block):
    """Single-token decode against a block-paged KV pool (DESIGN.md §8).

    x: (S, 1, D) — every engine slot jointly (the pool is shared, so slots
    cannot be vmapped the way dense slot caches are).  pool: {"k","v"} of
    (num_pages, bs, Hkv, dh); block_tables (S, M) int32; context_lens (S,)
    int32 current positions; write_block (S,) int32 destination page for
    this step's k/v (page 0 is the sink — done/free slots write there and
    nothing ever reads it).  Returns (out (S, 1, D), new pool).

    Numerics mirror :func:`decode_attention` exactly — einsums in
    ``compute_dtype``, softcap/softmax in f32, -1e30 masking — so paged vs
    dense equivalence holds at f32-roundoff tolerance."""
    cd = cfg.compute_dtype
    S = x.shape[0]
    pos = context_lens[:, None].astype(jnp.int32)  # (S, 1)
    q, k, v = _qk(cfg, p, x, pos)  # q (S,1,Hkv,G,dh), k/v (S,1,Hkv,dh)
    bs = pool["k"].shape[1]
    off = (context_lens % bs).astype(jnp.int32)
    new_k = pool["k"].at[write_block, off].set(k[:, 0].astype(pool["k"].dtype))
    new_v = pool["v"].at[write_block, off].set(v[:, 0].astype(pool["v"].dtype))
    window = None  # paged pools are non-windowed (guarded at pool creation)
    if cfg.use_flash_kernel and cfg.mrope_sections is None:
        from repro.kernels.ops import paged_attention as _paged

        out = _paged(
            q[:, 0], new_k, new_v, block_tables, context_lens,
            scale=_scale(cfg), window=window, softcap=cfg.attn_logit_softcap,
        )[:, None]  # (S, 1, Hkv, G, dh)
    else:
        M = block_tables.shape[1]
        kd = new_k[block_tables].reshape(S, M * bs, cfg.num_kv_heads, cfg.head_dim)
        vd = new_v[block_tables].reshape(S, M * bs, cfg.num_kv_heads, cfg.head_dim)
        kpos = jnp.arange(M * bs)[None, :]
        valid = kpos <= context_lens[:, None]
        s = jnp.einsum("bqhgk,bthk->bhgqt", q.astype(cd), kd.astype(cd)) * _scale(cfg)
        s = _softcap(s.astype(jnp.float32), cfg.attn_logit_softcap)
        s = jnp.where(valid[:, None, None, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(cd)
        out = jnp.einsum("bhgqt,bthk->bqhgk", w, vd.astype(cd))
    out = out.reshape(S, 1, cfg.num_heads, cfg.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(cd), p["wo"].astype(cd))
    return out, {"k": new_k, "v": new_v}


def init_page_pool(cfg: ModelConfig, num_pages: int, block_size: int, dtype):
    """Paged KV pool for one attention layer: a flat page array shared by
    every sequence, indexed through per-sequence block tables."""
    shape = (num_pages, block_size, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def page_pool_specs(cfg: ModelConfig, num_pages: int, block_size: int, dtype):
    shape = (num_pages, block_size, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_specs(cfg: ModelConfig, d_ff=None) -> dict:
    D, F, pd = cfg.d_model, d_ff or cfg.d_ff, cfg.param_dtype
    specs = {
        "w_up": ParamSpec((D, F), ("embed", "mlp"), dtype=pd),
        "w_down": ParamSpec((F, D), ("mlp", "embed"), dtype=pd),
    }
    if cfg.mlp_gated:
        specs["w_gate"] = ParamSpec((D, F), ("embed", "mlp"), dtype=pd)
    return specs


def mlp(cfg: ModelConfig, p, x):
    cd = cfg.compute_dtype
    act = _ACTS[cfg.act]
    if cfg.mlp_gated:
        h = act(x.astype(cd) @ p["w_gate"].astype(cd)) * (x.astype(cd) @ p["w_up"].astype(cd))
    else:
        h = act(x.astype(cd) @ p["w_up"].astype(cd))
    return h @ p["w_down"].astype(cd)


# ---------------------------------------------------------------------------
# Embeddings + chunked cross-entropy
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> dict:
    pd = cfg.param_dtype
    specs = {
        "table": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=0.02, dtype=pd)
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dtype=pd
        )
    return specs


def embed(cfg: ModelConfig, p, tokens):
    if cfg.embed_onehot:
        # TP-friendly lookup: contraction over the (sharded) vocab dim is a
        # local matmul + psum; the gather form all-gathers the whole table.
        oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=cfg.compute_dtype)
        x = oh @ p["table"].astype(cfg.compute_dtype)
    else:
        x = jnp.take(p["table"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.embed_scale == "sqrt_d":
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)
    return x


def _logits_chunk(cfg: ModelConfig, p, x):
    cd = cfg.compute_dtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(cd), p["table"].astype(cd))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x.astype(cd), p["unembed"].astype(cd))
    return _softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


def chunked_xent(cfg: ModelConfig, p, x, labels, mask=None):
    """sum_t NLL(labels_t), scanning over sequence chunks so the full
    (B, S, V) logits tensor never exists. Returns (sum_nll, token_count)."""
    B, S, D = x.shape
    C = min(cfg.xent_chunk, S)
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    if S % C:  # pad to a chunk multiple; padded positions masked out
        pad = C - S % C
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S += pad
    n = S // C

    def body(acc, c):
        xs = jax.lax.dynamic_slice_in_dim(x, c * C, C, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, c * C, C, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, c * C, C, axis=1)
        logits = _logits_chunk(cfg, p, xs)  # (B,C,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ms
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(ms)), None

    (sum_nll, count), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), jnp.arange(n))
    return sum_nll, count


def final_logits(cfg: ModelConfig, p, x_last):
    """Logits for the last position only: x_last (B, 1, D) -> (B, 1, V)."""
    return _logits_chunk(cfg, p, x_last)
