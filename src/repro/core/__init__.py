"""The paper's primary contribution: SG-MCMC samplers with elastic coupling,
as composable optax-style transforms over (possibly chain-stacked) pytrees.
"""
from .types import Sampler
from .tree_util import (
    apply_updates,
    count_params,
    global_norm,
    tree_broadcast_axis0,
    tree_cast,
    tree_mean_axis0,
    tree_random_normal,
    tree_random_normal_per_chain,
)
from .schedules import (
    FeedbackESS,
    as_schedule,
    constant,
    cosine,
    feedback_ess,
    polynomial_decay,
    warmup_cosine,
)
from .sghmc import SGHMCState, sghmc
from .sgld import SGLDState, sgld
from .ec_sghmc import ECSGHMCState, ec_sghmc, resample_chain_from_center
from .ec_sgld import ECSGLDState, ec_sgld
from .async_sghmc import AsyncSGHMCState, async_sghmc
from .easgd import EAMSGDState, EASGDState, ECMSGDState, eamsgd, easgd, ec_msgd
from .potential import Potential, chainwise, flat_prior, gaussian_prior, make_potential
from .preconditioner import (
    PrecondState,
    adam_preconditioner,
    frozen_mass_inv,
    get_preconditioner,
    rmsprop_preconditioner,
)
from .preconditioned_sgld import PSGLDState, preconditioned_sgld
from .scale_adapted import (
    ScaleAdaptedECState,
    ScaleAdaptedState,
    scale_adapted_ec_sghmc,
    scale_adapted_sghmc,
)
from . import recipe

__all__ = [
    "Sampler",
    "apply_updates",
    "count_params",
    "global_norm",
    "tree_broadcast_axis0",
    "tree_cast",
    "tree_mean_axis0",
    "tree_random_normal",
    "tree_random_normal_per_chain",
    "FeedbackESS",
    "as_schedule",
    "constant",
    "cosine",
    "feedback_ess",
    "polynomial_decay",
    "warmup_cosine",
    "SGHMCState",
    "sghmc",
    "SGLDState",
    "sgld",
    "ECSGHMCState",
    "ec_sghmc",
    "resample_chain_from_center",
    "ECSGLDState",
    "ec_sgld",
    "AsyncSGHMCState",
    "async_sghmc",
    "EASGDState",
    "EAMSGDState",
    "ECMSGDState",
    "easgd",
    "eamsgd",
    "ec_msgd",
    "Potential",
    "chainwise",
    "flat_prior",
    "gaussian_prior",
    "make_potential",
    "PrecondState",
    "adam_preconditioner",
    "frozen_mass_inv",
    "get_preconditioner",
    "rmsprop_preconditioner",
    "PSGLDState",
    "preconditioned_sgld",
    "ScaleAdaptedECState",
    "ScaleAdaptedState",
    "scale_adapted_ec_sghmc",
    "scale_adapted_sghmc",
    "recipe",
]
