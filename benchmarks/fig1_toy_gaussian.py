"""Paper Fig. 1: first sampling steps on a 2-D Gaussian (alpha=1, eps=1e-2,
C=V=I, K=4, all samplers from the same initial guess).

What the figure actually shows (and what we quantify):
  (1) independent SGHMC runs take erratic initial paths — "depending on the
      noise it can happen that SGHMC only explores low-density regions in
      its first steps (cf. purple curve)".  Metric: WORST-case mean NLL
      across independent runs.
  (2) the elastically coupled chains "quickly sample from high density
      regions and show coherent behaviour".  Metrics: worst-case mean NLL
      across chains, and cross-chain spread (coherence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro import diagnostics as diag

from common import emit, time_fn

MU = jnp.array([2.0, -1.0])
STEPS = 600
K = 4
N_RUNS = 8  # independent SGHMC seeds (the paper's two, statistically robust)


def grad_U(theta):
    return theta - MU


def nll(x):
    return 0.5 * np.sum((np.asarray(x) - np.asarray(MU)) ** 2, axis=-1)


def _run(sampler, params, seed=0):
    state = sampler.init(params)

    def body(carry, key):
        p, st = carry
        upd, st = sampler.update(grad_U(p), st, params=p, rng=key)
        p = core.apply_updates(p, upd)
        return (p, st), p

    keys = jax.random.split(jax.random.PRNGKey(seed), STEPS)
    (_, _), traj = jax.lax.scan(body, (params, state), keys)
    return np.asarray(traj)


def run():
    start = jnp.array([-2.0, 3.0])
    sg = core.sghmc(step_size=1e-2, friction=1.0)
    t_sg = np.stack([_run(sg, start, seed=s) for s in range(N_RUNS)])  # (R,S,2)

    ec = core.ec_sghmc(step_size=1e-2, alpha=1.0, friction=1.0, center_friction=1.0,
                       sync_every=1, noise_convention="eq6")
    t_ec = np.stack(
        [_run(ec, jnp.broadcast_to(start[None], (K, 2)), seed=100 + s) for s in range(2)]
    )  # (2, S, K, 2)

    us = time_fn(
        lambda: _run(ec, jnp.broadcast_to(start[None], (K, 2)), seed=0), iters=3, warmup=1
    )

    # (1) worst-case exploration over the first 150 steps
    sg_worst = float(max(nll(t_sg[r, :150]).mean() for r in range(N_RUNS)))
    ec_worst = float(
        max(nll(t_ec[g, :150, i]).mean() for g in range(2) for i in range(K))
    )
    # (2) coherence: late-phase cross-chain spread vs cross-run spread
    # (shared estimator — leading axis = runs resp. chains)
    sg_spread = float(diag.cross_chain_spread(t_sg[:, 400:, :]))
    ec_spread = float(diag.cross_chain_spread(np.moveaxis(t_ec[0, 400:, :, :], 1, 0)))
    # (3) both reach the mode: final NLL of the pooled posterior mean
    sg_final = float(nll(diag.pooled_moments(t_sg[:, 500:])[0]))
    ec_final = float(nll(diag.pooled_moments(t_ec[:, 500:])[0].mean(axis=0)))
    # (4) exploration speed: effective sample size per position dim.
    # Pool BOTH EC groups (2 x K = 8 chains) so the raw sample budget
    # matches the N_RUNS=8 SGHMC side.  The pooled estimator assumes
    # independent chains — exact for the SGHMC runs, an UPPER bound for the
    # coupled chains — so the conservative chain-mean (coupled) ESS is
    # emitted alongside; the truth for EC lies between the two.
    ec_chains = np.concatenate(
        [np.moveaxis(t_ec[g, 150:, :, :], 1, 0) for g in range(t_ec.shape[0])], axis=0
    )  # (2K, S', 2)
    sg_ess = float(np.sum(diag.effective_sample_size_nd(t_sg[:, 150:, :])))
    ec_ess = float(np.sum(diag.effective_sample_size_nd(ec_chains)))
    sg_cess = float(np.sum(diag.coupled_ess_nd(t_sg[:, 150:, :])))
    ec_cess = float(np.sum(diag.coupled_ess_nd(ec_chains)))
    sg_rhat = float(np.max(diag.split_rhat_nd(t_sg[:, 150:, :])))
    ec_rhat = float(np.max(diag.split_rhat_nd(ec_chains)))

    emit("fig1_toy/sghmc_worst_run_nll_first100", us / STEPS, f"{sg_worst:.3f}")
    emit("fig1_toy/ecsghmc_worst_chain_nll_first100", us / STEPS, f"{ec_worst:.3f}")
    emit("fig1_toy/sghmc_cross_run_spread", us / STEPS, f"{sg_spread:.4f}")
    emit("fig1_toy/ecsghmc_cross_chain_spread", us / STEPS, f"{ec_spread:.4f}")
    emit("fig1_toy/sghmc_final_mean_nll", us / STEPS, f"{sg_final:.4f}")
    emit("fig1_toy/ecsghmc_final_mean_nll", us / STEPS, f"{ec_final:.4f}")
    emit("fig1_toy/sghmc_pooled_ess", us / STEPS, f"{sg_ess:.0f}")
    emit("fig1_toy/ecsghmc_pooled_ess", us / STEPS, f"{ec_ess:.0f}")
    emit("fig1_toy/sghmc_chain_mean_ess", us / STEPS, f"{sg_cess:.0f}")
    emit("fig1_toy/ecsghmc_chain_mean_ess", us / STEPS, f"{ec_cess:.0f}")
    emit("fig1_toy/sghmc_split_rhat", us / STEPS, f"{sg_rhat:.3f}")
    emit("fig1_toy/ecsghmc_split_rhat", us / STEPS, f"{ec_rhat:.3f}")
    ok = ec_worst < sg_worst and ec_spread < sg_spread and ec_final < 0.5
    emit("fig1_toy/claim_ec_coherent_fast_exploration", us / STEPS, "CONFIRMED" if ok else "REFUTED")
    return {
        "sg_worst": sg_worst, "ec_worst": ec_worst,
        "sg_spread": sg_spread, "ec_spread": ec_spread,
        "sg_ess": sg_ess, "ec_ess": ec_ess,
    }


if __name__ == "__main__":
    run()
