"""Quickstart: elastically-coupled SG-MCMC on a 2-D Gaussian (paper Fig. 1).

The whole run executes device-resident through ``repro.run.rollout`` — the
same chunked-scan executor every driver in this repo uses.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.run import rollout

MU = jnp.array([2.0, -1.0])
K, STEPS = 4, 800


def grad_U(theta):  # U = ||theta - mu||^2 / 2  =>  posterior N(mu, I)
    return theta - MU


def main():
    # K chains, coupled through a center variable, syncing every 4 steps
    sampler = core.ec_sghmc(step_size=5e-2, alpha=1.0, sync_every=4,
                            noise_convention="eq4", center_noise_in_p=False)
    keys = jax.random.split(jax.random.PRNGKey(0), STEPS)
    res = rollout(sampler, grad_U, jnp.zeros((K, 2)), num_steps=STEPS,
                  keys=keys, moments=False)
    samples = np.asarray(res.trace)[STEPS // 4 :].reshape(-1, 2)

    print(f"target  mean: {np.asarray(MU)}          target  var: [1. 1.]")
    print(f"sampled mean: {samples.mean(0).round(3)}   sampled var: {samples.var(0).round(3)}")
    print(f"center ended at: {np.asarray(res.state.center).round(3)}")

    # ASCII density plot
    H, xe, ye = np.histogram2d(samples[:, 0], samples[:, 1], bins=(24, 12),
                               range=[[-1, 5], [-4, 2]])
    shades = " .:-=+*#%@"
    print("\nsample density (x: theta_0, y: theta_1):")
    for row in (H / max(H.max(), 1) * (len(shades) - 1)).astype(int).T[::-1]:
        print("  " + "".join(shades[v] for v in row))


if __name__ == "__main__":
    main()
