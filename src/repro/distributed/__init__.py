from . import compression, sharding
from .compression import Int8Codec, int8_codec
from .sharding import build_spec, chain_specs, tree_shardings, tree_specs
