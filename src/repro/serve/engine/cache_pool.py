"""Slot-indexed KV/recurrent cache pool for the serving engine.

One pre-allocated pytree holds every decode slot's cache for every ensemble
member: each leaf of ``model.make_cache(cfg, batch=1, max_seq)`` is pooled
with a leading ``(K, num_slots)`` axis.  The pool is allocated ONCE at
engine construction; admissions and completions recycle slots by index —
no per-request allocation, no shape change, hence no retrace of the decode
program as streams join and leave.

Slots are also the engine's suspension unit: ``park`` lifts one slot's
cache out of the live pool (optionally through the int8 block codec from
``repro.distributed.compression`` — 4x smaller idle footprint, and the same
soundness argument as compressing the EC sync collective: a perturbed
cache/center is what the elastically coupled ensemble is designed to
tolerate), and ``restore`` decodes it back into any free slot.  Float
leaves round-trip through int8; integer leaves (ring-buffer pointers ``t``)
are kept exact.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.compression import int8_codec


class ParkedCache(NamedTuple):
    """A slot's cache lifted out of the live pool (possibly compressed)."""

    leaves: list
    treedef: Any
    compressed: bool


class CachePool:
    """Pre-allocated (K, num_slots, ...) cache pool with free-list recycling.

    The engine owns ``caches`` and is expected to REPLACE it after every
    jitted step (the pooled buffers are donated through the decode/admit
    programs).  The pool itself only tracks slot occupancy and park/restore.
    """

    def __init__(
        self,
        cfg,
        model,
        *,
        num_members: int,
        num_slots: int,
        max_seq: int,
        dtype=None,
        compress_parked: bool = False,
    ):
        if num_members < 1 or num_slots < 1:
            raise ValueError("num_members and num_slots must be >= 1")
        self.num_members = int(num_members)
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.compress_parked = bool(compress_parked)
        self._codec = int8_codec()
        proto = model.make_cache(cfg, 1, max_seq, dtype or cfg.compute_dtype, abstract=True)
        self.slot_shape = jax.tree.map(lambda s: (s.shape, s.dtype), proto)
        self.caches = jax.tree.map(
            lambda s: jnp.zeros((self.num_members, self.num_slots) + s.shape, s.dtype),
            proto,
        )
        self._free = list(range(self.num_slots - 1, -1, -1))  # pop() -> slot 0 first
        self.acquired = 0
        self.released = 0
        self.high_water = 0

    # -- slot bookkeeping ---------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> int:
        return self.num_slots - len(self._free)

    def acquire(self) -> int:
        """Claim a free slot index; raises IndexError when the pool is full
        (the scheduler checks ``free_slots`` before admitting)."""
        slot = self._free.pop()
        self.acquired += 1
        self.high_water = max(self.high_water, self.active_slots)
        return slot

    def release(self, slot: int) -> None:
        if slot in self._free or not (0 <= slot < self.num_slots):
            raise ValueError(f"release of non-acquired slot {slot}")
        self._free.append(slot)
        self.released += 1

    # -- park / restore (idle-slot compression) -----------------------------

    def park(self, slot: int, *, release: bool = True) -> ParkedCache:
        """Lift slot ``slot``'s cache out of the live pool.  With
        ``compress_parked`` float leaves go through the int8 block codec
        (~4x smaller); int leaves stay exact.  ``release`` frees the slot."""
        leaves, treedef = jax.tree.flatten(
            jax.tree.map(lambda a: a[:, slot], self.caches)
        )
        if self.compress_parked:
            leaves = [
                self._codec.encode(x) if jnp.issubdtype(x.dtype, jnp.floating) else x
                for x in leaves
            ]
        if release:
            self.release(slot)
        return ParkedCache(leaves, treedef, self.compressed_parking)

    def restore(self, parked: ParkedCache, slot: int | None = None) -> int:
        """Write a parked cache back into ``slot`` (or a newly acquired
        one); returns the slot index."""
        if slot is None:
            slot = self.acquire()
        leaves = [
            self._codec.decode(x) if isinstance(x, dict) and "q" in x else x
            for x in parked.leaves
        ]
        one = jax.tree.unflatten(parked.treedef, leaves)
        self.caches = jax.tree.map(
            lambda full, x: full.at[:, slot].set(x.astype(full.dtype)), self.caches, one
        )
        return slot

    @property
    def compressed_parking(self) -> bool:
        return self.compress_parked

    def stats(self) -> dict:
        return {
            "num_slots": self.num_slots,
            "active": self.active_slots,
            "high_water": self.high_water,
            "acquired": self.acquired,
            "released": self.released,
        }
