"""Effective sample size and split-R̂ (host-side numpy, post-hoc).

Implements the Stan/Vehtari-et-al. estimators: per-chain autocorrelation
via FFT, cross-chain pooling through the between/within decomposition, and
Geyer's initial monotone positive sequence for truncation.  Inputs are
``(num_chains, num_samples)`` arrays (a 1-D array is treated as one chain);
``*_nd`` variants map the estimator over trailing sample dimensions.

These run on trajectories AFTER sampling — they are numpy on purpose (no
tracing, no device transfers beyond the trajectory itself).
"""
from __future__ import annotations

import numpy as np


def _as_chains(x) -> np.ndarray:
    x = np.asarray(x, np.float64)
    if x.ndim == 1:
        x = x[None, :]
    if x.ndim != 2:
        raise ValueError(f"expected (chains, samples) or (samples,), got {x.shape}")
    return x


def autocorrelation(x) -> np.ndarray:
    """Per-chain autocorrelation function via FFT.  (M, N) -> (M, N),
    rho[:, 0] == 1.  Constant chains return zeros past lag 0."""
    x = _as_chains(x)
    m, n = x.shape
    x = x - x.mean(axis=1, keepdims=True)
    nfft = 1 << (2 * n - 1).bit_length()
    f = np.fft.rfft(x, nfft, axis=1)
    acov = np.fft.irfft(f * np.conj(f), nfft, axis=1)[:, :n] / n
    var0 = acov[:, :1]
    safe = np.where(var0 > 0, var0, 1.0)
    rho = acov / safe
    rho[:, 0] = 1.0
    return np.where(var0 > 0, rho, np.concatenate([np.ones((m, 1)), np.zeros((m, n - 1))], 1))


def effective_sample_size(x) -> float:
    """Multi-chain ESS (Vehtari et al. 2021 / Stan).  Cross-chain mean
    disagreement deflates ESS through the between-chain variance term, so
    unconverged chains report honestly small values."""
    x = _as_chains(x)
    m, n = x.shape
    if n < 4:
        return float(m * n)
    chain_var = x.var(axis=1, ddof=1)
    w = chain_var.mean()
    var_plus = w * (n - 1) / n
    if m > 1:
        var_plus += x.mean(axis=1).var(ddof=1)
    if var_plus <= 0 or w <= 0:
        return float(m * n)

    # mean-over-chains autocovariance at each lag, pooled rho_t
    acov = autocorrelation(x) * chain_var[:, None] * (n - 1) / n
    rho = 1.0 - (w - acov.mean(axis=0)) / var_plus
    rho[0] = 1.0

    # Geyer: pair sums, truncate at first negative pair, enforce monotone
    n_pairs = len(rho) // 2
    pairs = rho[: 2 * n_pairs].reshape(n_pairs, 2).sum(axis=1)
    tau = 0.0
    running_min = np.inf
    for k, p in enumerate(pairs):
        if p < 0 and k > 0:
            break
        running_min = min(running_min, max(p, 0.0))
        tau += 2.0 * running_min
    tau = max(tau - 1.0, 1.0 / (m * n))  # -1: lag-0 double count in pair sums
    return float(min(m * n / tau, m * n * np.log10(max(m * n, 10))))


def coupled_ess(x) -> float:
    """Conservative ESS for COUPLED chains.  The multi-chain estimator
    above assumes independent chains and overstates ESS by up to K× when
    chains co-move — which is elastic coupling's whole point.  Collapsing
    to the chain-mean series treats the K chains as a single chain: a
    lower bound that is tight when coupling is strong.  Use this (or
    report both) whenever the chains interact."""
    x = _as_chains(x)
    return effective_sample_size(x.mean(axis=0))


def coupled_ess_nd(x) -> np.ndarray:
    """Per-dimension conservative ESS for (chains, samples, *dims)."""
    return _map_trailing(coupled_ess, x)


def split_rhat(x) -> float:
    """Split-R̂: each chain halved, potential scale reduction across the 2M
    half-chains.  ~1.0 at convergence; > ~1.01 flags trouble."""
    x = _as_chains(x)
    m, n = x.shape
    half = n // 2
    if half < 2:
        return float("nan")
    halves = np.concatenate([x[:, :half], x[:, n - half :]], axis=0)  # (2M, half)
    w = halves.var(axis=1, ddof=1).mean()
    b = half * halves.mean(axis=1).var(ddof=1)
    if w <= 0:
        # frozen chains: identical constants are (vacuously) converged, but
        # DISTINCT constants are the starkest possible divergence
        return 1.0 if b <= 0 else float("inf")
    var_plus = (half - 1) / half * w + b / half
    return float(np.sqrt(var_plus / w))


def _map_trailing(fn, x):
    """Apply a (chains, samples) estimator over trailing dims of
    (M, N, *dims) — returns an array shaped ``dims``."""
    x = np.asarray(x, np.float64)
    if x.ndim < 2:
        raise ValueError(f"need at least (chains, samples), got {x.shape}")
    m, n = x.shape[:2]
    flat = x.reshape(m, n, -1)
    out = np.array([fn(flat[:, :, d]) for d in range(flat.shape[2])])
    return out.reshape(x.shape[2:]) if x.ndim > 2 else out.reshape(())


def effective_sample_size_nd(x) -> np.ndarray:
    """Per-dimension ESS for (chains, samples, *dims) trajectories."""
    return _map_trailing(effective_sample_size, x)


def split_rhat_nd(x) -> np.ndarray:
    """Per-dimension split-R̂ for (chains, samples, *dims) trajectories."""
    return _map_trailing(split_rhat, x)
