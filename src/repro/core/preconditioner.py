"""Diagonal mass-matrix adaptation (RMSProp/Adam-style, à la scale-adapted
SGHMC and pSGLD).

Maintains a running second-moment estimate V̂ = E[g²] per parameter and
exposes M⁻¹ = 1/(√V̂ + ε) as a pytree the samplers consume in place of the
scalar ``mass``.  Adaptation is FROZEN after ``burnin`` steps so the sampler
targets a fixed (valid) Hamiltonian afterwards: for every step ≥ burnin the
returned M⁻¹ is bit-identical — the contract the frozen-preconditioner
oracle (``repro.diagnostics.oracle``) and the stationary battery rely on.

Both preconditioners share the ``(init, update)`` transform shape:

    p_init, p_update = rmsprop_preconditioner(decay=0.99, burnin=1000)
    pstate = p_init(params)
    minv, pstate = p_update(pstate, grads)   # minv: pytree like params, > 0

Identity preconditioning for equivalence tests: ``decay=1.0`` holds V̂ at
its all-ones init and ``eps=0.0`` makes M⁻¹ exactly 1.0 — a sampler built
that way must match its unpreconditioned twin bit-for-bit
(``tests/test_adaptive_equivalence.py``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .types import Params


class PrecondState(NamedTuple):
    """Diagonal-preconditioner carry: ``v`` is the running E[g²] pytree
    (same structure as params, f32 leaves); ``step`` the adaptation
    counter that implements the burn-in freeze."""

    v: Params  # running E[g²], pytree congruent with params
    step: jnp.ndarray  # scalar i32


def rmsprop_preconditioner(decay: float = 0.99, eps: float = 1e-8, burnin: int = 1000):
    """M⁻¹ = 1/(√V̂ + ε) with V̂ an exponential moving average of g²
    (Springenberg et al.'s scale-adapted choice).  ``decay=1.0`` freezes V̂
    at the all-ones init (identity preconditioning when ``eps=0``)."""

    def init(params):
        return PrecondState(
            v=jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32), params),
            step=jnp.zeros((), jnp.int32),
        )

    def update(state, grads):
        adapt = (state.step < burnin).astype(jnp.float32)
        new_v = jax.tree.map(
            lambda v, g: v + adapt * (1 - decay) * (jnp.square(g.astype(jnp.float32)) - v),
            state.v,
            grads,
        )
        minv = jax.tree.map(lambda v: 1.0 / (jnp.sqrt(v) + eps), new_v)
        return minv, PrecondState(v=new_v, step=state.step + 1)

    return init, update


def adam_preconditioner(beta2: float = 0.999, eps: float = 1e-8, burnin: int = 1000):
    """Adam-style second-moment preconditioner with bias correction:

        M⁻¹ = 1 / (√(V̂ / (1 − β₂^t)) + ε)

    The correction counter saturates at ``burnin`` together with V̂, so the
    post-freeze M⁻¹ is a constant function of the frozen state — bit-frozen
    for all steps ≥ burnin like the RMSProp variant.  (No first moment: a
    sampler wants a mass matrix, not a search direction.)"""

    def init(params):
        return PrecondState(
            v=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            step=jnp.zeros((), jnp.int32),
        )

    def update(state, grads):
        adapt = (state.step < burnin).astype(jnp.float32)
        new_v = jax.tree.map(
            lambda v, g: v + adapt * (1 - beta2) * (jnp.square(g.astype(jnp.float32)) - v),
            state.v,
            grads,
        )
        # saturating step count: bias correction freezes with V̂
        t_eff = jnp.minimum(state.step + 1, burnin).astype(jnp.float32)
        correction = 1.0 - beta2**t_eff
        minv = jax.tree.map(lambda v: 1.0 / (jnp.sqrt(v / correction) + eps), new_v)
        return minv, PrecondState(v=new_v, step=state.step + 1)

    return init, update


def get_preconditioner(name: str, *, burnin: int, decay: float, eps: float):
    """Resolve a preconditioner family by name ("rmsprop" | "adam").
    ``decay`` maps to the EMA coefficient (β₂ for adam)."""
    if name == "rmsprop":
        return rmsprop_preconditioner(decay=decay, eps=eps, burnin=burnin)
    if name == "adam":
        return adam_preconditioner(beta2=decay, eps=eps, burnin=burnin)
    raise ValueError(f"unknown preconditioner {name!r} (want 'rmsprop' or 'adam')")


def frozen_mass_inv(pstate: PrecondState, *, eps: float = 1e-8):
    """The M⁻¹ implied by a (frozen) RMSProp preconditioner state — what the
    stationary battery feeds to the frozen-preconditioner oracle.  Must match
    ``rmsprop_preconditioner``'s formula exactly."""
    return jax.tree.map(lambda v: 1.0 / (jnp.sqrt(v) + eps), pstate.v)
